"""The paper's §3.2/§3.3 distributed machinery, visibly at work.

Part 1 (in-process): places a graph across 3 virtual workers with the
§3.2.1 greedy cost-model placer, partitions it with canonicalised
Send/Recv (§3.2.2), schedules Recvs ASAP/ALAP (§5.2), runs it with one
executor thread per worker coordinating through the rendezvous —
optionally with §5.5 lossy 32->16 bit compression on every cross-worker
edge.

Part 2 (multi-process, DESIGN.md §11): spawns two REAL worker processes
serving the TCP wire protocol, ships the partitioned subgraphs to them
(RegisterGraph), runs the same computation with tensors crossing OS
process boundaries through the WireRendezvous, and shows the result
bit-matching the in-process run — plus the heartbeat view of the pool.

  PYTHONPATH=src python examples/distributed_graph.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Session
from repro.core import placement, partition, scheduler, distributed_runner
from repro.runtime.devices import DeviceSet


def main():
    rs = np.random.RandomState(0)
    b = GraphBuilder()
    # pipeline: worker0 produces, worker1 transforms, worker2 reduces
    data = b.constant(jnp.array(rs.randn(256, 256).astype("f")),
                      name="data", device="/job:worker/task:0")
    w1 = b.constant(jnp.array(rs.randn(256, 256).astype("f") * 0.05),
                    name="w1", device="/job:worker/task:1")
    h = b.relu(b.matmul(data, w1, name="mm1", device="/job:worker/task:1"),
               name="h", device="/job:worker/task:1")
    w2 = b.constant(jnp.array(rs.randn(256, 64).astype("f") * 0.05),
                    name="w2", device="/job:worker/task:2")
    out = b.reduce_sum(b.matmul(h, w2, name="mm2", device="/job:worker/task:2"),
                       name="out", device="/job:worker/task:2")

    from repro.core.options import SessionOptions
    devices = DeviceSet.make_cluster(3, 1, kind="cpu")
    sess = Session(b.graph, options=SessionOptions(devices=devices))

    node_set = sess.pruned_nodes([out.ref], {})
    place = placement.place(b.graph, devices, node_names=node_set)
    parted = partition.partition(b.graph, place, node_set)
    n_ctrl = scheduler.schedule_recvs(parted.graph, set(parted.graph.nodes),
                                      placement.CostModel(), devices,
                                      parted.placement)
    print(f"placement: { {n: place[n].split('/')[2] for n in sorted(place)} }")
    print(f"transfers inserted: {parted.n_transfers} "
          f"(Send/Recv pairs, canonicalised)")
    print(f"ASAP/ALAP control edges added to Recvs: {n_ctrl}")

    exact = sess.run(out.ref)
    print(f"distributed result: {float(exact):.4f}")

    # same graph with §5.5 lossy compression on the wire
    (lossy,) = distributed_runner.run_partitioned(
        sess, node_set, [out.ref], {}, compress=True)
    rel = abs(float(lossy) - float(exact)) / abs(float(exact))
    print(f"with 32->16 bit wire compression: {float(lossy):.4f} "
          f"(rel err {rel:.2e}, bound 2^-7={2**-7:.2e})")
    return float(exact)


def main_wire(expected):
    """DESIGN.md §11: the same machinery across real OS processes."""
    from repro.distrib import start_worker_processes, stop_worker_processes

    print("\n-- multi-process (2 worker processes over TCP) --")
    procs, spec = start_worker_processes(2)
    sess = None
    try:
        rs = np.random.RandomState(0)
        b = GraphBuilder()
        data = b.constant(jnp.array(rs.randn(256, 256).astype("f")),
                          name="data", device="/job:worker/task:0")
        w1 = b.constant(jnp.array(rs.randn(256, 256).astype("f") * 0.05),
                        name="w1", device="/job:worker/task:1")
        h = b.relu(b.matmul(data, w1, name="mm1", device="/job:worker/task:1"),
                   name="h", device="/job:worker/task:1")
        w2 = b.constant(jnp.array(rs.randn(256, 64).astype("f") * 0.05),
                        name="w2", device="/job:worker/task:0")
        out = b.reduce_sum(
            b.matmul(h, w2, name="mm2", device="/job:worker/task:0"),
            name="out", device="/job:worker/task:0")

        from repro.core.options import SessionOptions
        sess = Session(b.graph, options=SessionOptions(cluster=spec))
        wire = sess.run(out.ref)     # RegisterGraph + RunGraph under the hood
        again = sess.run(out.ref)    # cached Executable: RunGraph only
        print(f"worker pool: {', '.join(spec.workers)}")
        print(f"result over the wire rendezvous: {float(wire):.4f} "
              f"(run 2: {float(again):.4f}; cache {sess.cache_stats})")
        print(f"bit-matches the in-process run: {float(wire) == expected}")
        exe = sess.executable([out.ref], set())
        stats = exe.wire_plan.last_run_stats
        print("per-task wire traffic:",
              {f"task:{t}": s for t, s in sorted(stats.items())})
        import time

        time.sleep(1.0)  # let a heartbeat cycle land
        hb = {t: exe.wire_plan.master._info.get(t, {}).get("pid")
              for t in sorted(stats)}
        print(f"heartbeats: worker pids {hb} (master pid {os.getpid()})")
    finally:
        if sess is not None:
            sess.close()
        stop_worker_processes(procs, spec)


if __name__ == "__main__":
    main_wire(main())
