"""Quickstart: the paper's Figure-1 program on this system.

Builds relu(Wx+b), a loss, §4.1 gradients, runs eagerly via Session.Run
(§2), then compiles the same graph through the §10 lowering and trains —
the whole core API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Session, gradients, compile_subgraph
from repro.optim import attach_train_op


def build_graph():
    """Figure-1 graph + §4.1 train op, as an importable factory — the
    `python -m repro.analysis.lint` suite verifies exactly this graph."""
    rs = np.random.RandomState(0)
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.array(
        rs.uniform(-1, 1, (100, 784)).astype("float32")))
    bias = b.variable("b", init_value=lambda: jnp.zeros((100,), "float32"))
    x = b.placeholder("x")                       # (batch, 784)
    y = b.placeholder("y")                       # (batch,) int labels in [0,100)
    h = b.relu(b.add(b.matmul(x, b.call(jnp.transpose, [W], name="WT")), bias))
    C = b.softmax_xent(h, y, name="C")
    train_op = attach_train_op(b, C, [W, bias], optimizer="adamw", lr=1e-3)
    return b, dict(W=W, bias=bias, x=x, y=y, C=C, train_op=train_op)


def main():
    rs = np.random.RandomState(0)

    # --- Figure 1: build the graph with the Python front end (plus the
    # §4.1 + optimizer nodes: "updates are just more nodes in the graph")
    b, refs = build_graph()
    W, bias, x, y, C, train_op = (refs[k] for k in
                                  ("W", "bias", "x", "y", "C", "train_op"))

    # --- §2 Session.Run: eager execution of exactly the needed subgraph
    # (fetching C alone prunes the optimizer nodes away)
    sess = Session(b.graph)
    X = jnp.array(rs.randn(32, 784).astype("float32"))
    Y = jnp.array(rs.randint(0, 100, (32,)), jnp.int32)
    print("initial loss:", float(sess.run(C.ref, {x.ref: X, y.ref: Y})))

    for step in range(10):
        loss, _ = sess.run([C.ref, train_op.ref], {x.ref: X, y.ref: Y})
        print(f"eager step {step}: loss {float(loss):.4f}")

    # --- §10: compile the SAME graph to one fused jitted function
    low = compile_subgraph(sess, [C.ref], [x.ref, y.ref],
                           extra_updates=[train_op.name])
    step_fn = jax.jit(low.fn)
    variables = {n: sess.variable_value(n)
                 for n in set(low.var_reads) | set(low.var_writes)}
    for step in range(10):
        (loss,), new_vars = step_fn({"x:0": X, "y:0": Y}, variables)
        variables.update(new_vars)
        print(f"compiled step {step}: loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
