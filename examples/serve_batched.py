"""Serve a small model with batched requests: prefill + greedy decode
through the cache-as-Variable serve step.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b --gen 48
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    res = serve(args.arch, smoke=True, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print("batch outputs (first 12 ids each):")
    for i, row in enumerate(res["generated"]):
        print(f"  req[{i}]:", row[:12].tolist())


if __name__ == "__main__":
    main()
