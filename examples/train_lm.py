"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full stack: synthetic data pipeline (§4.5/4.6) -> graph-built train step
(§4.1 gradients + AdamW nodes) -> §10 lowering -> jax.jit -> §3.3
checkpointing with resume.  ~100M params on CPU is slow; pass --fast for
a 10M-param variant.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --fast --steps 100
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
import repro.configs as configs  # noqa: E402

# ~100M-parameter dense LM (llama-ish) used by the assignment's e2e ask.
LM_100M = ModelConfig(
    arch_id="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    tie_embeddings=True, source="this repo (e2e driver config)")

LM_10M = ModelConfig(
    arch_id="repro-10m", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=8192,
    tie_embeddings=True, source="this repo (fast variant)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = LM_10M if args.fast else LM_100M

    # register the config so launch.train can find it by id
    import types

    mod = types.ModuleType(cfg.arch_id)
    mod.CONFIG = cfg
    mod.SMOKE = cfg
    sys.modules[f"repro.configs.{cfg.arch_id.replace('-', '_').replace('.', 'p')}"] = mod

    res = train(cfg.arch_id, smoke=False, steps=args.steps, batch=args.batch,
                seq=args.seq, lr=6e-4, ckpt_dir=args.ckpt_dir,
                ckpt_every=100)
    losses = res["losses"]
    print(f"first-10 mean {sum(losses[:10])/10:.4f} -> "
          f"last-10 mean {sum(losses[-10:])/10:.4f}")
    assert sum(losses[-10:]) < sum(losses[:10]), "loss did not decrease!"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
