"""Benchmark harness — one benchmark per paper claim/table.

The paper defers its quantitative section ("§8: a future version of this
white paper will have a comprehensive performance evaluation"), so the
benchmarks target the paper's *structural* performance claims plus this
repo's §Roofline artifacts:

  b1  session_run_overhead   §3.1 ready-queue executor dispatch cost
  b2  compiled_vs_eager      §10/§6: JIT-compiled graph vs interpreted
                             (the paper's "6x over DistBelief" analogue)
  b3  send_recv_rendezvous   §3.2.2 transfer latency + canonicalisation
  b4  lossy_compression      §5.5 compress/decompress throughput
  b5  input_pipeline         §4.6 prefetch-queue overlap win
  b6  cse                    §5.1 node-count reduction
  b7  recv_scheduling        §5.2 peak-memory window reduction (simulated)
  b8  kernel_registry        §12 registered-kernel dispatch: the smoke LM
                             block with the backend registry on vs off
  b9  train_throughput       end-to-end compiled training tokens/s
  b10 roofline_table         §Roofline summary from experiments/dryrun
  b14 replicated_training    §4.3–§4.4 data-parallel replication over a
                             4-process pool: tok/s vs replica count +
                             sync-vs-async convergence on the smoke LM

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS = []


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _timeit(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------

def bench_session_run_overhead():
    from repro.core import GraphBuilder, Session

    b = GraphBuilder()
    x = b.constant(jnp.ones((8, 8)), name="x")
    cur = x
    n_ops = 64
    for i in range(n_ops):
        cur = b.add(cur, x, name=f"a{i}")
    sess = Session(b.graph)
    us = _timeit(lambda: sess.run(cur.ref))
    emit("b1_session_run_overhead", us, f"{us / n_ops:.2f}us/op@{n_ops}ops")


def bench_compiled_vs_eager():
    """§10/§6: whole-graph JIT vs interpreted per-op dispatch.

    The eager Session runs UNFUSED — since PR 2 the default eager path
    partially compiles via region fusion (and the deque ready queue made
    dispatch ~2x cheaper), which was masking the gap this benchmark
    exists to track.  The graph is a matmul-heavy residual chain so both
    sides do real compute and the contrast stays §10 whole-graph jit vs
    interpreted dispatch.  The fused-fast row (DESIGN.md §9) runs the
    SAME Session engine with numerics="fast": matmuls/reductions join the
    region and compile at full XLA opt, so the eager engine closes most
    of the gap to the hand-lowered jit."""
    from repro.core import GraphBuilder, Session, compile_subgraph

    rs = np.random.RandomState(0)
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.array(
        rs.randn(256, 256).astype("f") * 0.05))
    x = b.placeholder("x")
    cur = x
    n_layers = 16
    for i in range(n_layers):
        h = b.matmul(cur, W, name=f"mm{i}")
        cur = b.relu(b.add(h, cur, name=f"res{i}"), name=f"r{i}")
    out = b.reduce_sum(cur)
    from repro.core.options import SessionOptions
    sess = Session(b.graph, options=SessionOptions(fuse_regions=False))
    X = jnp.array(rs.randn(64, 256).astype("f"))
    # block on every fetch: jax dispatch is async even on CPU, and the
    # fused engine issues ONE region call — an unblocked timer would
    # measure dispatch, not compute (the eager side blocks too so the
    # derived speedup divides like for like)
    eager_us = _timeit(lambda: jax.block_until_ready(
        sess.run(out.ref, {x.ref: X})))
    fast_sess = Session(b.graph, options=SessionOptions(
        fuse_regions=True, numerics="fast", parity_guard=False))
    fast_us = _timeit(lambda: jax.block_until_ready(
        fast_sess.run(out.ref, {x.ref: X})))
    low = compile_subgraph(sess, [out.ref], [x.ref])
    jf = jax.jit(low.fn)
    Wv = sess.variable_value("W")
    jf({"x:0": X}, {"W": Wv})  # compile
    comp_us = _timeit(lambda: jax.block_until_ready(
        jf({"x:0": X}, {"W": Wv})[0][0]))
    emit("b2_eager_graph", eager_us, f"interpreted,{n_layers}xmatmul256")
    emit("b2_fused_fast_graph", fast_us,
         f"numerics=fast,speedup={eager_us / fast_us:.1f}x_over_interp")
    emit("b2_compiled_graph", comp_us,
         f"speedup={eager_us / comp_us:.1f}x")


def bench_send_recv():
    from repro.runtime.rendezvous import Rendezvous, make_key

    r = Rendezvous()
    payload = jnp.ones((256, 256))
    i = [0]

    def xfer():
        k = make_key("t", "a", "b", i[0])
        i[0] += 1
        r.send(k, payload)
        r.recv(k)

    us = _timeit(xfer, n=200)
    mbps = payload.nbytes / (us / 1e6) / 1e6
    emit("b3_send_recv_roundtrip", us, f"{mbps:.0f}MB/s")

    # canonicalisation saving: N consumers of one remote tensor -> 1 xfer
    from repro.core import GraphBuilder
    from repro.core import partition as pt

    b = GraphBuilder()
    x = b.constant(jnp.ones(4), name="x")
    consumers = [b.square(x, name=f"c{i}") for i in range(8)]
    place = {"x": "/job:worker/task:0/device:cpu:0"}
    for c in consumers:
        place[c.name] = "/job:worker/task:1/device:cpu:0"
    parted = pt.partition(b.graph, place)
    emit("b3_canonicalised_transfers", 0.0,
         f"{parted.n_transfers}xfer_for_8_consumers")


def bench_compression():
    from repro.core import compression as C

    x = jnp.array(np.random.randn(1 << 20).astype("f"))
    comp = jax.jit(C.compress_f32_to_16)
    dec = jax.jit(C.decompress_16_to_f32)
    w = comp(x)
    us_c = _timeit(lambda: jax.block_until_ready(comp(x)))
    us_d = _timeit(lambda: jax.block_until_ready(dec(w)))
    gbs = x.nbytes / (us_c / 1e6) / 1e9
    emit("b4_compress_1M_f32", us_c, f"{gbs:.1f}GB/s,wire_bytes=0.5x")
    emit("b4_decompress_1M_f32", us_d, "")


def bench_input_pipeline():
    """§4.6 prefetch overlap.  Median of several reps: a mean of 3 was
    noisy enough to report a spurious <1.0x "regression" (batch
    generation holds the GIL for ~4ms at a stretch, so a single convoyed
    rep dominated the mean — see data/pipeline.py Prefetcher._fill)."""
    import statistics

    from repro.data import SyntheticLMDataset, Prefetcher, batch_iterator

    ds = SyntheticLMDataset(vocab_size=32000, seq_len=512, seed=0)

    def consume_direct():
        it = batch_iterator(ds, 8)
        for _ in range(10):
            next(it)
            time.sleep(0.002)  # simulated compute

    def consume_prefetched():
        pf = Prefetcher(batch_iterator(ds, 8), capacity=4).start()
        for _ in range(10):
            pf.get()
            time.sleep(0.002)
        pf.stop()

    def _median_us(fn, n=7):
        fn()  # warmup
        reps = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            reps.append((time.perf_counter() - t0) * 1e6)
        return statistics.median(reps)

    us_direct = _median_us(consume_direct)
    us_pf = _median_us(consume_prefetched)
    emit("b5_pipeline_no_prefetch", us_direct, "")
    emit("b5_pipeline_prefetch", us_pf,
         f"overlap_win={us_direct / us_pf:.2f}x")


def bench_cse():
    from repro.core import GraphBuilder
    from repro.core.cse import eliminate_common_subexpressions

    b = GraphBuilder()
    x = b.constant(jnp.ones(4), name="x")
    for i in range(32):  # 32 copies of the same expression
        b.add(b.mul(x, x, name=f"m{i}"), x, name=f"a{i}")
    before = len(b.graph.nodes)
    t0 = time.perf_counter()
    eliminate_common_subexpressions(b.graph)
    us = (time.perf_counter() - t0) * 1e6
    after = len(b.graph.nodes)
    emit("b6_cse", us, f"nodes_{before}->{after}")


def bench_recv_scheduling():
    """§5.2: ASAP vs ALAP recv start -> peak 'resident remote bytes'."""
    from repro.core import GraphBuilder
    from repro.core import placement as pl, partition as pt, scheduler as sc
    from repro.runtime.devices import DeviceSet

    b = GraphBuilder()
    remotes = [b.constant(jnp.ones((256, 256)), name=f"r{i}",
                          device="/job:worker/task:0") for i in range(6)]
    a = b.constant(jnp.ones((256, 256)), name="seed",
                   device="/job:worker/task:1")
    cur = a
    for i, r in enumerate(remotes):
        cur = b.matmul(cur, cur, name=f"chain{i}", device="/job:worker/task:1")
        cur = b.add(cur, r, name=f"use{i}", device="/job:worker/task:1")
    devs = DeviceSet.make_cluster(2, 1, kind="cpu")
    place = pl.place(b.graph, devs)
    parted = pt.partition(b.graph, place)
    cm = pl.CostModel()
    added = sc.schedule_recvs(parted.graph, set(parted.graph.nodes), cm,
                              devs, parted.placement)
    n_recv = sum(1 for n in parted.graph.nodes.values() if n.op == "Recv")
    emit("b7_recv_scheduling", 0.0,
         f"recvs={n_recv},delayed={added},peak_asap={n_recv}buf,peak_alap=1buf")


def bench_kernels():
    """DESIGN.md §12: the kernel-backend registry in a real graph run.

    One smoke LM block (rmsnorm -> q-proj -> attention -> out-proj ->
    residual, x2 layers) executed through the SAME fused-fast Session
    engine twice: registry off (backend="generic", pure XLA lowering) and
    registry on (backend="pallas", pattern-matched regions dispatch onto
    the hand-written kernels).  The pallas row must actually dispatch >=3
    distinct registered kernels or the comparison is vacuous."""
    from repro.core import GraphBuilder, Session
    from repro.core import kernel_registry as kr

    rs = np.random.RandomState(0)
    S, D = 128, 64

    def build():
        b = GraphBuilder()
        x = b.placeholder("x")
        kT = b.constant(jnp.array(rs.randn(D, S).astype("f")), name="kT")
        v = b.constant(jnp.array(rs.randn(S, D).astype("f")), name="v")
        cur = x
        for i in range(2):
            w = b.constant(jnp.array(
                np.abs(rs.randn(D)).astype("f") + 0.5), name=f"w{i}")
            wq = b.constant(jnp.array(
                rs.randn(D, D).astype("f") * 0.2), name=f"wq{i}")
            wo = b.constant(jnp.array(
                rs.randn(D, D).astype("f") * 0.2), name=f"wo{i}")
            xn = b.rmsnorm(cur, w, name=f"l{i}/xn")
            q = b.matmul(xn, wq, name=f"l{i}/q")
            att = b.attention(q, kT, v, scale=D ** -0.5, name=f"l{i}/att")
            proj = b.matmul(att, wo, name=f"l{i}/proj")
            cur = b.add(proj, cur, name=f"l{i}/res")
        out = b.reduce_sum(cur, name="out")
        return b, x, out

    X = jnp.array(rs.randn(S, D).astype("f"))
    rows = {}
    for backend in ("generic", "pallas"):
        b, x, out = build()
        from repro.core.options import SessionOptions
        sess = Session(b.graph, options=SessionOptions(
            numerics="fast", parity_guard=False, backend=backend))
        before = kr.dispatch_counts(backend)
        sess.run(out.ref, {x.ref: X})  # compile + (for pallas) dispatch
        delta = {k: c - before.get(k, 0)
                 for k, c in kr.dispatch_counts(backend).items()
                 if c > before.get(k, 0)}
        # min over repeats: the step is dispatch-overhead heavy, so a
        # mean-of-one-window estimate is too noisy to compare backends
        us = min(_timeit(lambda: jax.block_until_ready(
            sess.run(out.ref, {x.ref: X})), n=20, warmup=2)
            for _ in range(3))
        rows[backend] = us
        kstr = "+".join(sorted(delta)) if delta else "none"
        emit(f"b8_lm_{backend}_fused", us,
             f"s{S}_d{D}_2layer,kernels={kstr}")
        if backend == "pallas":
            assert len(delta) >= 3, (
                f"registry dispatched only {sorted(delta)} — b8 is vacuous")
    emit("b8_registry_on_vs_off", rows["pallas"],
         f"speedup={rows['generic'] / rows['pallas']:.2f}x_vs_generic")


def bench_train_throughput():
    from repro.launch.train import train

    t0 = time.time()
    res = train("smollm-360m", smoke=True, steps=30, batch=8, seq=128,
                log_every=1000, ckpt_dir=None)
    dt = time.time() - t0
    toks = 30 * 8 * 128
    emit("b9_train_tokens_per_s", dt / 30 * 1e6,
         f"{toks / dt:,.0f}tok/s,final_loss={res['final_loss']:.3f}")


def bench_roofline_table():
    pat = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun", "*__1pod_256.json")
    files = sorted(glob.glob(pat))
    if not files:
        emit("b10_roofline_table", 0.0, "no_dryrun_artifacts")
        return
    worst = None
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        rl = rec["roofline"]
        key = f"{rec['arch']}__{rec['shape']}"
        dom = rl["dominant"]
        tot = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        emit(f"b10_roofline[{key}]", tot * 1e6,
             f"dom={dom},useful={rl['useful_ratio']:.2f},"
             f"hbm_gib={rec['per_device_total_bytes'] / 2**30:.1f}")
        if worst is None or tot > worst[1]:
            worst = (key, tot)
    if worst:
        emit("b10_roofline_worst", worst[1] * 1e6, worst[0])


def _two_worker_graph(n_remote=96):
    # fan-in: many remote tensors consumed along a local chain — lots
    # of Recvs, so the §3.2.1/§3.2.2/§5.2 build passes dominate the
    # uncached path while per-run execution stays cheap
    from repro.core import GraphBuilder

    b = GraphBuilder()
    remotes = [b.constant(jnp.ones((4, 4)), name=f"r{i}",
                          device="/job:worker/task:0")
               for i in range(n_remote)]
    cur = b.constant(jnp.ones((4, 4)), name="seed",
                     device="/job:worker/task:1")
    for i, r in enumerate(remotes):
        cur = b.add(b.mul(cur, cur, name=f"m{i}",
                          device="/job:worker/task:1"),
                    r, name=f"u{i}", device="/job:worker/task:1")
    out = b.reduce_sum(cur, name="out", device="/job:worker/task:1")
    return b.graph, out


def bench_executable_cache():
    """DESIGN.md §5: steady-state Session.run steps/sec, cached Executable
    vs rebuilding prune/place/partition/schedule/executors every run, on a
    2-worker graph (the paper's "caches these graphs" master optimisation).
    Both sessions run UNFUSED so b12 keeps measuring the interpreted
    dispatch path across PRs (b13 measures the fused path)."""
    from repro.core import Session
    from repro.runtime.devices import DeviceSet

    g1, out1 = _two_worker_graph()
    g2, out2 = _two_worker_graph()
    from repro.core.options import SessionOptions
    cached = Session(g1, options=SessionOptions(
        devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
        fuse_regions=False))
    uncached = Session(g2, options=SessionOptions(
        devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
        max_cached_executables=0, fuse_regions=False))
    us_uncached = _timeit(lambda: uncached.run(out2.ref), n=8, warmup=2)
    us_cached = _timeit(lambda: cached.run(out1.ref), n=8, warmup=2)
    sps_cached = 1e6 / us_cached
    sps_uncached = 1e6 / us_uncached
    emit("b12_run_uncached", us_uncached, f"{sps_uncached:.0f}steps/s")
    emit("b12_run_cached_executable", us_cached,
         f"{sps_cached:.0f}steps/s,speedup={us_uncached / us_cached:.1f}x,"
         f"hits={cached.cache_stats['hits']}")


def bench_fused_partitioned_step():
    """§10 region fusion (DESIGN.md §7): the b12 2-worker graph executed
    as a handful of FusedRegion kernels + Send/Recv, vs the same cached
    Executable interpreted node-by-node; plus per-op dispatch overhead on
    a fused 64-op chain vs the b1-style interpreted chain.  The fused
    session runs numerics="fast" — the shipping default for the graph
    engine (DESIGN.md §9) — so the terminal ReduceSum joins the region
    and regions compile at full XLA optimization."""
    from repro.core import GraphBuilder, Session
    from repro.runtime.devices import DeviceSet

    g1, out1 = _two_worker_graph()
    g2, out2 = _two_worker_graph()
    from repro.core.options import SessionOptions
    fused = Session(g1, options=SessionOptions(
        devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
        fuse_regions=True, numerics="fast", parity_guard=False))
    interp = Session(g2, options=SessionOptions(
        devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
        fuse_regions=False))
    us_interp = _timeit(lambda: interp.run(out2.ref), n=8, warmup=2)
    us_fused = _timeit(lambda: fused.run(out1.ref), n=8, warmup=2)
    emit("b13_fused_partitioned_step", us_fused,
         f"{1e6 / us_fused:.0f}steps/s,interp={1e6 / us_interp:.0f}steps/s,"
         f"speedup={us_interp / us_fused:.1f}x,numerics=fast")

    # per-op dispatch overhead: placeholder-fed so constant folding cannot
    # collapse the chain — the fused run dispatches ONE super-node
    n_ops = 64
    b = GraphBuilder()
    x = b.placeholder("x")
    cur = x
    for i in range(n_ops):
        cur = b.add(cur, x, name=f"a{i}")
    from repro.core.options import SessionOptions
    sf = Session(b.graph, options=SessionOptions(
        fuse_regions=True, numerics="fast", parity_guard=False))
    su = Session(b.graph, options=SessionOptions(fuse_regions=False))
    X = jnp.ones((8, 8))
    us_u = _timeit(lambda: su.run(cur.ref, {x.ref: X}))
    us_f = _timeit(lambda: sf.run(cur.ref, {x.ref: X}))
    emit("b13_fused_chain_dispatch", us_f,
         f"{us_f / n_ops:.2f}us/op@{n_ops}ops,interp={us_u / n_ops:.2f}us/op,"
         f"speedup={us_u / us_f:.1f}x")


def bench_replicated_training():
    """§4.3–§4.4 / DESIGN.md §15: the factory-Call smoke-LM train step
    replicated over a real 4-process worker pool.

    Reports aggregate tok/s at 1 vs 4 sync replicas plus a 4-replica
    async (parameter-server) leg, and the sync-vs-async loss after the
    same 20-shard stream.  NOTE the scaling derived field is hardware-
    bound: on a single-core container every replica's XLA compute and
    every wire pickle shares one core, so aggregate tok/s is capped near
    1x regardless of replica count (the per-process CPU accounting in
    the wire `timings` stats shows the step is CPU-bound, not
    latency-bound).  On an m-core pool the replica compute runs in
    separate worker processes and the same graph scales.
    """
    from repro.configs import get_config
    from repro.core.options import SessionOptions
    from repro.distrib.replication import ReplicaPlan
    from repro.distrib.worker import (start_worker_processes,
                                      stop_worker_processes)
    from repro.launch.steps import build_lm_replica_spec
    from repro.models.api import Shape

    cfg = get_config("smollm_360m", smoke=True)
    batch, seq, conv_steps = 2, 64, 20
    spec = build_lm_replica_spec(
        cfg, Shape("custom", seq, batch, "train"), lr=1e-2, seed=0,
        hparam_overrides={"compute_dtype": jnp.float32,
                          "loss_chunk": 0, "q_chunk": 0})

    def shard(i, r):
        # a 4-shard cycle per replica: repeated data makes the loss drop
        # visibly within the 20-step convergence window
        rs = np.random.RandomState(1000003 * (i % 4) + 131 * r)
        return {n: rs.randint(0, cfg.vocab_size, (batch, seq))
                .astype(np.int32) for n in spec.feed_names}

    procs, cspec = start_worker_processes(4)
    opts = SessionOptions(numerics="fast", parity_guard=False)
    try:
        results = {}
        for n_rep in (1, 4):
            plan = ReplicaPlan(spec, n_rep, mode="sync", cluster=cspec,
                               options=opts)
            losses = [plan.step([shard(i, r) for r in range(n_rep)])
                      for i in range(conv_steps)]
            fixed = [shard(0, r) for r in range(n_rep)]
            us = _timeit(lambda: plan.step(fixed), n=10, warmup=3)
            results[n_rep] = (us, losses)
            plan.close()
        us1, _ = results[1]
        us4, sync_losses = results[4]
        tok1 = batch * seq / (us1 / 1e6)
        tok4 = 4 * batch * seq / (us4 / 1e6)
        emit("b14_replicated_sync_1x", us1, f"{tok1:.0f}tok/s")
        emit("b14_replicated_sync_4x", us4,
             f"{tok4:.0f}tok/s,scaling={tok4 / tok1:.2f}x,"
             f"loss={sync_losses[0]:.3f}->{sync_losses[-1]:.3f},"
             f"1core-serialized-compute")

        plan = ReplicaPlan(spec, 4, mode="async", cluster=cspec,
                           options=opts)
        plan.run_async(shard, 8)  # warm: registration + per-replica compile
        plan.set_variable_values(spec.init_values)
        # a longer window than sync: interleaved applies see ~n_replicas
        # of gradient staleness, so early losses churn before descending
        async_steps = 2 * conv_steps
        t0 = time.perf_counter()
        applies = plan.run_async(shard, async_steps)
        us_async = (time.perf_counter() - t0) / async_steps * 1e6
        async_last = applies[-1][2]
        plan.close()
        tok_async = batch * seq / (us_async / 1e6)
        emit("b14_replicated_async_4x", us_async,
             f"{tok_async:.0f}tok/s,loss={applies[0][2]:.3f}->"
             f"{async_last:.3f},sync_loss={sync_losses[-1]:.3f}")
    finally:
        stop_worker_processes(procs, cspec)


BENCHES = [
    bench_session_run_overhead,
    bench_compiled_vs_eager,
    bench_send_recv,
    bench_compression,
    bench_input_pipeline,
    bench_cse,
    bench_recv_scheduling,
    bench_kernels,
    bench_train_throughput,
    bench_roofline_table,
    bench_executable_cache,
    bench_fused_partitioned_step,
    bench_replicated_training,
]


def _git_rev() -> str:
    try:
        import subprocess

        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — best effort outside a checkout
        return "unknown"


def write_json(path: str) -> None:
    """Persist the run as BENCH_latest.json (the --check baseline) AND
    append it to BENCH_history.jsonl — one line per full run, so perf is
    a time series across PRs/CI runs, not a single overwritten snapshot."""
    rec = {name: {"us_per_call": us, "derived": derived}
           for name, us, derived in ROWS}
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=2, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    hist = os.path.join(os.path.dirname(os.path.abspath(path)),
                        "BENCH_history.jsonl")
    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
             "rev": _git_rev(), "metrics": rec}
    with open(hist, "a") as fh:
        fh.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"# appended {hist}", flush=True)


# --- regression gate (CI / `pytest -m benchcheck`) --------------------------

# key metrics guarded against regression, with the benchmark function
# that produces each (b1: dispatch overhead, b2: fused-fast eager engine,
# b8: LM step with the kernel registry off/on, b9: end-to-end training,
# b12: cached multi-device step, b13: fused multi-device step)
KEY_METRICS = {
    "b1_session_run_overhead": bench_session_run_overhead,
    "b2_fused_fast_graph": bench_compiled_vs_eager,
    "b8_lm_generic_fused": bench_kernels,
    "b8_lm_pallas_fused": bench_kernels,
    "b9_train_tokens_per_s": bench_train_throughput,
    "b12_run_cached_executable": bench_executable_cache,
    "b13_fused_partitioned_step": bench_fused_partitioned_step,
    "b14_replicated_sync_1x": bench_replicated_training,
    "b14_replicated_sync_4x": bench_replicated_training,
}

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "BENCH_latest.json")


def run_check(threshold: float = 0.25, baseline_path: str = BASELINE_PATH,
              metrics=None) -> int:
    """Re-run the key benchmarks and compare against the committed
    baseline artifact; returns the number of metrics that regressed by
    more than ``threshold`` (so 0 == pass).  A metric missing from the
    baseline (e.g. first run after adding it) is reported but not failed.
    """
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    wanted = dict(KEY_METRICS if metrics is None else
                  {m: KEY_METRICS[m] for m in metrics})

    def run_bench(bench) -> None:
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            emit(f"FAIL_{bench.__name__}", -1.0, repr(e)[:80])

    def best(metric: str):
        # min across (re)runs: the noise-robust latency estimator
        vals = [us for name, us, _ in ROWS if name == metric and us >= 0]
        return min(vals) if vals else None

    for bench in dict.fromkeys(wanted.values()):
        run_bench(bench)
    failures = 0
    for metric, bench in wanted.items():
        if metric not in baseline:
            print(f"# CHECK SKIP {metric}: not in baseline "
                  f"({os.path.basename(baseline_path)})")
            continue
        base_us = baseline[metric]["us_per_call"]

        def ratio():
            new_us = best(metric)
            if new_us is None or base_us <= 0:
                return None
            return new_us / base_us

        r = ratio()
        retries = 2
        while r is not None and r > 1.0 + threshold and retries:
            retries -= 1  # looks like a regression: re-measure before failing
            run_bench(bench)
            r = ratio()
        if r is None:
            print(f"# CHECK FAIL {metric}: benchmark did not produce it")
            failures += 1
            continue
        status = "FAIL" if r > 1.0 + threshold else "ok"
        print(f"# CHECK {status} {metric}: {best(metric):.1f}us vs "
              f"baseline {base_us:.1f}us ({r:.2f}x)")
        if r > 1.0 + threshold:
            failures += 1
    return failures


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--json", default=None,
                    help="path for the BENCH_*.json artifact ('' disables; "
                         "default: BENCH_latest.json for full runs, disabled "
                         "for --only runs so a filtered subset never "
                         "clobbers the tracked artifact)")
    ap.add_argument("--check", action="store_true",
                    help="re-run the key metrics (b1, b2-fast, b8, b9, b12, "
                         "b13) "
                         "and exit non-zero if any regressed >25%% vs the "
                         "committed BENCH_latest.json")
    ap.add_argument("--check-threshold", type=float, default=0.25,
                    help="allowed relative regression for --check")
    args = ap.parse_args(argv)
    if args.check:
        print("name,us_per_call,derived")
        failures = run_check(threshold=args.check_threshold)
        sys.exit(1 if failures else 0)
    if args.json is None:
        args.json = "" if args.only else os.path.join(
            os.path.dirname(__file__), "BENCH_latest.json")
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            emit(f"FAIL_{bench.__name__}", -1.0, repr(e)[:80])
    failed = [name for name, _us, _d in ROWS if name.startswith("FAIL_")]
    if args.json and failed:
        print(f"# not writing {args.json}: {len(failed)} benchmark(s) failed "
              f"({', '.join(failed)}) — keeping the last good artifact", flush=True)
    elif args.json:
        write_json(args.json)




def bench_continuous_batching():
    """Serving layer: occupancy + throughput with continuous slot refill."""
    import jax
    from repro.configs import get_config
    from repro.models.api import Model
    from repro.serving import ContinuousBatcher, Request

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batcher = ContinuousBatcher(model, params, n_slots=4, max_seq=64)
    rs = np.random.RandomState(0)
    n_req = 12
    for i in range(n_req):
        batcher.submit(Request(rid=i, prompt=list(rs.randint(0, 64, (4,))),
                               max_new_tokens=8))
    t0 = time.time()
    results = batcher.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.tokens) + r.prompt_len for r in results.values())
    emit("b11_continuous_batching", dt / max(batcher.stats['steps'], 1) * 1e6,
         f"{toks / dt:.0f}tok/s,occupancy={batcher.occupancy():.2f},"
         f"reqs={len(results)}")


BENCHES.append(bench_continuous_batching)


def bench_trace_overhead():
    """§16 distributed EEG: steps/s with tracing off vs on.

    The off row is the headline — SessionOptions(trace_dir=None) must be
    indistinguishable from pre-§16 builds, because every instrumentation
    site reduces to one ``is None`` check.  The bench also asserts the
    structural half of that claim: an untraced run records zero events
    into any recorder (no buffer even exists to fill)."""
    from repro.core import GraphBuilder, Session
    from repro.core.options import SessionOptions
    from repro.obs import spans as spans_mod

    def build():
        b = GraphBuilder()
        x = b.constant(jnp.ones((8, 8)), name="x")
        cur = x
        for i in range(64):
            cur = b.add(cur, x, name=f"a{i}")
        return b, cur

    spans_mod.install(None)
    b_off, cur_off = build()
    sess_off = Session(b_off.graph)
    b_on, cur_on = build()
    sess_on = Session(b_on.graph, options=SessionOptions(trace_dir="/tmp/b15"))
    # warm BOTH before timing either: the second session to compile the
    # (identical) fused region hits jax's compile cache, and timing it
    # cold-vs-warm would swamp the instrumentation cost being measured
    for _ in range(3):
        sess_off.run(cur_off.ref)
        sess_on.run(cur_on.ref)

    us_off = _timeit(lambda: sess_off.run(cur_off.ref))
    assert sess_off._spans is None and spans_mod.get() is sess_on._spans, \
        "trace-off session must not own a span recorder"
    us_on = _timeit(lambda: sess_on.run(cur_on.ref))
    n_events = len(sess_on._spans)
    spans_mod.install(None)
    sess_off.close()
    sess_on.close()
    assert n_events > 0, "traced run recorded nothing"

    emit("b15_trace_off", us_off, f"traced={us_on:.2f}us,"
         f"overhead={us_on / us_off - 1.0:+.1%},events={n_events}")


BENCHES.append(bench_trace_overhead)


if __name__ == "__main__":
    main()
