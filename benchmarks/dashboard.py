"""Benchmark trendline dashboard from BENCH_history.jsonl.

Every full ``run.py`` sweep appends one line to BENCH_history.jsonl
(timestamp + git rev + all metrics), so perf is a time series across
PRs/CI runs.  This tool renders that series as:

* a markdown table — latest value, delta vs the previous run, delta vs
  the first recorded run, run count — with a unicode sparkline per
  benchmark (renders anywhere markdown does, including the GitHub
  Actions job summary);
* an inline-SVG sparkline per benchmark in an HTML artifact (real
  vector trendlines for local viewing / artifact download — GitHub's
  markdown sanitizer strips inline ``<svg>``, hence the split).

CI appends the markdown to ``$GITHUB_STEP_SUMMARY`` and uploads both
renderings as artifacts (see .github/workflows/ci.yml).

  python benchmarks/dashboard.py [--history PATH] [--md PATH]
                                 [--html PATH] [--stdout]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))
HISTORY = os.path.join(HERE, "BENCH_history.jsonl")

SPARK_CHARS = "▁▂▃▄▅▆▇█"


def load_history(path: str) -> List[dict]:
    entries = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # a truncated line (killed run) must not hide the rest
    return entries


def series_of(entries: List[dict]) -> Dict[str, List[Tuple[str, str, float]]]:
    """metric -> [(ts, rev, us_per_call), ...] in history order, negative
    sentinel values (failed runs) dropped."""
    out: Dict[str, List[Tuple[str, str, float]]] = {}
    for e in entries:
        ts = e.get("ts", "?")
        rev = e.get("rev", "?")
        for name, m in e.get("metrics", {}).items():
            us = m.get("us_per_call")
            if isinstance(us, (int, float)) and us >= 0:
                out.setdefault(name, []).append((ts, rev, float(us)))
    return out


def _norm(vals: List[float]) -> List[float]:
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return [0.5] * len(vals)
    return [(v - lo) / (hi - lo) for v in vals]


def sparkline(vals: List[float], width: int = 24) -> str:
    """Unicode sparkline (down = faster, since values are latencies)."""
    if len(vals) > width:  # keep the most recent window
        vals = vals[-width:]
    if not vals:
        return ""
    return "".join(SPARK_CHARS[int(round(x * (len(SPARK_CHARS) - 1)))]
                   for x in _norm(vals))


def svg_sparkline(vals: List[float], width: int = 160, height: int = 36,
                  pad: int = 3) -> str:
    """Inline SVG trendline: polyline over history order, latest point
    marked; lower is better so the reference band is the series min."""
    if len(vals) < 2:
        vals = vals * 2
    norm = _norm(vals)
    n = len(norm)
    xs = [pad + i * (width - 2 * pad) / (n - 1) for i in range(n)]
    ys = [height - pad - v * (height - 2 * pad) for v in norm]
    pts = " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(xs, ys))
    return (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" '
        f'height="{height}" viewBox="0 0 {width} {height}" '
        f'role="img" aria-label="trend">'
        f'<polyline points="{pts}" fill="none" stroke="#4078c0" '
        f'stroke-width="1.5"/>'
        f'<circle cx="{xs[-1]:.1f}" cy="{ys[-1]:.1f}" r="2.5" '
        f'fill="#d73a49"/></svg>')


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.1f}us"


def _fmt_delta(new: float, old: float) -> str:
    if old <= 0:
        return "—"
    pct = (new - old) / old * 100.0
    arrow = "🔺" if pct > 2 else ("🔻" if pct < -2 else "·")
    return f"{arrow}{pct:+.1f}%"


def to_markdown(series: Dict[str, List[Tuple[str, str, float]]],
                entries: List[dict]) -> str:
    lines = [
        "# Benchmark trend dashboard",
        "",
        f"{len(entries)} recorded run(s); latest: "
        f"`{entries[-1].get('rev', '?')}` at {entries[-1].get('ts', '?')}. "
        "Values are µs/call — **lower is better**; sparklines read "
        "oldest→newest.",
        "",
        "| benchmark | latest | vs prev | vs first | runs | trend |",
        "|---|---|---|---|---|---|",
    ]
    for name in sorted(series):
        pts = series[name]
        vals = [v for _, _, v in pts]
        latest = vals[-1]
        prev = _fmt_delta(latest, vals[-2]) if len(vals) > 1 else "—"
        first = _fmt_delta(latest, vals[0]) if len(vals) > 1 else "—"
        lines.append(
            f"| {name} | {_fmt_us(latest)} | {prev} | {first} |"
            f" {len(vals)} | `{sparkline(vals)}` |")
    return "\n".join(lines) + "\n"


def to_html(series: Dict[str, List[Tuple[str, str, float]]],
            entries: List[dict]) -> str:
    rows = []
    for name in sorted(series):
        pts = series[name]
        vals = [v for _, _, v in pts]
        latest = vals[-1]
        prev = _fmt_delta(latest, vals[-2]) if len(vals) > 1 else "—"
        rows.append(
            f"<tr><td><code>{name}</code></td><td>{_fmt_us(latest)}</td>"
            f"<td>{prev}</td><td>{len(vals)}</td>"
            f"<td>{svg_sparkline(vals)}</td></tr>")
    return (
        "<!doctype html><meta charset='utf-8'>"
        "<title>Benchmark trend dashboard</title>"
        "<style>body{font:14px system-ui;margin:2em}"
        "table{border-collapse:collapse}td,th{border:1px solid #ddd;"
        "padding:4px 10px;text-align:left}</style>"
        f"<h1>Benchmark trend dashboard</h1>"
        f"<p>{len(entries)} recorded run(s); latest "
        f"<code>{entries[-1].get('rev', '?')}</code> at "
        f"{entries[-1].get('ts', '?')}. Lower is better.</p>"
        "<table><tr><th>benchmark</th><th>latest</th><th>vs prev</th>"
        "<th>runs</th><th>trend</th></tr>"
        + "".join(rows) + "</table>")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--history", default=HISTORY)
    ap.add_argument("--md", default=os.path.join(HERE, "BENCH_dashboard.md"),
                    help="markdown output path ('' disables)")
    ap.add_argument("--html", default=os.path.join(HERE, "BENCH_dashboard.html"),
                    help="HTML (inline-SVG) output path ('' disables)")
    ap.add_argument("--stdout", action="store_true",
                    help="also print the markdown to stdout (CI pipes this "
                         "into $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    if not os.path.exists(args.history):
        print(f"no history at {args.history}; nothing to render",
              file=sys.stderr)
        return 1
    entries = load_history(args.history)
    if not entries:
        print(f"history {args.history} is empty; nothing to render",
              file=sys.stderr)
        return 1
    series = series_of(entries)
    md = to_markdown(series, entries)
    if args.md:
        with open(args.md, "w") as fh:
            fh.write(md)
        print(f"# wrote {args.md}", file=sys.stderr)
    if args.html:
        with open(args.html, "w") as fh:
            fh.write(to_html(series, entries))
        print(f"# wrote {args.html}", file=sys.stderr)
    if args.stdout:
        print(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
