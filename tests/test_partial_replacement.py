"""§13 partial re-placement recovery + seeded fault-plan chaos tests.

The acceptance contract: killing one worker (really, or via an injected
``kill`` rule) recovers by re-placing ONLY the dead task's subgraph onto
a standby or a survivor — survivors' live Variable state is bit-preserved
against a pre-kill snapshot, only the dead task's Variables restore from
the checkpoint, and post-recovery training bit-matches an uninterrupted
run.  Same-seed FaultPlans replay identically (failure point AND
recovered final state), and the whole-pool restart stays the fallback
when nothing can host.

Every test here is marked ``chaos``: the CI chaos job runs exactly this
set under hard timeouts (``pytest -m chaos``); the tests also run in the
default tier-1 selection because they are fully deterministic.  Tests
print their plan as ``[chaos] REPRO_FAULTS=<spec>`` so a red CI run's
job summary carries the exact replay recipe.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, TensorRef, cond, while_loop
from repro.core.executor import ExecutorError
from repro.distrib import (RecoveryError, start_worker_processes,
                           stop_worker_processes)
from repro.distrib.protocol import Channel, WorkerError
from repro.launch.steps import build_wire_train_step
from repro.runtime.devices import DeviceSet

pytestmark = pytest.mark.chaos

T0, T1 = "/job:worker/task:0", "/job:worker/task:1"
TASKS = [T0, T1]


def _batch(i, n=32):
    rs = np.random.RandomState(1000 + i)
    return (jnp.asarray(rs.randn(n, 16).astype("f")),
            jnp.asarray(rs.randint(0, 8, (n,)).astype("i")))


def _ref_vars(seed, steps):
    """Uninterrupted in-process reference: final Variable state."""
    ws = build_wire_train_step(TASKS, seed=seed)
    sess = Session(ws.builder.graph,
                   devices=DeviceSet.make_cluster(2, 1, kind="cpu"))
    run = sess.make_callable([ws.loss, ws.train_op], [ws.feed_x, ws.feed_y])
    for i in range(steps):
        run(*_batch(i))
    out = {n: np.asarray(sess.variable_value(n)) for n in ws.var_names}
    sess.close()
    return out


def _expect_dead(run, i, *, timeout=30.0):
    """Drive the step until the lost worker surfaces as an ExecutorError
    (the first post-kill run may race the detection)."""
    with pytest.raises(ExecutorError) as ei:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            run(*_batch(i))
    return ei.value


def test_partial_replacement_onto_standby_keeps_survivor_live_state():
    ref = _ref_vars(seed=7, steps=6)
    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    sprocs, sspec = start_worker_processes(1, first_task=2,
                                           rendezvous_timeout=10.0)
    sess = None
    try:
        ws = build_wire_train_step(TASKS, seed=7)
        sess = Session(ws.builder.graph, cluster=spec)
        run = sess.make_callable([ws.loss, ws.train_op],
                                 [ws.feed_x, ws.feed_y])
        ckpts = {}
        for i in range(3):
            run(*_batch(i))
            ckpts[i + 1] = {k: np.asarray(v)
                            for k, v in sess.pull_cluster_variables().items()}
        procs[1].kill()  # task 1 owns w2; task 0 (owns w1) survives
        time.sleep(0.2)
        err = _expect_dead(run, 3)
        assert "task:1" in str(err)

        # poison the session store's copy of the SURVIVOR's Variable: if
        # recovery wrongly re-registered or pushed task 0, training below
        # would diverge and the worker-side probe would read garbage
        sess.set_variable("w1", np.full_like(ckpts[3]["w1"], 1e9))

        report = sess.recover_dead_tasks(ckpts[3],
                                         standby=[sspec.workers[0]])
        print(report.describe())
        assert report.mode == "partial"
        assert sorted(report.dead) == [1]
        assert report.survivors == (0,)
        assert report.replacements == {1: sspec.workers[0]}
        assert report.kept_live == ("w1",)
        assert report.restored == ("w2",)

        # the survivor's live state is bit-preserved vs the pre-kill
        # snapshot — read worker-side, bypassing the poisoned store
        rep = sess.master.channels[0].call(
            "get_variables", namespace=sess.wire_namespace, names=["w1"])
        np.testing.assert_array_equal(np.asarray(rep["values"]["w1"]),
                                      ckpts[3]["w1"])

        misses = sess.cache_stats["misses"]
        for i in range(3, 6):
            run(*_batch(i))
        # endpoint swap kept the shape-only fingerprint: no rebuild
        assert sess.cache_stats["misses"] == misses
        final = {k: np.asarray(v)
                 for k, v in sess.pull_cluster_variables().items()}
        for name in ws.var_names:
            np.testing.assert_array_equal(final[name], ref[name])
    finally:
        if sess is not None:
            sess.close()
        stop_worker_processes(procs, spec)
        stop_worker_processes(sprocs, sspec)


def test_partial_replacement_onto_survivor_hosts_two_tasks():
    """No standby: the dead task's subgraph lands on the survivor's
    process, which then serves BOTH tasks of the plan (registry keyed by
    (handle, task)); peer fetches between the two co-hosted tasks resolve
    through the shared mailbox, not loopback RPCs."""
    ref = _ref_vars(seed=9, steps=5)
    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    sess = None
    try:
        ws = build_wire_train_step(TASKS, seed=9)
        sess = Session(ws.builder.graph, cluster=spec)
        run = sess.make_callable([ws.loss, ws.train_op],
                                 [ws.feed_x, ws.feed_y])
        ckpts = {}
        for i in range(2):
            run(*_batch(i))
            ckpts[i + 1] = {k: np.asarray(v)
                            for k, v in sess.pull_cluster_variables().items()}
        procs[1].kill()
        time.sleep(0.2)
        _expect_dead(run, 2)
        report = sess.recover_dead_tasks(ckpts[2])
        print(report.describe())
        assert report.replacements == {1: spec.workers[0]}
        for i in range(2, 5):
            run(*_batch(i))
        final = {k: np.asarray(v)
                 for k, v in sess.pull_cluster_variables().items()}
        for name in ws.var_names:
            np.testing.assert_array_equal(final[name], ref[name])
        # genuinely dual-task: one process, two registered slots
        st = sess.master.channels[0].call("debug_state")
        assert any(s.endswith("task:0") for s in st["registered"])
        assert any(s.endswith("task:1") for s in st["registered"])
    finally:
        if sess is not None:
            sess.close()
        stop_worker_processes(procs, spec)


def test_whole_pool_fallback_when_nothing_can_host():
    """Both workers dead -> RecoveryError (partial path refuses) -> the
    documented whole-pool recipe still lands bit-exact.  This is the test
    that distinguishes the two recovery paths."""
    ref = _ref_vars(seed=5, steps=4)
    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    procs2 = spec2 = None
    sess = None
    try:
        ws = build_wire_train_step(TASKS, seed=5)
        sess = Session(ws.builder.graph, cluster=spec)
        run = sess.make_callable([ws.loss, ws.train_op],
                                 [ws.feed_x, ws.feed_y])
        ckpts = {}
        for i in range(2):
            run(*_batch(i))
            ckpts[i + 1] = {k: np.asarray(v)
                            for k, v in sess.pull_cluster_variables().items()}
        for p in procs:
            p.kill()
        time.sleep(0.2)
        _expect_dead(run, 2)
        deadline = time.monotonic() + 30  # monitor must condemn BOTH
        while len(sess.master.dead) < 2 and time.monotonic() < deadline:
            time.sleep(0.1)
        assert sorted(sess.master.dead) == [0, 1]
        with pytest.raises(RecoveryError, match="whole-pool"):
            sess.recover_dead_tasks(ckpts[2])

        procs2, spec2 = start_worker_processes(2, rendezvous_timeout=10.0)
        for name, value in ckpts[2].items():
            sess.set_variable(name, value)
        sess.rebind_cluster(spec2)
        for i in range(2, 4):
            run(*_batch(i))
        final = {k: np.asarray(v)
                 for k, v in sess.pull_cluster_variables().items()}
        for name in ws.var_names:
            np.testing.assert_array_equal(final[name], ref[name])
    finally:
        if sess is not None:
            sess.close()
        stop_worker_processes(procs, spec)
        if procs2 is not None:
            stop_worker_processes(procs2, spec2)


def test_injected_kill_replays_identically():
    """Same-seed FaultPlan -> same failure point, same recovered state,
    twice over — and both runs bit-match the uninterrupted reference
    (an injected kill fires on run_graph *receipt*, before any state
    mutates, so recovery loses nothing)."""
    plan_spec = "seed=5;kill:task=1,step=3"
    print(f"[chaos] REPRO_FAULTS={plan_spec}")
    ref = _ref_vars(seed=13, steps=5)
    outcomes = []
    for _ in range(2):
        procs, spec = start_worker_processes(
            2, rendezvous_timeout=10.0,
            extra_env={"REPRO_FAULTS": plan_spec})
        sess = None
        try:
            ws = build_wire_train_step(TASKS, seed=13)
            sess = Session(ws.builder.graph, cluster=spec)
            run = sess.make_callable([ws.loss, ws.train_op],
                                     [ws.feed_x, ws.feed_y])
            ckpts = {0: {}}
            fail_step = None
            i = 0
            while i < 5:
                try:
                    run(*_batch(i))
                except (ExecutorError, WorkerError, OSError):
                    assert fail_step is None, "plan must kill exactly once"
                    fail_step = i
                    report = sess.recover_dead_tasks(ckpts[i])
                    print(report.describe())
                    continue  # retry the aborted step on the replacement
                i += 1
                ckpts[i] = {k: np.asarray(v)
                            for k, v in sess.pull_cluster_variables().items()}
            outcomes.append((fail_step, ckpts[5]))
        finally:
            if sess is not None:
                sess.close()
            stop_worker_processes(procs, spec)
    (s1, f1), (s2, f2) = outcomes
    assert s1 == s2 == 2  # the 3rd run_graph on task 1, every replay
    for name in ("w1", "w2"):
        np.testing.assert_array_equal(f1[name], f2[name])
        np.testing.assert_array_equal(f1[name], ref[name])


# ---------------------------------------------------------------------------
# rendezvous hygiene across control flow


def _loop_graph(limit):
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0", device=T0)
    acc0 = b.constant(jnp.array(0.0), name="acc0", device=T0)
    lim = b.constant(jnp.array(limit), name="lim")
    one = b.constant(jnp.array(1), name="one")
    outs = while_loop(
        b, lambda i, a: b.less(i, lim),
        lambda i, a: [b.add(i, one, name="inc", device=T1),
                      b.add(a, b.mul(b.cast(i, "float32"),
                                     b.cast(i, "float32"), name="sq",
                                     device=T1),
                            name="acc", device=T0)],
        [i0, acc0])
    return b, outs


def test_rendezvous_hygiene_after_injected_midrun_kill():
    """A cross-process loop, a zero-iteration loop, then a cond whose
    remote branch is killed mid-iteration: after abort + recovery the
    survivor must hold NO leaked rendezvous state — empty mailbox, no
    active executions, no straggler fetcher threads."""
    plan_spec = "seed=3;kill:task=1,step=3"
    print(f"[chaos] REPRO_FAULTS={plan_spec}")
    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0,
                                         extra_env={"REPRO_FAULTS": plan_spec})
    sessions = []
    try:
        # run_graph receipts 1+2 on task 1: both loops complete cleanly
        b5, outs5 = _loop_graph(5)
        s5 = Session(b5.graph, cluster=spec)
        sessions.append(s5)
        r5 = s5.run(outs5)
        assert int(r5[0]) == 5
        b0, outs0 = _loop_graph(0)
        s0 = Session(b0.graph, cluster=spec)
        sessions.append(s0)
        r0 = s0.run(outs0)
        assert int(r0[0]) == 0 and float(r0[1]) == 0.0

        # receipt 3: task 1 dies holding the cond's remote branch — the
        # survivor blocks on the wire mid-iteration until the §13 abort
        # purges the execution
        b = GraphBuilder()
        p = b.placeholder("p")
        x = b.constant(jnp.array(3.0), name="x", device=T0)
        res = cond(b, p,
                   lambda t: [b.mul(t, t, name="tb", device=T1)],
                   lambda f: [b.neg(f, name="fb", device=T0)], [x])
        sc = Session(b.graph, cluster=spec)
        sessions.append(sc)
        with pytest.raises(ExecutorError):
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                sc.run(res, {TensorRef("p", 0): jnp.array(True)})

        # variable-free partial recovery: survivor hosts the dead task
        report = sc.recover_dead_tasks()
        print(report.describe())
        assert report.restored == () and report.kept_live == ()
        out = sc.run(res, {TensorRef("p", 0): jnp.array(True)})
        assert float(out[0]) == 9.0
        out = sc.run(res, {TensorRef("p", 0): jnp.array(False)})
        assert float(out[0]) == -3.0

        # hygiene probe: poll until the async cleanups land, then demand
        # a spotless survivor process
        ch = Channel(*spec.host_port(0))
        try:
            deadline = time.monotonic() + 20
            while True:
                st = ch.call("debug_state")
                if (not st["pending_keys"] and not st["active_executions"]
                        and st["fetch_threads"] == 0):
                    break
                if time.monotonic() > deadline:
                    raise AssertionError(f"leaked rendezvous state: {st}")
                time.sleep(0.2)
        finally:
            ch.close()
    finally:
        for s in sessions:
            s.close()
        stop_worker_processes(procs, spec)


# ---------------------------------------------------------------------------
# §13 distributed parity guard (satellite of the §9 guard)


def test_distributed_parity_guard_preserves_training_trajectory():
    """parity_guard over a cluster session rides get/set_variables: the
    strict wire reference runs first, worker state rewinds, then the fast
    plan runs.  If the snapshot/restore is faithful, N guarded steps
    (first run + every 2nd sampled) bit-match an unguarded fast session."""
    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    try:
        ws = build_wire_train_step(TASKS, seed=21)
        guarded = Session(ws.builder.graph, cluster=spec, numerics="fast",
                          parity_guard="sample:2")
        grun = guarded.make_callable([ws.loss, ws.train_op],
                                     [ws.feed_x, ws.feed_y])
        glosses = [np.asarray(grun(*_batch(i))[0]) for i in range(4)]
        # the guard genuinely built its strict companion: the fresh pool
        # now holds TWO registered handles (fast + strict) on both tasks
        st = guarded.master.channels[0].call("debug_state")
        assert len(st["registered"]) == 2
        gvars = {k: np.asarray(v)
                 for k, v in guarded.pull_cluster_variables().items()}
        guarded.close()

        ws2 = build_wire_train_step(TASKS, seed=21)
        plain = Session(ws2.builder.graph, cluster=spec, numerics="fast",
                        parity_guard=False)
        prun = plain.make_callable([ws2.loss, ws2.train_op],
                                   [ws2.feed_x, ws2.feed_y])
        plosses = [np.asarray(prun(*_batch(i))[0]) for i in range(4)]
        pvars = {k: np.asarray(v)
                 for k, v in plain.pull_cluster_variables().items()}
        plain.close()

        np.testing.assert_array_equal(np.asarray(glosses),
                                      np.asarray(plosses))
        for name in ws.var_names:
            np.testing.assert_array_equal(gvars[name], pvars[name])
    finally:
        stop_worker_processes(procs, spec)
