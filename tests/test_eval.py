"""Evaluation substrate: training moves perplexity/accuracy the right way."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMDataset, batch_iterator
from repro.eval import perplexity_eval, token_accuracy
from repro.launch.steps import build_step
from repro.models.api import Model
from repro.models.params import init_params
from repro.optim import adamw_init


def test_perplexity_drops_with_training():
    cfg = get_config("smollm-360m", smoke=True)
    model = Model.for_config(cfg)
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len=32, seed=0)

    sb = build_step(cfg, "train_4k",
                    hparam_overrides={"compute_dtype": jnp.float32}, lr=2e-3)
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    variables = {"params": params, "opt": adamw_init(params)}

    before = perplexity_eval(model, variables["params"],
                             batch_iterator(ds, 4, 1000), max_batches=4)
    assert 0.5 * cfg.vocab_size < before["perplexity"] < 2 * cfg.vocab_size

    step = jax.jit(sb.fn)
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(8, i).items()}
        _, variables = step(batch, variables)

    after = perplexity_eval(model, variables["params"],
                            batch_iterator(ds, 4, 1000), max_batches=4)
    assert after["perplexity"] < 0.7 * before["perplexity"]

    acc0 = token_accuracy(model, params, ds.batch(4, 2000))
    acc1 = token_accuracy(model, variables["params"], ds.batch(4, 2000))
    assert acc1 > acc0
