"""DESIGN.md §11 wire serialization: the tensor codec and message framing.

The distributed runtime's correctness contract starts here: every dtype
the graph engine produces must round-trip the wire bit-faithfully,
DEAD_TENSOR must survive as a first-class marker (§4.4 deadness crosses
process boundaries), and the §5.5 compress16 uint16 wire format must
decompress to exactly what the in-process path produces.  No sockets or
subprocesses in this module — the end-to-end 2-process paths live in
tests/test_distrib_runtime.py.
"""
import socket
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph, GraphBuilder, TensorRef
from repro.core.compression import compress_f32_to_16, decompress_16_to_f32
from repro.distrib.protocol import (
    Channel, ProtocolError, decode_tensor, encode_tensor, pack_msg,
    read_frame, recv_msg, send_msg, unpack_msg, write_frame,
)
from repro.runtime.rendezvous import DEAD_TENSOR

# every dtype the graph engine produces somewhere: placeholders/Consts
# (float/int/bool), Shape/Rank (int32/int64), comparisons (bool), Cast
# targets, compress16's uint16 wire format, bf16/f16 compute dtypes
WIRE_DTYPES = [
    "float32", "float64", "float16", "bfloat16",
    "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
    "bool", "complex64",
]


def _sample(dtype: str, shape=(3, 5)) -> np.ndarray:
    rs = np.random.RandomState(hash(dtype) % (2**31))
    if dtype == "bool":
        return rs.rand(*shape) > 0.5
    if dtype == "complex64":
        return (rs.randn(*shape) + 1j * rs.randn(*shape)).astype(dtype)
    if dtype.startswith(("int", "uint")):
        return rs.randint(0, 100, shape).astype(dtype)
    if dtype == "bfloat16":
        import ml_dtypes

        return rs.randn(*shape).astype(ml_dtypes.bfloat16)
    return rs.randn(*shape).astype(dtype)


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
def test_every_engine_dtype_roundtrips_bitwise(dtype):
    arr = _sample(dtype)
    out = decode_tensor(encode_tensor(arr))
    got = np.asarray(out)
    assert got.dtype == arr.dtype
    assert got.shape == arr.shape
    # bit-level comparison, not allclose: the codec is a buffer copy
    np.testing.assert_array_equal(got.view(np.uint8), arr.view(np.uint8))


@pytest.mark.parametrize("shape", [(), (0,), (1,), (2, 0, 3), (4, 1, 2)])
def test_shapes_including_scalar_and_empty(shape):
    arr = np.asarray(np.random.RandomState(0).randn(*shape), dtype="f")
    out = np.asarray(decode_tensor(encode_tensor(arr)))
    assert out.shape == shape and out.dtype == arr.dtype
    np.testing.assert_array_equal(out, arr)


def test_fortran_order_input_roundtrips():
    arr = np.asfortranarray(np.random.RandomState(1).randn(4, 6).astype("f"))
    np.testing.assert_array_equal(np.asarray(decode_tensor(encode_tensor(arr))), arr)


def test_jax_array_roundtrips_bitwise():
    x = jnp.linspace(-1.0, 1.0, 17, dtype=jnp.float32)
    out = decode_tensor(encode_tensor(x))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_dead_tensor_is_a_first_class_wire_marker():
    assert decode_tensor(encode_tensor(DEAD_TENSOR)) is DEAD_TENSOR
    # ...and survives arbitrarily deep inside a message structure
    msg = unpack_msg(pack_msg({"kind": "run", "vals": [1, DEAD_TENSOR,
                                                       {"x": DEAD_TENSOR}]}))
    assert msg["vals"][1] is DEAD_TENSOR
    assert msg["vals"][2]["x"] is DEAD_TENSOR


def test_compress16_wire_format_matches_in_process_roundtrip():
    """A compressed edge sends uint16; the receiving process must
    decompress to exactly the in-process result (§5.5)."""
    x = jnp.asarray(np.random.RandomState(2).randn(8, 8).astype("f"))
    wire_u16 = compress_f32_to_16(x)
    arrived = decode_tensor(encode_tensor(wire_u16))
    assert np.asarray(arrived).dtype == np.uint16
    np.testing.assert_array_equal(
        np.asarray(decompress_16_to_f32(arrived)),
        np.asarray(decompress_16_to_f32(wire_u16)))


def test_message_with_tensors_roundtrips():
    feeds = {TensorRef("x", 0): jnp.ones((2, 3), jnp.float32),
             TensorRef("y", 1): np.int32(7)}
    msg = unpack_msg(pack_msg({"kind": "run_graph", "feeds": feeds, "timeout": 5.0}))
    assert msg["kind"] == "run_graph"
    assert set(msg["feeds"]) == set(feeds)
    np.testing.assert_array_equal(np.asarray(msg["feeds"][TensorRef("x", 0)]),
                                  np.ones((2, 3), np.float32))


def test_graph_slice_ships_with_const_values_bitwise():
    b = GraphBuilder()
    v = np.random.RandomState(3).randn(4, 4).astype("f")
    c = b.constant(jnp.asarray(v), name="c")
    b.reduce_sum(c, name="s")
    g2 = unpack_msg(pack_msg({"graph": b.graph}))["graph"]
    assert isinstance(g2, Graph)
    assert set(g2.nodes) == set(b.graph.nodes)
    np.testing.assert_array_equal(np.asarray(g2.nodes["c"].attrs["value"]), v)


def test_gradient_graphs_ship(tmp_path):
    """§4.1 autodiff Call nodes use picklable _GradFn kernels, so a
    primitive-op train graph (forward+backward+updates) crosses the wire."""
    from repro.launch.steps import build_wire_train_step

    ws = build_wire_train_step(["/job:worker/task:0", "/job:worker/task:1"])
    g2 = unpack_msg(pack_msg({"graph": ws.builder.graph}))["graph"]
    assert any(n.startswith("grad/") for n in g2.nodes)
    fn = g2.nodes["grad/mm1"].attrs["fn"]
    # the reconstructed kernel is callable and produces the right arity
    a = jnp.ones((2, 3)); w = jnp.ones((3, 4))
    outs = fn(a, w, a @ w, jnp.ones((2, 4)))
    assert len(outs) == 2 and outs[0].shape == a.shape


def test_closure_call_rejected_with_clear_error():
    captured = 3.0
    # the rejection must point at the §15 fix: ship a Call factory instead
    with pytest.raises(ProtocolError, match="call_factory.*closures cannot ship"):
        pack_msg({"kind": "register_graph", "fn": lambda x: x * captured})


def test_frame_roundtrip_over_real_socket():
    a, b = socket.socketpair()
    payload = {"kind": "heartbeat", "blob": np.arange(1000, dtype=np.int64)}

    def server():
        msg = recv_msg(b)
        send_msg(b, {"ok": True, "echo": msg["blob"] * 2})

    t = threading.Thread(target=server)
    t.start()
    send_msg(a, payload)
    reply = recv_msg(a)
    t.join()
    np.testing.assert_array_equal(np.asarray(reply["echo"]),
                                  np.arange(1000, dtype=np.int64) * 2)
    a.close(); b.close()


def test_clean_eof_returns_none_and_midframe_eof_raises():
    a, b = socket.socketpair()
    a.close()
    assert read_frame(b) is None
    b.close()
    a, b = socket.socketpair()
    a.sendall(b"\x00\x00\x01\x00partial")  # announces 256 bytes, sends 7
    a.close()
    with pytest.raises(ProtocolError, match="mid-frame"):
        read_frame(b)
    b.close()


def test_channel_round_trip_and_worker_error():
    from repro.distrib.protocol import WorkerError

    srv = socket.socket()
    srv.bind(("127.0.0.1", 0)); srv.listen(1)
    port = srv.getsockname()[1]

    def server():
        conn, _ = srv.accept()
        while True:
            msg = recv_msg(conn)
            if msg is None:
                return
            if msg["kind"] == "boom":
                send_msg(conn, {"ok": False, "error": "kaboom"})
            else:
                send_msg(conn, {"ok": True, "pong": msg.get("n", 0) + 1})

    t = threading.Thread(target=server, daemon=True)
    t.start()
    ch = Channel("127.0.0.1", port)
    assert ch.call("ping", n=41)["pong"] == 42
    with pytest.raises(WorkerError, match="kaboom"):
        ch.call("boom")
    # the pooled connection survives both calls
    assert ch.call("ping", n=1)["pong"] == 2
    ch.close(); srv.close()
