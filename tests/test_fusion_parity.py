"""Region fusion (§10, DESIGN.md §7): fused and unfused execution must be
bit-identical on fetches and variable state, across representative graphs
— multi-device Send/Recv, while-loops, queues, variable read-modify-write
chains — and fusion must invalidate on Session.extend and honour the
``fuse_regions=False`` escape hatch (PR 1 behavior restored exactly).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, TensorRef, while_loop, cond
from repro.core import fusion
from repro.runtime.devices import DeviceSet
from repro.runtime.queues import FIFOQueue


def _bits(x):
    if x is None:
        return None
    a = np.asarray(x)
    return (a.dtype.str, a.shape, a.tobytes())


def _assert_bit_identical(fused_vals, unfused_vals):
    assert len(fused_vals) == len(unfused_vals)
    for f, u in zip(fused_vals, unfused_vals):
        assert _bits(f) == _bits(u)


def _parity(build, fetches_of, *, feeds_of=None, devices=None, n_runs=3):
    """Run the same graph in a fused and an unfused Session; every fetch
    and every variable must match bit-for-bit after every run."""
    sessions = []
    for fuse in (True, False):
        b = GraphBuilder()
        extra = build(b)
        sess = Session(b.graph, fuse_regions=fuse,
                       devices=devices() if devices else None)
        sessions.append((sess, fetches_of(b, extra), extra))
    (fs, ffetch, fextra), (us, ufetch, uextra) = sessions
    for step in range(n_runs):
        feeds_f = feeds_of(fextra, step) if feeds_of else None
        feeds_u = feeds_of(uextra, step) if feeds_of else None
        fvals = fs.run(ffetch, feeds_f)
        uvals = us.run(ufetch, feeds_u)
        _assert_bit_identical(fvals, uvals)
        fvars = sorted(n for n in fs.graph.nodes
                       if fs.graph.nodes[n].op == "Variable")
        for vn in fvars:
            if fs.variables.has(vn):
                assert _bits(fs.variable_value(vn)) == _bits(us.variable_value(vn))
    return fs, us


def test_single_device_chain_parity_and_one_trace_entry():
    b = GraphBuilder()
    x = b.placeholder("x")
    cur = x
    for i in range(16):
        cur = b.add(b.mul(cur, cur, name=f"m{i}"), x, name=f"a{i}")
    fused = Session(b.graph, fuse_regions=True)
    unfused = Session(b.graph, fuse_regions=False)
    xv = jnp.linspace(0.1, 0.9, 8)
    trace_f, trace_u = [], []
    fv = fused.run(cur.ref, {x.ref: xv}, trace=trace_f)
    uv = unfused.run(cur.ref, {x.ref: xv}, trace=trace_u)
    _assert_bit_identical([fv], [uv])
    # the fused run dispatches ONE super-node; the unfused all 32
    assert len(trace_f) == 1 and trace_f[0].startswith("fused/")
    assert len(trace_u) == 32


def test_multi_device_send_recv_parity():
    def build(b):
        remotes = [b.constant(jnp.full((4, 4), float(i + 1)), name=f"r{i}",
                              device="/job:worker/task:0") for i in range(6)]
        cur = b.placeholder("seed")
        for i, r in enumerate(remotes):
            cur = b.add(b.mul(cur, cur, name=f"m{i}",
                              device="/job:worker/task:1"),
                        r, name=f"u{i}", device="/job:worker/task:1")
        out = b.reduce_sum(cur, name="out", device="/job:worker/task:1")
        return {"seed": b.graph.nodes["seed"], "out": out}

    fs, us = _parity(
        build,
        lambda b, ex: [ex["out"].ref],
        feeds_of=lambda ex, step: {ex["seed"].ref:
                                   jnp.full((4, 4), 1.0 + 0.125 * step)},
        devices=lambda: DeviceSet.make_cluster(2, 1, kind="cpu"))
    # fusion actually engaged on the fused session
    exe = fs.executable([TensorRef("out", 0)],
                        frozenset({TensorRef("seed", 0)}))
    assert exe.fusion is not None and len(exe.fusion.regions) >= 1


def test_while_loop_graph_parity():
    def build(b):
        lim = b.constant(jnp.array(5), name="lim")
        one = b.constant(jnp.array(1), name="one")
        i0 = b.constant(jnp.array(0), name="i0")
        acc0 = b.placeholder("acc0")
        outs = while_loop(
            b, lambda i, a: b.less(i, lim),
            lambda i, a: [b.add(i, one), b.add(a, b.cast(i, "float32"))],
            [i0, acc0])
        return {"outs": outs, "acc0": b.graph.nodes["acc0"]}

    _parity(build, lambda b, ex: list(ex["outs"]),
            feeds_of=lambda ex, step: {ex["acc0"].ref: jnp.array(0.5 * step)})


def test_cond_graph_parity_both_branches():
    def build(b):
        p = b.placeholder("p")
        x = b.placeholder("x")
        pre = b.mul(x, x, name="pre")
        res = cond(b, p, lambda t: [b.add(t, t)], lambda f: [b.neg(f)], [pre])
        post = b.add(res[0], pre, name="post")
        return {"p": p, "x": x, "post": post}

    for pred in (True, False):
        _parity(build, lambda b, ex: [ex["post"].ref],
                feeds_of=lambda ex, step, pred=pred: {
                    ex["p"].ref: jnp.array(pred),
                    ex["x"].ref: jnp.array(2.0 + step)},
                n_runs=2)


def test_queue_ops_parity():
    def build(b):
        x = b.placeholder("x")
        sq = b.square(x, name="sq")
        enq = b.graph.add_node("QueueEnqueue", [sq], name="enq",
                               attrs={"queue": "q"})
        deq = b.graph.add_node("QueueDequeue", [], name="deq",
                               attrs={"queue": "q", "n_components": 1},
                               control_inputs=[enq])
        out = b.reduce_sum(b.mul(deq, deq, name="dsq"), name="out")
        return {"x": x, "out": out}

    sessions = []
    for fuse in (True, False):
        b = GraphBuilder()
        ex = build(b)
        sess = Session(b.graph, fuse_regions=fuse)
        sess.register_queue("q", FIFOQueue(capacity=4, timeout=5.0))
        sessions.append((sess, ex))
    for step in range(3):
        xv = jnp.full((3,), 1.0 + step)
        (fs, fex), (us, uex) = sessions
        fv = fs.run(fex["out"].ref, {fex["x"].ref: xv})
        uv = us.run(uex["out"].ref, {uex["x"].ref: xv})
        _assert_bit_identical([fv], [uv])


def test_variable_read_modify_write_chain_parity():
    def build(b):
        v = b.variable("v", init_value=lambda: jnp.array(1.0))
        w = b.variable("w", init_value=lambda: jnp.full((2,), 2.0))
        a1 = b.assign_add(v, b.constant(jnp.array(0.5), name="half"))
        # second write depends on the first through a control edge and on
        # a computed value through a data edge
        delta = b.mul(a1, b.constant(jnp.array(3.0), name="three"), name="delta")
        a2 = b.graph.add_node("AssignAdd", [v, delta], name="a2",
                              control_inputs=[a1.name])
        wupd = b.assign(w, b.add(w, b.reshape(a2, (1,)), name="wnew"))
        step_op = b.group([a2, wupd], name="step")
        return {"step": step_op, "a2": a2}

    _parity(build, lambda b, ex: [ex["step"].ref, ex["a2"].ref], n_runs=4)


def test_gradient_train_step_parity():
    """A realistic optimizer graph: gradients + assigns, run repeatedly."""
    from repro.optim import attach_train_op

    def build(b):
        W = b.variable("W", init_value=lambda: jnp.full((3, 1), 0.1))
        x = b.placeholder("x")
        y = b.placeholder("y")
        loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
        op = attach_train_op(b, loss, [W], optimizer="sgd", lr=0.05)
        return {"x": x, "y": y, "loss": loss, "op": op}

    rs = np.random.RandomState(0)
    X = jnp.array(rs.randn(8, 3).astype("f"))
    Y = jnp.array(rs.randn(8, 1).astype("f"))
    _parity(build, lambda b, ex: [ex["loss"].ref, ex["op"].ref],
            feeds_of=lambda ex, step: {ex["x"].ref: X, ex["y"].ref: Y},
            n_runs=4)


def test_fusion_invalidated_by_extend():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.add(b.mul(x, x, name="m"), x, name="y")
    sess = Session(b.graph, fuse_regions=True)
    assert float(sess.run(y.ref, {x.ref: jnp.array(2.0)})) == 6.0
    exe1 = sess.executable([y.ref], frozenset({x.ref}))

    other = GraphBuilder()
    c = other.constant(jnp.array(10.0), name="late")
    sess.extend(other.graph)
    z = sess.graph.add_node("Add", [TensorRef("y", 0), TensorRef("late", 0)],
                            name="z")
    assert float(sess.run(z.ref, {x.ref: jnp.array(2.0)})) == 16.0
    # the old signature rebuilt too (graph version changed)
    exe2 = sess.executable([y.ref], frozenset({x.ref}))
    assert exe2 is not exe1
    assert exe2.graph_version > exe1.graph_version


def test_escape_hatch_restores_unfused_pipeline():
    b = GraphBuilder()
    x = b.placeholder("x")
    cur = x
    for i in range(4):
        cur = b.add(cur, x, name=f"a{i}")
    sess = Session(b.graph, fuse_regions=False)
    trace = []
    out = sess.run(cur.ref, {x.ref: jnp.ones(2)}, trace=trace)
    np.testing.assert_array_equal(np.asarray(out), np.full((2,), 5.0))
    assert trace == ["a0", "a1", "a2", "a3"]  # PR 1 behavior, node by node
    exe = sess.executable([cur.ref], frozenset({x.ref}))
    assert exe.fusion is None


def test_fusion_planned_once_per_signature():
    b = GraphBuilder()
    x = b.placeholder("x")
    out = b.reduce_sum(b.mul(x, x, name="m"), name="out")
    sess = Session(b.graph)
    before = fusion.STATS["fuse_calls"]
    for v in range(5):
        sess.run(out.ref, {x.ref: jnp.full((2,), float(v))})
    assert fusion.STATS["fuse_calls"] == before + 1  # cached with the Executable
    assert sess.cache_stats["misses"] == 1 and sess.cache_stats["hits"] == 4


def test_written_variables_stay_unfused_and_reads_snapshot():
    """The eager executor reads dep-free Variables in the first ready
    wave, before any assignment; fusion must preserve that snapshot."""
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.array(10.0))
    doubled = b.mul(v, b.constant(jnp.array(2.0), name="two"), name="doubled")
    upd = b.assign_add(v, b.constant(jnp.array(1.0), name="one"))
    fused = Session(b.graph, fuse_regions=True)
    unfused = Session(b.graph, fuse_regions=False)
    for sess in (fused, unfused):
        got = sess.run([doubled.ref, upd.ref])
        assert float(got[0]) == 20.0  # pre-write snapshot
        assert float(sess.variable_value("v")) == 11.0
    _assert_bit_identical(fused.run([doubled.ref, upd.ref]),
                          unfused.run([doubled.ref, upd.ref]))


def test_cse_never_merges_across_devices():
    """Two identical unconstrained Consts whose consumers are pinned to
    different workers: placement puts the twins on different devices, so
    the pre-fusion CSE must NOT merge them — a merge would leave a
    cross-device edge with no Send/Recv pair and the fetch would never
    be produced."""
    b = GraphBuilder()
    c1 = b.constant(3.0, name="c1")
    c2 = b.constant(3.0, name="c2")
    u1 = b.square(c1, name="u1", device="/job:worker/task:0")
    u2 = b.square(c2, name="u2", device="/job:worker/task:1")
    devices = DeviceSet.make_cluster(2, 1, kind="cpu")
    fused = Session(b.graph, devices=devices, fuse_regions=True)
    unfused = Session(b.graph, devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
                      fuse_regions=False)
    fv = fused.run([u1.ref, u2.ref])
    uv = unfused.run([u1.ref, u2.ref])
    _assert_bit_identical(fv, uv)
    assert [float(v) for v in fv] == [9.0, 9.0]


def test_strict_numerics_on_contraction_prone_patterns():
    """mul->add chains (FMA contraction bait) and reductions over fused
    chains must stay bit-identical across many random inputs — the
    numerics="strict" contract (regions compile without cross-op
    reassociation; reductions/dots dispatch eagerly)."""
    b = GraphBuilder()
    x = b.placeholder("x")
    w = b.placeholder("w")
    cur = x
    for i in range(6):
        cur = b.add(b.mul(cur, w, name=f"fm{i}"), x, name=f"fa{i}")
    total = b.reduce_sum(cur, name="total")
    mean = b.reduce_mean(b.square(cur, name="sq"), name="mean")
    fused = Session(b.graph, fuse_regions=True)
    unfused = Session(b.graph, fuse_regions=False)
    rs = np.random.RandomState(7)
    for _ in range(10):
        feeds_v = (jnp.array(rs.randn(33).astype("f")),
                   jnp.array(rs.randn(33).astype("f")))
        fv = fused.run([total.ref, mean.ref],
                       {x.ref: feeds_v[0], w.ref: feeds_v[1]})
        uv = unfused.run([total.ref, mean.ref],
                         {x.ref: feeds_v[0], w.ref: feeds_v[1]})
        _assert_bit_identical(fv, uv)


def test_tracer_on_fused_session_keeps_per_kernel_events():
    from repro.tools import Tracer

    b = GraphBuilder()
    a = b.placeholder("a")
    m = b.matmul(a, a, name="mm")
    out = b.reduce_sum(m, name="out")
    sess = Session(b.graph, fuse_regions=True)
    tr = Tracer()
    sess.run(out.ref, {a.ref: jnp.ones((3, 3))}, tracer=tr)
    ops = {e["op"] for e in tr.events}
    assert "MatMul" in ops and "ReduceSum" in ops


def test_region_jit_cache_evicts_and_recompiles(monkeypatch):
    """DESIGN.md §7: fused regions hold one jitted executable per input
    (shape, dtype) signature in a bounded LRU — a serving workload feeding
    many shapes must not grow memory unboundedly.  Eviction + re-feed of
    an old signature recompiles and still matches the unfused run."""
    monkeypatch.setenv("REPRO_REGION_CACHE", "2")
    b = GraphBuilder()
    x = b.placeholder("x")
    cur = x
    for i in range(4):
        cur = b.add(b.mul(cur, cur, name=f"m{i}"), x, name=f"a{i}")
    fused = Session(b.graph, fuse_regions=True)
    unfused = Session(b.graph, fuse_regions=False)

    def run_shape(n):
        v = jnp.linspace(0.0, 1.0, n)
        return (fused.run(cur.ref, {x.ref: v}),
                unfused.run(cur.ref, {x.ref: v}))

    for n in (3, 5, 7, 9):  # 4 signatures through a cap of 2
        f, u = run_shape(n)
        _assert_bit_identical([f], [u])
    exe = fused.executable([TensorRef(cur.name, 0)],
                           frozenset({TensorRef("x", 0)}))
    region_caches = [s._jit_cache for s in exe.fusion.regions
                     if s._jit_cache is not None]
    assert region_caches, "no fused region built a jit cache"
    assert all(len(c) <= 2 for c in region_caches)
    assert any(c.stats["evictions"] >= 2 for c in region_caches)
    # round-trip: an evicted signature recompiles and stays bit-identical
    before = sum(c.stats["misses"] for c in region_caches)
    f, u = run_shape(3)
    _assert_bit_identical([f], [u])
    after = sum(c.stats["misses"] for c in region_caches)
    assert after > before  # the old signature really was rebuilt
