"""§4.1 gradients-by-graph-extension vs jax.grad (incl. hypothesis DAGs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GraphBuilder, Session, gradients


def test_figure5_gradients_match_jax():
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.arange(12.0).reshape(4, 3) / 10)
    bb = b.variable("b", init_value=lambda: jnp.ones((4, 1)))
    x = b.placeholder("x")
    relu = b.relu(b.add(b.matmul(W, x), bb))
    C = b.reduce_sum(b.square(relu), name="C")
    gW, gb, gx = gradients(b.graph, [C], [W, bb, x])
    sess = Session(b.graph)
    xv = jnp.ones((3, 2)) * 0.5
    got = sess.run([gW, gb, gx], {x.ref: xv})

    def f(Wv, bv, xv):
        return jnp.sum(jax.nn.relu(Wv @ xv + bv) ** 2)

    want = jax.grad(f, argnums=(0, 1, 2))(
        sess.variable_value("W"), sess.variable_value("b"), xv)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5)


def test_gradient_unreachable_is_none():
    b = GraphBuilder()
    a = b.variable("a", init_value=lambda: jnp.array(1.0))
    c = b.variable("c", init_value=lambda: jnp.array(2.0))
    y = b.square(a, name="y")
    (ga, gc) = gradients(b.graph, [y], [a, c])
    assert gc is None and ga is not None


def test_unused_output_port_gets_zero_gradient():
    """§4.1: 'the first input to O's gradient function is set to 0'."""
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.array([1.0, 2.0, 3.0, 4.0]))

    def split2(x):
        return x[:2], x[2:]

    two = b.call(split2, [v], name="split", n_out=2)
    # C depends only on output 1
    C = b.reduce_sum(b.square(two.output(1)), name="C")
    (gv,) = gradients(b.graph, [C], [v])
    got = Session(b.graph).run(gv)
    np.testing.assert_allclose(got, [0.0, 0.0, 6.0, 8.0])


def test_grad_accumulation_fan_out():
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.array(3.0))
    y = b.add(b.square(v), b.mul(v, v), name="y")  # 2 v^2
    (gv,) = gradients(b.graph, [y], [v])
    assert float(Session(b.graph).run(gv)) == pytest.approx(12.0)


_UNARY = ["square", "exp", "tanh", "sigmoid", "relu", "neg"]
_BINARY = ["add", "sub", "mul"]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(_UNARY + _BINARY), min_size=1, max_size=8),
       st.integers(0, 2 ** 31 - 1))
def test_random_dag_gradients_match_jax(opseq, seed):
    """Property: graph autodiff == jax.grad on random op chains/DAGs."""
    rs = np.random.RandomState(seed)
    x0 = jnp.array(rs.randn(4).astype("float32") * 0.3)

    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: x0)
    vals = [v.ref]
    for i, op in enumerate(opseq):
        if op in _UNARY:
            src = vals[rs.randint(len(vals))]
            vals.append(getattr(b, op)(src, name=f"n{i}").ref)
        else:
            s1 = vals[rs.randint(len(vals))]
            s2 = vals[rs.randint(len(vals))]
            vals.append(getattr(b, op)(s1, s2, name=f"n{i}").ref)
    loss = b.reduce_sum(b.square(vals[-1]), name="loss")
    (gv,) = gradients(b.graph, [loss], [v])
    sess = Session(b.graph)
    got_loss, got_g = sess.run([loss.ref, gv])

    # replay functionally
    import jax.numpy as jnp2

    def f(x):
        fvals = [x]
        rs2 = np.random.RandomState(seed)
        _ = rs2.randn(4)  # consume the x0 draw
        fn_map = {"square": jnp2.square, "exp": jnp2.exp, "tanh": jnp2.tanh,
                  "sigmoid": jax.nn.sigmoid, "relu": jax.nn.relu,
                  "neg": jnp2.negative, "add": jnp2.add, "sub": jnp2.subtract,
                  "mul": jnp2.multiply}
        for op in opseq:
            if op in _UNARY:
                src = fvals[rs2.randint(len(fvals))]
                fvals.append(fn_map[op](src))
            else:
                s1 = fvals[rs2.randint(len(fvals))]
                s2 = fvals[rs2.randint(len(fvals))]
                fvals.append(fn_map[op](s1, s2))
        return jnp2.sum(jnp2.square(fvals[-1]))

    want_loss, want_g = jax.value_and_grad(f)(x0)
    np.testing.assert_allclose(got_loss, want_loss, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(got_g, want_g, rtol=2e-4, atol=1e-5)
