"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train step on CPU; output shapes + finiteness (assignment req)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import build_step
from repro.models.api import Model
from repro.models.params import init_params
from repro.optim import adamw_init


def _batch(cfg, B=2, S=32, seed=0):
    rs = np.random.RandomState(seed)
    batch = {
        "tokens": jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.array(
            (rs.randn(B, cfg.enc_seq, cfg.d_model) * 0.1).astype("float32"))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward_logits(params, batch)
    assert logits.shape == (2, 32, model.plan.vocab_pad)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_via_graph(arch):
    """One optimizer step through the full stack: graph -> lowering -> jit."""
    cfg = get_config(arch, smoke=True)
    sb = build_step(cfg, "train_4k",
                    hparam_overrides={"compute_dtype": jnp.float32})
    batch = _batch(cfg)
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(sb.fn)
    loss, newv = step(batch, {"params": params, "opt": opt})
    assert np.isfinite(float(loss))
    assert float(loss) > 0.5 * np.log(cfg.vocab_size)
    # parameters actually moved
    moved = any(
        not np.allclose(a, b) for a, b in zip(
            jax.tree.leaves(params), jax.tree.leaves(newv["params"])))
    assert moved
    assert int(newv["opt"].step) == 1
    # second step decreases loss on the same batch (sanity, not science)
    loss2, _ = step(batch, newv)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = Model.for_config(cfg)
    B, max_seq = 2, 16
    params = model.init(jax.random.PRNGKey(0))
    cache = init_params(model.init_cache_desc(batch=B, max_seq=max_seq),
                        jax.random.PRNGKey(1))
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = model.serve_step(params, cache, toks, jnp.array(0))
    assert logits.shape == (B, 1, model.plan.vocab_pad)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "hymba-1.5b", "qwen2-0.5b"])
def test_decode_matches_forward(arch):
    """Greedy per-position decode logits == teacher-forced forward."""
    cfg = get_config(arch, smoke=True)
    model = Model.for_config(cfg)
    B, S = 2, 12
    params = model.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    tokens = jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    from repro.models import lm

    hid, _ = lm.forward(cfg, model.plan, params, tokens)
    full = lm.logits_from_hidden(cfg, model.plan, params, hid)
    cache = init_params(model.init_cache_desc(batch=B, max_seq=S),
                        jax.random.PRNGKey(1))
    step = jax.jit(lambda c, tk, t: model.serve_step(params, c, tk, t))
    worst = 0.0
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1], jnp.array(t))
        worst = max(worst, float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert worst < 1e-3


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned dimensions."""
    spec = {
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    }
    for arch, (L, D, H, KV, FF, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D, arch
        assert cfg.n_heads == H and cfg.n_kv_heads == KV, arch
        assert cfg.d_ff == FF and cfg.vocab_size == V, arch
        assert cfg.source, arch
    assert get_config("qwen3-moe-30b-a3b").n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").top_k == 8
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("qwen2-moe-a2.7b").top_k == 4
    assert get_config("qwen2-moe-a2.7b").n_shared_experts == 4
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
