"""Layer primitives: attention variants, SSD, conv, MoE, head planner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.models.config import ModelConfig, plan_padding


def _qkv(seed=0, B=2, S=64, KV=4, G=2, Dh=16):
    rs = np.random.RandomState(seed)
    q = jnp.array(rs.randn(B, S, KV, G, Dh).astype("float32"))
    k = jnp.array(rs.randn(B, S, KV, Dh).astype("float32"))
    v = jnp.array(rs.randn(B, S, KV, Dh).astype("float32"))
    return q, k, v


def test_chunked_attention_equals_full():
    q, k, v = _qkv()
    pos = jnp.arange(64)
    full = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True)
    for chunk in (8, 16, 32):
        ch = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True,
                         q_chunk=chunk)
        np.testing.assert_allclose(ch, full, rtol=1e-5, atol=1e-6)


def test_window_attention_sliced_equals_masked():
    q, k, v = _qkv()
    pos = jnp.arange(64)
    w = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True, window=16)
    wc = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True, window=16,
                     q_chunk=16)
    np.testing.assert_allclose(w, wc, rtol=1e-5, atol=1e-6)


def test_indivisible_q_chunk_falls_back():
    q, k, v = _qkv(S=60)
    pos = jnp.arange(60)
    out = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True, q_chunk=16)
    full = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True)
    np.testing.assert_allclose(out, full, rtol=1e-5)


def test_head_mask_zeroes_pad_slots():
    q, k, v = _qkv()
    hm = jnp.array([[1.0], [0.0], [1.0], [0.0]])[:, :, None] * jnp.ones((4, 2, 1))
    hm = jnp.concatenate([jnp.ones((4, 1, 1)), jnp.zeros((4, 1, 1))], axis=1)
    out = L.attention(q, k, v, pos_q=jnp.arange(64), pos_kv=jnp.arange(64),
                      causal=True, head_mask=hm)
    assert float(jnp.abs(out[:, :, :, 1]).max()) == 0.0
    assert float(jnp.abs(out[:, :, :, 0]).max()) > 0.0


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([(14, 2), (40, 8), (15, 5), (25, 5), (20, 20),
                        (96, 8), (32, 4), (16, 16), (64, 8)]),
       st.sampled_from([1, 4, 8, 16]))
def test_head_plan_properties(qkv, shard):
    q0, kv0 = qkv
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=1, d_model=q0 * 64,
                      n_heads=q0, n_kv_heads=kv0, d_ff=16, vocab_size=1000)
    p = plan_padding(cfg, shard)
    assert p.q_pad % shard == 0 and p.kv_pad % shard == 0
    assert p.q_pad == p.kv_pad * p.group
    assert p.q_pad >= q0 and p.kv_pad >= kv0
    # head mask marks exactly q0 live slots
    assert int(p.head_mask().sum()) == q0
    # locality: q slot s attends kv slot s//group which duplicates the
    # ORIGINAL kv parent of the original q head placed at s
    dup = p.kv_dup_index()
    g0 = q0 // kv0
    for i, s in enumerate(p.q_slot_of_orig):
        assert dup[s // p.group] == i // g0


def test_duplicate_kv_preserves_values():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=1, d_model=896,
                      n_heads=14, n_kv_heads=2, d_ff=16, vocab_size=1000)
    p = plan_padding(cfg, 16)
    kv = jnp.array(np.random.RandomState(0).randn(1, 4, 2, 8).astype("f"))
    d = L.duplicate_kv(kv, p)
    assert d.shape == (1, 4, p.kv_pad, 8)
    idx = p.kv_dup_index()
    for slot in range(p.kv_pad):
        np.testing.assert_array_equal(d[:, :, slot], kv[:, :, idx[slot]])


def test_ssd_chunk_invariance_and_initial_state():
    rs = np.random.RandomState(0)
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    x = jnp.array((rs.randn(B, S, H, P) * 0.5).astype("f"))
    dt = jnp.array((rs.rand(B, S, H) * 0.5).astype("f"))
    A_log = jnp.array(rs.rand(H).astype("f"))
    Bc = jnp.array((rs.randn(B, S, G, N) * 0.3).astype("f"))
    Cc = jnp.array((rs.randn(B, S, G, N) * 0.3).astype("f"))
    D = jnp.array(rs.randn(H).astype("f"))
    y8, h8 = L.ssd_chunked(x, dt, A_log, Bc, Cc, D, chunk=8)
    y32, h32 = L.ssd_chunked(x, dt, A_log, Bc, Cc, D, chunk=32)
    np.testing.assert_allclose(y8, y32, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h8, h32, rtol=1e-4, atol=1e-5)
    # split in two halves chained via initial_state == one pass
    y1, h1 = L.ssd_chunked(x[:, :32], dt[:, :32], A_log, Bc[:, :32],
                           Cc[:, :32], D, chunk=8)
    y2, h2 = L.ssd_chunked(x[:, 32:], dt[:, 32:], A_log, Bc[:, 32:],
                           Cc[:, 32:], D, chunk=8, initial_state=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y8,
                               rtol=1e-4, atol=1e-5)


def test_moe_no_drop_matches_dense_reference():
    rs = np.random.RandomState(0)
    Gr, T, Dm, E, F, K = 2, 32, 16, 8, 32, 2
    xm = jnp.array(rs.randn(Gr, T, Dm).astype("f"))
    rw = jnp.array(rs.randn(Dm, E).astype("f") * 0.1)
    w1 = jnp.array(rs.randn(E, Dm, F).astype("f") * 0.1)
    w3 = jnp.array(rs.randn(E, Dm, F).astype("f") * 0.1)
    w2 = jnp.array(rs.randn(E, F, Dm).astype("f") * 0.1)
    out, stats = L.moe_ffn(xm, rw, w1, w3, w2, n_experts=E, top_k=K,
                           capacity_factor=100.0)
    assert float(stats.frac_dropped) == 0.0
    logits = xm @ rw
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, K)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros((Gr, T, Dm), "f")
    for g in range(Gr):
        for t in range(T):
            for kk in range(K):
                e = int(ei[g, t, kk])
                h = np.asarray(xm[g, t]) @ np.asarray(w1[e])
                gt = np.asarray(xm[g, t]) @ np.asarray(w3[e])
                act = gt / (1 + np.exp(-gt))
                want[g, t] += float(gv[g, t, kk]) * ((act * h) @ np.asarray(w2[e]))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_and_aux_loss():
    rs = np.random.RandomState(0)
    Gr, T, Dm, E, F = 1, 64, 8, 4, 16
    # positive activations + positive col-0 router -> everyone picks e0
    xm = jnp.array(np.abs(rs.randn(Gr, T, Dm)).astype("f") + 0.1)
    rw = jnp.zeros((Dm, E)).at[:, 0].set(5.0)
    w1 = jnp.array(rs.randn(E, Dm, F).astype("f") * 0.1)
    w3 = jnp.array(rs.randn(E, Dm, F).astype("f") * 0.1)
    w2 = jnp.array(rs.randn(E, F, Dm).astype("f") * 0.1)
    out, stats = L.moe_ffn(xm, rw, w1, w3, w2, n_experts=E, top_k=1,
                           capacity_factor=1.0)
    assert float(stats.frac_dropped) > 0.4  # most tokens overflow expert 0
    assert float(stats.aux_loss) > 2.0      # unbalanced >> balanced (=1)
    # balanced router aux -> ~1
    rw_b = jnp.array(rs.randn(Dm, E).astype("f") * 0.01)
    _, stats_b = L.moe_ffn(xm, rw_b, w1, w3, w2, n_experts=E, top_k=1,
                           capacity_factor=4.0)
    assert float(stats_b.aux_loss) < float(stats.aux_loss)


def test_padded_experts_never_selected():
    rs = np.random.RandomState(0)
    Gr, T, Dm, E_real, E_pad, F = 1, 32, 8, 3, 4, 16
    xm = jnp.array(rs.randn(Gr, T, Dm).astype("f"))
    rw = jnp.array(rs.randn(Dm, E_pad).astype("f"))
    w1 = jnp.array(rs.randn(E_pad, Dm, F).astype("f") * 0.1)
    w3 = jnp.array(rs.randn(E_pad, Dm, F).astype("f") * 0.1)
    w2 = jnp.array(rs.randn(E_pad, F, Dm).astype("f") * 0.1)
    out, _ = L.moe_ffn(xm, rw, w1, w3, w2, n_experts=E_real, top_k=2,
                       capacity_factor=50.0)
    # poisoning the pad expert's weights must not change the output
    w2_poison = w2.at[E_real:].set(1e6)
    out2, _ = L.moe_ffn(xm, rw, w1, w3, w2_poison, n_experts=E_real, top_k=2,
                        capacity_factor=50.0)
    np.testing.assert_allclose(out, out2)


def test_conv_decode_matches_train():
    rs = np.random.RandomState(0)
    B, S, C, K = 2, 32, 6, 4
    x = jnp.array(rs.randn(B, S, C).astype("f"))
    w = jnp.array(rs.randn(C, K).astype("f"))
    full, _ = L.causal_conv1d(x, w)
    cache = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        y, cache = L.causal_conv1d(x[:, t:t + 1], w, cache)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=1e-5, atol=1e-5)


def test_rope_rotation_preserves_norm_and_relativity():
    rs = np.random.RandomState(0)
    x = jnp.array(rs.randn(1, 8, 2, 32).astype("f"))
    pos = jnp.arange(8)
    y = L.rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.array(rs.randn(1, 1, 1, 32).astype("f"))
    k = jnp.array(rs.randn(1, 1, 1, 32).astype("f"))
    def dot_at(i, j):
        qi = L.rope(jnp.broadcast_to(q, (1, 1, 1, 32)), jnp.array([i]), 1e4)
        kj = L.rope(jnp.broadcast_to(k, (1, 1, 1, 32)), jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-4)
