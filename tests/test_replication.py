"""DESIGN.md §15 replicated data-parallel training tests.

Covers the ISSUE-9 acceptance surface: a sync replicated step over two
real worker processes bit-matches the in-process strict oracle on the
same shard order; async (parameter-server) training converges within a
bounded apply budget, on the primitive-op MLP in-process and on the
§15 factory-Call smoke LM over the wire; an injected kill (REPRO_FAULTS)
of one replica's task recovers through ``recover_dead_tasks`` with the
surviving replica's Variable state kept live; and a wire run with
``backend="pallas"`` provably dispatches registry kernels worker-side
(the §12 dispatch-count assertion).
"""
import numpy as np
import pytest

from repro.core.options import SessionOptions
from repro.core.executor import ExecutorError
from repro.distrib import start_worker_processes, stop_worker_processes
from repro.distrib.replication import ReplicaPlan
from repro.launch.steps import build_mlp_replica_spec


def _shard(i, r, n=16):
    rs = np.random.RandomState(7919 * i + 131 * r)
    return {"x": rs.randn(n, 16).astype("f"),
            "y": rs.randint(0, 8, (n,)).astype("i")}


def _shards(i, n_replicas):
    return [_shard(i, r) for r in range(n_replicas)]


STRICT = SessionOptions(numerics="strict")


def test_sync_wire_bit_matches_inprocess_strict():
    """The paper's determinism contract extended to replication: the
    2-process sync plan and the in-process DeviceSet plan run the same
    graph through the same partition, so identical shard order must give
    bit-identical losses AND bit-identical final Variables."""
    steps = 4
    ref_plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="sync",
                           options=STRICT)
    ref_losses = [ref_plan.step(_shards(i, 2)) for i in range(steps)]
    ref_vars = {k: np.asarray(v)
                for k, v in ref_plan.variable_values().items()}
    ref_plan.close()

    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    try:
        plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="sync",
                           cluster=spec, options=STRICT)
        losses = [plan.step(_shards(i, 2)) for i in range(steps)]
        final = {k: np.asarray(v)
                 for k, v in plan.variable_values().items()}
        plan.close()
    finally:
        stop_worker_processes(procs, spec)

    np.testing.assert_array_equal(np.asarray(losses), np.asarray(ref_losses))
    assert sorted(final) == sorted(ref_vars)
    for name, v in ref_vars.items():
        np.testing.assert_array_equal(final[name], v)


def test_sync_odd_replica_count_and_descent():
    """3 replicas exercise the odd-arm carry in the binary reduce tree;
    repeated shards must descend (the mean gradient actually applies)."""
    plan = ReplicaPlan(build_mlp_replica_spec(), 3, mode="sync",
                       options=STRICT)
    fixed = _shards(0, 3)
    losses = [plan.step(fixed) for _ in range(20)]
    plan.close()
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.8


def test_sync_single_replica_degenerates_cleanly():
    plan = ReplicaPlan(build_mlp_replica_spec(), 1, mode="sync",
                       options=STRICT)
    l0 = plan.step(_shards(0, 1))
    l1 = plan.step(_shards(0, 1))
    plan.close()
    assert np.isfinite([l0, l1]).all() and l1 < l0


def test_async_interleaved_applies_in_process():
    """Downpour shape: both replica threads contribute applies, the loss
    descends on a fixed batch, and every step index applies exactly once."""
    plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="async")
    fixed = _shard(0, 0)
    applies = plan.run_async(lambda i, r: fixed, 30)
    plan.close()
    assert len(applies) == 30
    assert sorted(i for i, _r, _l in applies) == list(range(30))
    assert {r for _i, r, _l in applies} == {0, 1}
    first = np.mean([l for _i, _r, l in applies[:5]])
    last = np.mean([l for _i, _r, l in applies[-5:]])
    assert last < first * 0.8


def test_async_rejects_sync_api_and_vice_versa():
    plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="sync",
                       options=STRICT)
    with pytest.raises(RuntimeError):
        plan.run_async(lambda i, r: _shard(0, 0), 1)
    plan.close()
    plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="async")
    with pytest.raises(RuntimeError):
        plan.step([_shard(0, 0)])
    plan.close()


def test_async_smoke_lm_reaches_target_over_wire():
    """The §15 factory-Call smoke-LM step trains async over two real
    worker processes and reaches the target loss within a bounded apply
    budget (the ISSUE-9 acceptance bound)."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.steps import build_lm_replica_spec
    from repro.models.api import Shape

    cfg = get_config("smollm_360m", smoke=True)
    spec = build_lm_replica_spec(
        cfg, Shape("custom", 32, 2, "train"), lr=1e-2, seed=0,
        hparam_overrides={"compute_dtype": jnp.float32,
                          "loss_chunk": 0, "q_chunk": 0})
    rs = np.random.RandomState(0)
    fixed = {n: rs.randint(0, cfg.vocab_size, (2, 32)).astype(np.int32)
             for n in spec.feed_names}
    procs, cspec = start_worker_processes(2, rendezvous_timeout=15.0)
    try:
        plan = ReplicaPlan(
            spec, 2, mode="async", cluster=cspec,
            options=SessionOptions(numerics="fast", parity_guard=False))
        applies = plan.run_async(lambda i, r: fixed, 30)
        plan.close()
    finally:
        stop_worker_processes(procs, cspec)
    first, last = applies[0][2], applies[-1][2]
    # ln(vocab)=6.24 at init; a fixed batch overfits fast — 5.5 is a
    # loose bound (typical ~2-4) that still proves applies accumulate
    assert last < 5.5, f"async LM did not reach target: {first}->{last}"


@pytest.mark.chaos
def test_sync_replica_kill_recovers_with_live_survivor_state():
    """§13 meets §15: an injected kill of replica 1's task mid-epoch
    surfaces as an ExecutorError; ``recover_dead_tasks`` re-places the
    dead slice (here onto the survivor), keeps the survivor's Variables
    live, and post-recovery training bit-matches an uninterrupted
    in-process run of the same shard order."""
    plan_spec = "seed=5;kill:task=1,step=3"
    print(f"[chaos] REPRO_FAULTS={plan_spec}")
    steps = 5

    ref_plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="sync",
                           options=STRICT)
    for i in range(steps):
        ref_plan.step(_shards(i, 2))
    ref_vars = {k: np.asarray(v)
                for k, v in ref_plan.variable_values().items()}
    ref_plan.close()

    procs, spec = start_worker_processes(
        2, rendezvous_timeout=10.0, extra_env={"REPRO_FAULTS": plan_spec})
    try:
        plan = ReplicaPlan(build_mlp_replica_spec(), 2, mode="sync",
                           cluster=spec, options=STRICT)
        ckpt = None
        done = 0
        while done < steps:
            ckpt = {k: np.asarray(v)
                    for k, v in plan.variable_values().items()}
            try:
                plan.step(_shards(done, 2))
            except ExecutorError as e:
                assert "task:1" in str(e)
                report = plan.session.recover_dead_tasks(ckpt)
                print(report.describe())
                assert report.mode == "partial"
                assert sorted(report.dead) == [1]
                # both Variables home on the surviving task 0: nothing
                # restored from the checkpoint, everything kept live
                assert sorted(report.kept_live) == ["w1", "w2"]
                assert report.restored == ()
                continue  # retry the same shard: the kill fired on
                # run_graph receipt, before any state mutated
            done += 1
        final = {k: np.asarray(v) for k, v in plan.variable_values().items()}
        plan.close()
    finally:
        stop_worker_processes(procs, spec)
    for name, v in ref_vars.items():
        np.testing.assert_array_equal(final[name], v)


def test_wire_pallas_backend_dispatch_count():
    """Satellite 3: ``SessionOptions(backend=...)`` rides WirePlan
    registration, so a cluster run re-fuses worker-side onto the named
    backend — proven by the worker's own §12 dispatch counters, not by
    master-side state."""
    import jax.numpy as jnp

    from repro.core import GraphBuilder, Session

    rs = np.random.RandomState(3)
    W = rs.randn(32, 32).astype("f")
    b = GraphBuilder()
    x = b.placeholder("x")
    w = b.constant(jnp.asarray(W), name="w")
    y = b.matmul(x, w, name="mm")
    out = b.add(y, y, name="out")  # >1 op so the region fuses

    procs, spec = start_worker_processes(1, rendezvous_timeout=10.0)
    try:
        sess = Session(b.graph, options=SessionOptions(
            cluster=spec, backend="pallas", numerics="fast",
            parity_guard=False))
        X = rs.randn(16, 32).astype("f")
        v = sess.run(out.ref, {x.ref: X})
        st = sess.master.channels[0].call("debug_state")
        sess.close()
    finally:
        stop_worker_processes(procs, spec)
    np.testing.assert_allclose(np.asarray(v), (X @ W) * 2, rtol=2e-5)
    pallas = {k: n for k, n in st["kernel_dispatch"].items()
              if k.startswith("pallas:")}
    assert pallas and sum(pallas.values()) >= 1, st["kernel_dispatch"]
