"""DESIGN.md §9 numerics policy: the tolerance-gated parity machinery.

Three layers under test: the drift metrics (ULP + scale-relative), the
parity gate itself (a deliberately-divergent op — fp32 sequential
accumulation when compiled vs an fp64-accumulated eager reference —
must trip it; the representative suite must pass it), and the
Session-level guard (a tolerance breach falls back to strict execution
with a warning, leaving results and variable state bit-identical to the
strict engine).
"""
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import GraphBuilder, Session, TensorRef, register
from repro.core import numerics as num
from repro.core.graph import as_ref


# ---------------------------------------------------------------------------
# a deliberately-divergent op: compiled (traced) execution accumulates
# sequentially in fp32 via lax.scan; eager execution accumulates in fp64
# and rounds once.  On a cancellation-heavy input ([1e8, 1 x64, -1e8])
# the fp32 path loses the ones entirely — drift ~= 1.0 relative.


@register("DivergentSum")
def _divergent_sum(ctx, node, xv):
    if isinstance(xv, jax.core.Tracer):
        total, _ = jax.lax.scan(lambda c, v: (c + v, None),
                                jnp.float32(0.0), xv)
        return (total,)
    return (jnp.asarray(np.asarray(xv, np.float64).sum(), jnp.float32),)


CANCEL_INPUT = np.concatenate(
    [[1e8], np.ones(64, np.float32), [-1e8]]).astype(np.float32)


def _divergent_graph():
    b = GraphBuilder()
    y = b.placeholder("y")
    ds = b.graph.add_node("DivergentSum", [y], name="ds")
    fin = b.add(ds, b.constant(jnp.float32(1.0), name="bias"), name="fin")
    v = b.variable("v", init_value=lambda: jnp.float32(10.0))
    upd = b.assign_add(v, b.constant(jnp.float32(0.5), name="half"))
    return b, y, fin, upd


# ---------------------------------------------------------------------------
# drift metrics


def test_ulp_distance_basics():
    one = np.float32(1.0)
    next_up = np.nextafter(one, np.float32(2.0), dtype=np.float32)
    assert num.ulp_distance(one, one) == 0
    assert num.ulp_distance(one, next_up) == 1
    assert num.ulp_distance(np.float32(-0.0), np.float32(0.0)) == 0
    # sign-crossing distances are finite and monotone
    tiny = np.float32(1e-45)
    assert num.ulp_distance(tiny, -tiny) == 2
    nan = np.float32("nan")
    assert num.ulp_distance(nan, nan) == 0
    assert np.isinf(num.ulp_distance(nan, one))


def test_compare_scale_relative_absorbs_near_zero_elements():
    # a tiny absolute wiggle on a near-zero element of a large-scale
    # tensor passes (the allclose atol=rtol*amax convention) ...
    ref = np.array([100.0, 1e-12], np.float32)
    got = np.array([100.0, 2e-12], np.float32)
    ok, drift = num.compare([ref], [got], num.Tolerance(ulp=4, rel=1e-6))
    assert ok
    # ... while the same wiggle on a tensor OF that scale fails
    ref2 = np.array([1e-12, 1e-12], np.float32)
    got2 = np.array([1e-12, 2e-12], np.float32)
    ok2, _ = num.compare([ref2], [got2], num.Tolerance(ulp=4, rel=1e-6))
    assert not ok2


def test_compare_exact_for_non_float_and_structure():
    tol = num.TOLERANCES["cpu"]["elementwise"]
    ok, _ = num.compare([np.arange(4)], [np.arange(4)], tol)
    assert ok
    ok, drift = num.compare([np.arange(4)], [np.arange(1, 5)], tol)
    assert not ok and np.isinf(drift.ulp)
    ok, _ = num.compare([None], [None], tol)
    assert ok
    ok, _ = num.compare([None, 1.0], [1.0], tol)
    assert not ok


def test_compare_handles_pytrees():
    ref = {"a": np.float32(1.0), "b": [np.ones(3, np.float32)]}
    got = {"a": np.float32(1.0),
           "b": [np.ones(3, np.float32)
                 + np.float32(1e-7)]}
    ok, drift = num.compare(ref, got, num.TOLERANCES["cpu"]["reduction"])
    assert ok and drift.ulp > 0


def test_tolerance_for_ops_merges_loosest_class():
    cpu = num.TOLERANCES["cpu"]
    t_elem = num.tolerance_for_ops({"Add", "Mul", "Relu"})
    assert t_elem == cpu["elementwise"]
    t_mm = num.tolerance_for_ops({"Add", "MatMul"})
    assert t_mm.ulp == max(cpu["matmul"].ulp, cpu["elementwise"].ulp)
    # softmax dominates matmul in both bounds
    t_all = num.tolerance_for_ops({"MatMul", "SoftMax", "ReduceSum"})
    assert t_all.ulp >= cpu["softmax"].ulp


def test_tolerance_table_device_and_backend_keying():
    """TPU tables are looser than CPU; a backend calibration overlays
    loosest-wins on top of the device table."""
    cpu = num.tolerance_table("cpu")
    tpu = num.tolerance_table("tpu")
    assert set(cpu) == set(tpu)
    assert tpu["matmul"].ulp >= cpu["matmul"].ulp
    pal = num.tolerance_table("cpu", backend="pallas")
    for cls, tol in pal.items():
        assert tol.ulp >= cpu[cls].ulp and tol.rel >= cpu[cls].rel
    assert pal["softmax"].ulp > cpu["softmax"].ulp
    # merging across device kinds keeps the loosest bound
    t = num.tolerance_for_ops({"MatMul"}, device_kinds=("cpu", "tpu"))
    assert t.ulp == tpu["matmul"].ulp


# ---------------------------------------------------------------------------
# the gate itself


@pytest.mark.paritygate
def test_parity_gate_passes_on_representative_suite():
    report = num.run_parity_gate()
    assert report.passed, report.breaches
    # every case fused something (never vacuous) ...
    assert all(c.regions >= 1 and c.ops_fused >= 2 for c in report.cases)
    # ... and the suite exercised every tolerance class
    assert set(report.per_class) == set(num.tolerance_table())
    # the structured report round-trips
    js = report.to_json()
    assert js["passed"] and set(js["max_drift_per_class"]) == set(
        num.tolerance_table())
    assert "PASS" in report.to_markdown()


@pytest.mark.paritygate
def test_divergent_op_trips_gate():
    """An injected fp32-accumulation-vs-fp64-reference op must breach."""

    def build(b):
        y = b.placeholder("y")
        ds = b.graph.add_node("DivergentSum", [y], name="ds")
        fin = b.add(ds, b.constant(jnp.float32(1.0), name="bias"),
                    name="fin")
        return {"y": y, "fin": fin}

    case = num.ParityCase(
        name="injected_divergence", build=build,
        fetches=lambda ex: [ex["fin"].ref],
        fetch_classes=("call",),  # loosest class: still must breach
        feeds=lambda ex, step: {ex["y"].ref: jnp.asarray(CANCEL_INPUT)},
        n_runs=1)
    report = num.run_parity_gate([case])
    assert not report.passed
    assert any("injected_divergence" in b for b in report.breaches)
    assert report.per_class["call"].rel > 0.5  # the ones were lost


def test_gate_cli_json_report(tmp_path):
    path = str(tmp_path / "report.json")
    rc = num.main(["--gate", "--cases", "residual_tower", "--json", path])
    assert rc == 0
    with open(path) as fh:
        js = json.load(fh)
    assert js["passed"] and js["cases"][0]["name"] == "residual_tower"
    assert "tolerances" in js


# ---------------------------------------------------------------------------
# Session-level guard: breach -> warn + permanent strict fallback


def test_session_fallback_on_breach_matches_strict_bitwise():
    b, y, fin, upd = _divergent_graph()
    fast = Session(b.graph, numerics="fast")  # parity guard defaults on
    strict = Session(b.graph, numerics="strict", fuse_regions=False)
    feeds = lambda: {y.ref: jnp.asarray(CANCEL_INPUT)}  # noqa: E731
    with pytest.warns(RuntimeWarning, match="parity breach"):
        fv = fast.run([fin.ref, upd.ref], feeds())
    sv = strict.run([fin.ref, upd.ref], feeds())
    assert [float(a) for a in fv] == [float(c) for c in sv]
    assert float(fast.variable_value("v")) == float(
        strict.variable_value("v")) == 10.5
    # the fallback is permanent: later runs stay strict, no more warnings
    exe = fast.executable([fin.ref, upd.ref], frozenset({y.ref}))
    assert exe._strict_fallback and not exe._parity_pending
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        fv2 = fast.run([fin.ref, upd.ref], feeds())
    sv2 = strict.run([fin.ref, upd.ref], feeds())
    assert [float(a) for a in fv2] == [float(c) for c in sv2]
    assert float(fast.variable_value("v")) == float(
        strict.variable_value("v")) == 11.0


def test_benign_fast_session_keeps_fusion_and_warns_nothing():
    b = GraphBuilder()
    x = b.placeholder("x")
    cur = x
    for i in range(6):
        cur = b.add(b.mul(cur, x, name=f"m{i}"), x, name=f"a{i}")
    out = b.reduce_sum(cur, name="out")
    sess = Session(b.graph, numerics="fast")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        v1 = sess.run(out.ref, {x.ref: jnp.linspace(0.1, 0.9, 16)})
        v2 = sess.run(out.ref, {x.ref: jnp.linspace(0.1, 0.9, 16)})
    assert float(v1) == float(v2)
    exe = sess.executable([out.ref], frozenset({x.ref}))
    assert not exe._strict_fallback and not exe._parity_pending
    # fast mode actually fused the reduction (the point of the flip)
    assert any(exe.fusion.graph.nodes[s.name] and
               "ReduceSum" in {s.subgraph.nodes[m].op for m in s.members}
               for s in exe.fusion.regions)


def test_guard_skips_unreplayable_side_effects():
    """Queue ops cannot be double-executed for a reference run: the guard
    must skip, and each run must consume the queue exactly once."""
    from repro.runtime.queues import FIFOQueue

    b = GraphBuilder()
    x = b.placeholder("x")
    sq = b.square(x, name="sq")
    enq = b.graph.add_node("QueueEnqueue", [sq], name="enq",
                           attrs={"queue": "q"})
    deq = b.graph.add_node("QueueDequeue", [], name="deq",
                           attrs={"queue": "q", "n_components": 1},
                           control_inputs=[enq])
    out = b.reduce_sum(b.mul(deq, deq, name="dsq"), name="out")
    sess = Session(b.graph, numerics="fast")
    sess.register_queue("q", FIFOQueue(capacity=4, timeout=5.0))
    for step in range(3):
        v = sess.run(out.ref, {x.ref: jnp.full((3,), 1.0 + step)})
        assert np.isfinite(float(v))
    assert sess.queues["q"].size() == 0  # exactly one enqueue per dequeue
    exe = sess.executable([out.ref], frozenset({x.ref}))
    assert not exe._parity_pending and not exe._strict_fallback


def test_strict_and_fast_executables_cache_separately():
    b = GraphBuilder()
    x = b.placeholder("x")
    out = b.reduce_sum(b.mul(x, x, name="m"), name="out")
    sess = Session(b.graph, numerics="fast", parity_guard=False)
    sess.run(out.ref, {x.ref: jnp.ones(4)})
    exe_fast = sess.executable([out.ref], frozenset({x.ref}))
    assert exe_fast.numerics == "fast"
    # flipping the session's numerics mode must MISS the cache: a stale
    # fast plan silently serving strict (or vice versa) would make
    # results signature-dependent
    sess.numerics = "strict"
    sess.run(out.ref, {x.ref: jnp.ones(4)})
    exe_strict = sess.executable([out.ref], frozenset({x.ref}))
    assert exe_strict is not exe_fast and exe_strict.numerics == "strict"
    sess.numerics = "fast"
    assert sess.executable([out.ref], frozenset({x.ref})) is exe_fast


def test_session_rejects_unknown_numerics():
    with pytest.raises(ValueError, match="numerics"):
        Session(numerics="fastest")


def test_fast_mode_fuses_matmul_at_full_opt():
    """The tentpole behavior: under fast numerics MatMul/reductions join
    regions (strict keeps them eager) and the region spec records the
    fast policy (full XLA optimization; no opt-0 compile option)."""
    b = GraphBuilder()
    x = b.placeholder("x")
    w = b.constant(jnp.eye(4, dtype=jnp.float32), name="w")
    mm = b.matmul(x, w, name="mm")
    out = b.reduce_sum(b.add(mm, x, name="sum_in"), name="out")
    fast = Session(b.graph, numerics="fast", parity_guard=False)
    strict = Session(b.graph, numerics="strict")
    X = jnp.ones((4, 4), jnp.float32)
    fv = fast.run(out.ref, {x.ref: X})
    sv = strict.run(out.ref, {x.ref: X})
    assert float(fv) == float(sv) == 32.0
    fexe = fast.executable([out.ref], frozenset({x.ref}))
    fused_ops = {s.subgraph.nodes[m].op
                 for s in fexe.fusion.regions for m in s.members}
    assert {"MatMul", "ReduceSum"} <= fused_ops
    assert all(s.numerics == "fast" for s in fexe.fusion.regions)
    sexe = strict.executable([out.ref], frozenset({x.ref}))
    strict_fused = {s.subgraph.nodes[m].op
                    for s in (sexe.fusion.regions if sexe.fusion else [])
                    for m in s.members}
    assert "MatMul" not in strict_fused and "ReduceSum" not in strict_fused


def test_guard_sampling_catches_input_shift_drift():
    """REPRO_NUMERICS_GUARD=sample:N (ROADMAP item): the first batch can
    pass the guard while a later input distribution exposes drift — the
    sampled re-verification catches it and demotes to strict."""
    BENIGN = np.ones(66, np.float32)  # fp32 scan == fp64 sum exactly
    b, y, fin, upd = _divergent_graph()
    sess = Session(b.graph, numerics="fast", parity_guard="sample:2")
    assert sess.parity_guard and sess.parity_guard_every == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # first run verifies and passes
        sess.run([fin.ref, upd.ref], {y.ref: jnp.asarray(BENIGN)})
        sess.run([fin.ref, upd.ref], {y.ref: jnp.asarray(BENIGN)})  # unsampled
    with pytest.warns(RuntimeWarning, match="parity breach"):
        # run 3 is the sampled re-verification; the shifted input drifts
        rv = sess.run([fin.ref, upd.ref], {y.ref: jnp.asarray(CANCEL_INPUT)})
    exe = sess.executable([fin.ref, upd.ref], frozenset({y.ref}))
    assert exe._strict_fallback
    # ...and the caller received the strict reference, not the drifted value
    strict = Session(b.graph, numerics="strict", fuse_regions=False)
    for feed in (BENIGN, BENIGN, CANCEL_INPUT):
        sv = strict.run([fin.ref, upd.ref], {y.ref: jnp.asarray(feed)})
    assert float(rv[0]) == float(sv[0])


def test_default_guard_misses_late_drift_without_sampling():
    """The contrast case motivating sample:N — first-run-only verification
    lets a later shifted batch return the drifted fused value silently."""
    BENIGN = np.ones(66, np.float32)
    b, y, fin, upd = _divergent_graph()
    sess = Session(b.graph, numerics="fast", parity_guard=True)
    assert sess.parity_guard_every is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sess.run([fin.ref, upd.ref], {y.ref: jnp.asarray(BENIGN)})
        out = sess.run([fin.ref, upd.ref], {y.ref: jnp.asarray(CANCEL_INPUT)})
    # fused fp32 scan lost the 64 ones entirely: genuine unreported drift
    assert abs(float(out[0]) - (64.0 + 1.0)) > 1.0
    exe = sess.executable([fin.ref, upd.ref], frozenset({y.ref}))
    assert not exe._strict_fallback


def test_guard_sampling_env_and_param_parsing(monkeypatch):
    b = GraphBuilder()
    b.constant(jnp.float32(1.0), name="c")
    monkeypatch.setenv("REPRO_NUMERICS_GUARD", "sample:4")
    s = Session(b.graph)
    assert s.parity_guard and s.parity_guard_every == 4
    monkeypatch.setenv("REPRO_NUMERICS_GUARD", "off")
    s2 = Session(b.graph)
    assert not s2.parity_guard
    s3 = Session(b.graph, parity_guard="sample:1")  # re-verify every run
    assert s3.parity_guard_every == 1
    with pytest.raises(ValueError, match="sample period"):
        Session(b.graph, parity_guard="sample:0")


def test_compare_bf16_judged_in_native_ulps():
    """jax's ml_dtypes floats (the serve cache is bf16) must be drift-
    compared, not exact-compared — and the fp32-calibrated ULP bounds
    must scale to the narrower mantissa (2048 fp32-ULPs carried over to
    bf16 verbatim would span ~16 binades and check nothing)."""
    ml_dtypes = pytest.importorskip("ml_dtypes")
    tol = num.TOLERANCES["cpu"]["call"]
    a = np.array([1.0], ml_dtypes.bfloat16)
    one_ulp = np.array([1.0078125], ml_dtypes.bfloat16)
    ok, drift = num.compare([a], [one_ulp], tol)
    assert ok and drift.ulp == 1  # reassociation-scale drift passes
    binade = np.array([2.0], ml_dtypes.bfloat16)
    ok, drift = num.compare([a], [binade], tol)
    assert not ok and drift.ulp == 128  # genuine divergence still fails
    assert num._effective_ulp(tol.ulp, a.dtype) == 8.0
