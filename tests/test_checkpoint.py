"""§3.3 fault tolerance: Save/Restore nodes + kill/restore equivalence."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session
from repro.checkpoint import FileCheckpointIO, CheckpointManager, attach_save_restore
from repro.optim import attach_train_op


def _graph():
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.zeros((3, 1), "float32"))
    x = b.placeholder("x")
    y = b.placeholder("y")
    loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
    op = attach_train_op(b, loss, [W], optimizer="sgd", lr=0.05)
    return b, W, x, y, loss, op


def _data(seed=0):
    rs = np.random.RandomState(seed)
    X = rs.randn(64, 3).astype("float32")
    w = np.array([[1.0], [-2.0], [0.5]], "float32")
    return jnp.array(X), jnp.array(X @ w)


def test_save_restore_nodes_roundtrip(tmp_path):
    io = FileCheckpointIO(str(tmp_path))
    b, W, x, y, loss, op = _graph()
    nodes = attach_save_restore(b, [W], path="ckpt/test")
    X, Y = _data()
    sess = Session(b.graph, checkpoint_io=io)
    for _ in range(20):
        sess.run(op.ref, {x.ref: X, y.ref: Y})
    w_at_save = np.asarray(sess.variable_value("W"))
    sess.run(nodes["save"].ref)

    for _ in range(10):
        sess.run(op.ref, {x.ref: X, y.ref: Y})
    assert not np.allclose(sess.variable_value("W"), w_at_save)

    sess.run(nodes["restore"].ref)
    np.testing.assert_allclose(sess.variable_value("W"), w_at_save)


def test_kill_and_restart_resumes_identically(tmp_path):
    """Abort mid-training, restart from the checkpoint in a FRESH session
    (§3.3: 'the entire graph execution is aborted and restarted')."""
    io = FileCheckpointIO(str(tmp_path))
    X, Y = _data()

    # uninterrupted run: 40 steps
    b, W, x, y, loss, op = _graph()
    ref_sess = Session(b.graph, checkpoint_io=io)
    for _ in range(40):
        ref_sess.run(op.ref, {x.ref: X, y.ref: Y})
    w_ref = np.asarray(ref_sess.variable_value("W"))

    # interrupted run: 20 steps, checkpoint, "crash"
    b1, W1, x1, y1, loss1, op1 = _graph()
    s1 = Session(b1.graph, checkpoint_io=io)
    sr1 = attach_save_restore(b1, [W1, b1.graph.nodes["train/step"]],
                              path="ckpt/crash")
    for _ in range(20):
        s1.run(op1.ref, {x1.ref: X, y1.ref: Y})
    s1.run(sr1["save"].ref)
    del s1  # the crash

    # restart: fresh session, Restore enabled first iteration (§3.3)
    b2, W2, x2, y2, loss2, op2 = _graph()
    sr2 = attach_save_restore(b2, [W2, b2.graph.nodes["train/step"]],
                              path="ckpt/crash")
    s2 = Session(b2.graph, checkpoint_io=io)
    s2.run(sr2["restore"].ref)
    assert int(s2.variable_value("train/step")) == 20
    for _ in range(20):
        s2.run(op2.ref, {x2.ref: X, y2.ref: Y})
    np.testing.assert_allclose(s2.variable_value("W"), w_ref, rtol=1e-6)


def test_checkpoint_manager_periodic_and_retention(tmp_path):
    io = FileCheckpointIO(str(tmp_path))
    mgr = CheckpointManager(io, every_steps=10, keep=2)
    for step in range(1, 51):
        if mgr.should_save(step):
            mgr.save(step, {"w": jnp.full((4,), float(step))})
    assert mgr.latest_step() == 50
    assert len(io.list()) == 2  # retention
    restored = mgr.restore_latest()
    np.testing.assert_allclose(restored["w"], np.full((4,), 50.0))


def test_checkpoint_manager_resume_discovery(tmp_path):
    io = FileCheckpointIO(str(tmp_path))
    mgr = CheckpointManager(io, every_steps=5, keep=3)
    mgr.save(5, {"w": jnp.ones(2)})
    mgr.save(10, {"w": 2 * jnp.ones(2)})
    # fresh manager over the same dir discovers existing checkpoints
    mgr2 = CheckpointManager(io, every_steps=5, keep=3)
    assert mgr2.latest_step() == 10


def test_pytree_checkpoint_roundtrip(tmp_path):
    io = FileCheckpointIO(str(tmp_path))
    tree = {"params": {"a": jnp.ones((2, 2)), "b": [jnp.zeros(3), jnp.ones(1)]}}
    io.save("ckpt/tree", tree)
    out = io.load("ckpt/tree")
    np.testing.assert_allclose(out["params"]["a"], tree["params"]["a"])
    np.testing.assert_allclose(out["params"]["b"][1], tree["params"]["b"][1])
