"""§5.5 lossy compression: bit-level contract + error bound (property)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


def test_wire_format_is_uint16():
    x = jnp.array([1.0, -2.5, 3.14159], jnp.float32)
    w = C.compress_f32_to_16(x)
    assert w.dtype == jnp.uint16


def test_roundtrip_matches_bfloat16_truncation():
    """Keeping the top 16 bits of f32 IS the bfloat16 pattern (DESIGN §2)."""
    x = jnp.array(np.random.RandomState(0).randn(256).astype("float32"))
    rt = C.roundtrip(x)
    # bf16 truncation (round-toward-zero) differs from jnp.bfloat16 cast
    # (round-to-nearest), so compare against the explicit bit op:
    bits = np.asarray(x).view(np.uint32) & 0xFFFF0000
    want = bits.view(np.float32)
    np.testing.assert_array_equal(np.asarray(rt), want)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          allow_subnormal=False, width=32),
                min_size=1, max_size=64))
def test_relative_error_bound(vals):
    # subnormals excluded: truncating their mantissa has unbounded rel
    # error (they are below bf16's normal range) — documented behaviour.
    x = jnp.array(np.array(vals, dtype=np.float32))
    rt = C.roundtrip(x)
    denom = np.where(np.abs(np.asarray(x)) > 0, np.abs(np.asarray(x)), 1.0)
    rel = np.abs(np.asarray(rt) - np.asarray(x)) / denom
    assert float(rel.max(initial=0.0)) <= C.max_relative_error()


def test_zero_and_sign_preserved():
    x = jnp.array([0.0, -0.0, 1.5, -1.5], jnp.float32)
    rt = np.asarray(C.roundtrip(x))
    assert rt[0] == 0.0 and rt[2] == 1.5 and rt[3] == -1.5
    assert np.signbit(rt[1])
