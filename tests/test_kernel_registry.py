"""Pluggable kernel-backend registry (DESIGN.md §12).

Contract under test: fused-fast regions lowered under a non-generic
backend dispatch pattern-matched subgraphs (MatMul chains, rmsnorm,
softmax-attention, ssd_scan) onto the hand-written Pallas kernels; every
result stays within the per-backend calibrated tolerances of both the
generic lowering and the kernels/ref.py oracles; anything the matcher or
the trace-time feasibility checks reject falls back to the generic
compute path; and the backend choice joins the RunSignature so cached
plans never leak across backends.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphBuilder, Session
from repro.core import kernel_registry as kr
from repro.core import numerics as num
from repro.kernels import ref as kref

RNG = np.random.default_rng(7)


def _f32(*shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32) * scale)


def _pallas_tol(cls):
    return num.tolerance_table("cpu", backend="pallas")[cls]


def _assert_close(ref, got, cls):
    ok, drift = num.compare([np.asarray(ref)], [np.asarray(got)],
                            _pallas_tol(cls))
    assert ok, f"{cls} drift {drift} exceeds pallas tolerance"


def _run_pair(build, fetch_names, feeds=None):
    """Run the same graph under backend=pallas and backend=generic, both
    fused-fast, and return (pallas_vals, generic_vals, dispatched_delta)."""
    vals = {}
    for backend in ("pallas", "generic"):
        b = GraphBuilder()
        handles = build(b)
        sess = Session(b.graph, numerics="fast", parity_guard=False,
                       backend=backend)
        before = kr.dispatch_counts(backend)
        fd = {handles[k].ref: v for k, v in (feeds or {}).items()}
        out = sess.run([handles[n].ref for n in fetch_names], fd)
        after = kr.dispatch_counts(backend)
        delta = {k: after.get(k, 0) - before.get(k, 0)
                 for k in after if after.get(k, 0) > before.get(k, 0)}
        vals[backend] = (out, delta)
    p_out, p_delta = vals["pallas"]
    g_out, g_delta = vals["generic"]
    assert not g_delta, "generic backend must never dispatch kernels"
    return p_out, g_out, p_delta


# ---------------------------------------------------------------------------
# backend selection plumbing


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        Session(backend="cuda")


def test_env_var_selects_backend(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    assert Session().kernel_backend == "pallas"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "rocm")
    with pytest.raises(ValueError, match="backend"):
        Session()
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert Session().kernel_backend == "generic"
    assert set(kr.available_backends()) >= {"generic", "pallas"}


def test_backend_flip_misses_executable_cache():
    """kernel_backend is part of the RunSignature: a stale pallas plan
    silently serving generic (or vice versa) would bypass the per-backend
    tolerance calibration."""
    b = GraphBuilder()
    x = b.placeholder("x")
    w = b.constant(_f32(32, 32, scale=0.2), name="w")
    out = b.reduce_sum(b.matmul(x, w, name="mm"), name="out")
    sess = Session(b.graph, numerics="fast", parity_guard=False,
                   backend="pallas")
    X = _f32(32, 32)
    v1 = sess.run(out.ref, {x.ref: X})
    exe_p = sess.executable([out.ref], frozenset({x.ref}))
    sess.kernel_backend = "generic"
    v2 = sess.run(out.ref, {x.ref: X})
    exe_g = sess.executable([out.ref], frozenset({x.ref}))
    assert exe_g is not exe_p
    sess.kernel_backend = "pallas"
    assert sess.executable([out.ref], frozenset({x.ref})) is exe_p
    _assert_close(v2, v1, "matmul")


def test_strict_numerics_never_dispatches():
    """The registry is a fast-numerics optimisation: strict sessions lower
    every region generically regardless of the configured backend."""
    b = GraphBuilder()
    x = b.placeholder("x")
    w = b.constant(_f32(32, 32), name="w")
    out = b.matmul(x, w, name="mm")
    sess = Session(b.graph, numerics="strict", backend="pallas")
    before = kr.dispatch_total("pallas")
    sess.run(out.ref, {x.ref: _f32(16, 32)})
    assert kr.dispatch_total("pallas") == before


# ---------------------------------------------------------------------------
# per-pattern parity: pallas vs generic lowering vs kernels/ref oracles


def test_matmul_pattern_parity():
    A, B = _f32(64, 32), _f32(32, 48)

    def build(b):
        x = b.placeholder("x")
        w = b.constant(B, name="w")
        y = b.matmul(x, w, name="y")
        z = b.add(y, y, name="z")  # keep the region >1 op so it fuses
        return {"x": x, "z": z}

    p, g, delta = _run_pair(build, ["z"], feeds={"x": A})
    assert "matmul" in delta
    _assert_close(g[0], p[0], "matmul")
    _assert_close(kref.matmul_ref(A, B) * 2, p[0], "matmul")


def test_rmsnorm_pattern_parity():
    X = _f32(64, 128)
    W = jnp.asarray(np.abs(RNG.standard_normal(128)).astype(np.float32) + 0.5)

    def build(b):
        x = b.placeholder("x")
        w = b.constant(W, name="w")
        y = b.rmsnorm(x, w, name="y")
        return {"x": x, "y": y}

    p, g, delta = _run_pair(build, ["y"], feeds={"x": X})
    assert "rmsnorm" in delta
    _assert_close(g[0], p[0], "reduction")
    _assert_close(kref.rmsnorm_ref(X, W), p[0], "reduction")


def test_attention_pattern_parity():
    S, D = 64, 32
    Q, KT, V = _f32(S, D), _f32(D, S), _f32(S, D)
    scale = 1.0 / float(np.sqrt(D))

    def build(b):
        q = b.placeholder("q")
        kT = b.constant(KT, name="kT")
        v = b.constant(V, name="v")
        y = b.attention(q, kT, v, scale=scale, name="y")
        return {"q": q, "y": y}

    p, g, delta = _run_pair(build, ["y"], feeds={"q": Q})
    assert "flash_attention" in delta
    _assert_close(g[0], p[0], "softmax")
    oracle = kref.flash_attention_ref(
        Q.reshape(1, S, D), KT.T.reshape(1, S, D), V.reshape(1, S, D),
        causal=False)[0]
    _assert_close(oracle, p[0], "softmax")


def test_ssd_pattern_parity():
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 8
    X = _f32(B, S, H, P)
    DT = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))).astype(
        np.float32) * 0.1)
    A_log = _f32(H, scale=0.1)
    Bc, Cc = _f32(B, S, G, N), _f32(B, S, G, N)
    D_skip = _f32(H, scale=0.1)

    def build(b):
        x = b.placeholder("x")
        y = b.ssd_scan(x, b.constant(DT, name="dt"),
                       b.constant(A_log, name="al"),
                       b.constant(Bc, name="B"), b.constant(Cc, name="C"),
                       b.constant(D_skip, name="D"), name="y")
        tot = b.reduce_sum(y, name="tot")
        return {"x": x, "y": y, "tot": tot}

    p, g, delta = _run_pair(build, ["y", "tot"], feeds={"x": X})
    assert "ssd_scan" in delta
    _assert_close(g[0], p[0], "scan")
    _assert_close(g[1], p[1], "scan")


def test_full_lm_block_dispatches_three_kernels():
    """The b8 shape: rmsnorm -> q-proj -> attention -> residual should hit
    three distinct registered kernels in one fused region."""
    S, D = 64, 32
    X, KT, V = _f32(S, D), _f32(D, S), _f32(S, D)
    W = jnp.asarray(np.abs(RNG.standard_normal(D)).astype(np.float32) + 0.5)
    Wq = _f32(D, D, scale=0.2)

    def build(b):
        x = b.placeholder("x")
        xn = b.rmsnorm(x, b.constant(W, name="w"), name="xn")
        q = b.matmul(xn, b.constant(Wq, name="Wq"), name="q")
        att = b.attention(q, b.constant(KT, name="kT"),
                          b.constant(V, name="v"),
                          scale=1.0 / float(np.sqrt(D)), name="att")
        y = b.add(att, x, name="y")
        return {"x": x, "y": y}

    p, g, delta = _run_pair(build, ["y"], feeds={"x": X})
    assert {"rmsnorm", "matmul", "flash_attention"} <= set(delta)
    _assert_close(g[0], p[0], "softmax")


# ---------------------------------------------------------------------------
# fallback + matcher internals


def test_infeasible_shape_falls_back_to_generic():
    """K=192 violates the Pallas block constraint (>128 and not a
    multiple): the emit hook declines at trace time, the fallback counter
    moves, and the generic path still produces the right answer."""
    A, B = _f32(64, 192), _f32(192, 64)

    def build(b):
        x = b.placeholder("x")
        w = b.constant(B, name="w")
        z = b.add(b.matmul(x, w, name="y"), b.constant(
            jnp.float32(0.0), name="c"), name="z")
        return {"x": x, "z": z}

    before = kr.STATS["fallbacks"]
    b = GraphBuilder()
    handles = build(b)
    sess = Session(b.graph, numerics="fast", parity_guard=False,
                   backend="pallas")
    out = sess.run(handles["z"].ref, {handles["x"].ref: A})
    assert kr.STATS["fallbacks"] > before
    _assert_close(kref.matmul_ref(A, B), out, "matmul")


def test_feasibility_rule():
    assert kr._feasible(64, 128, 256)
    assert not kr._feasible(192)          # >128, not a multiple
    assert not kr._feasible(0)
    assert kr._feasible(200, block=256)   # fits inside one block


def test_plan_claims_interior_of_larger_match():
    """The q-projection MatMul inside an attention idiom anchors its own
    rule, but attention's scores-MatMul is interior to the attention match
    and must NOT be dispatched separately."""
    b = GraphBuilder()
    x = b.placeholder("x")
    q = b.matmul(x, b.constant(_f32(32, 32), name="Wq"), name="q")
    att = b.attention(q, b.constant(_f32(32, 64), name="kT"),
                      b.constant(_f32(64, 32), name="v"),
                      scale=0.125, name="att")
    g = b.graph
    members = [n for n in g.topo_sort() if g.nodes[n].op != "Placeholder"]
    overrides = kr.plan_region_overrides(g, members, "pallas", "cpu")
    assert set(overrides) == {"q", "att"}
    assert "att/scores" not in overrides
    assert kr.plan_region_overrides(g, members, "generic", "cpu") == {}
