"""Optimizers-as-graph-nodes + the paper's §7 idioms.

Key paper claim validated here: synchronous data parallelism "behaves
exactly as if we were running the sequential SGD algorithm with a batch
size of [the combined batch]" — we assert bitwise-close parameter
trajectories.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, gradients, compile_subgraph
from repro.optim import (attach_train_op, adamw_init, adamw_update,
                         sgd_init, sgd_update)


def _data(n=64, seed=0):
    rs = np.random.RandomState(seed)
    X = rs.randn(n, 3).astype("float32")
    w = np.array([[1.0], [-2.0], [0.5]], "float32")
    return jnp.array(X), jnp.array(X @ w)


def _regression_graph(opt, **hp):
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.zeros((3, 1), "float32"))
    x = b.placeholder("x")
    y = b.placeholder("y")
    loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
    op = attach_train_op(b, loss, [W], optimizer=opt, **hp)
    return b, W, x, y, loss, op


@pytest.mark.parametrize("opt,hp", [
    ("sgd", {"lr": 0.05}),
    ("momentum", {"lr": 0.02, "momentum": 0.9}),
    ("adamw", {"lr": 0.05}),
])
def test_optimizers_converge_eagerly(opt, hp):
    b, W, x, y, loss, op = _regression_graph(opt, **hp)
    X, Y = _data()
    sess = Session(b.graph)
    for _ in range(150):
        l, _ = sess.run([loss.ref, op.ref], {x.ref: X, y.ref: Y})
    assert float(l) < 1e-2
    np.testing.assert_allclose(sess.variable_value("W").ravel(),
                               [1.0, -2.0, 0.5], atol=0.15)


def test_sync_data_parallel_equals_sequential_sgd():
    """§7: N replicas each on 1/N of the batch + summed-gradient update
    == sequential SGD on the full batch."""
    X, Y = _data(n=64)
    lr = 0.1

    # sequential: full batch
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.zeros((3, 1), "float32"))
    x = b.placeholder("x")
    y = b.placeholder("y")
    loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
    (gW,) = gradients(b.graph, [loss], [W])
    upd = b.assign(W, b.sub(W, b.mul(b.constant(jnp.array(lr), name="lr"), gW)))
    seq = Session(b.graph)
    for _ in range(10):
        seq.run(upd.ref, {x.ref: X, y.ref: Y})
    W_seq = np.asarray(seq.variable_value("W"))

    # data-parallel: 4 replicas of the model graph, one shared W,
    # combined (averaged) gradients applied synchronously
    b2 = GraphBuilder()
    W2 = b2.variable("W", init_value=lambda: jnp.zeros((3, 1), "float32"))
    grads = []
    phs = []
    for r in range(4):
        xr = b2.placeholder(f"x{r}")
        yr = b2.placeholder(f"y{r}")
        phs.append((xr, yr))
        lr_loss = b2.reduce_mean(
            b2.square(b2.sub(b2.matmul(xr, W2), yr)), name=f"loss{r}")
        (g,) = gradients(b2.graph, [lr_loss], [W2])
        grads.append(g)
    acc = grads[0]
    for g in grads[1:]:
        acc = b2.add(acc, g)
    mean_g = b2.div(acc, b2.constant(jnp.array(4.0), name="four"))
    upd2 = b2.assign(W2, b2.sub(W2, b2.mul(
        b2.constant(jnp.array(lr), name="lr"), mean_g)))
    par = Session(b2.graph)
    shards_x = np.split(np.asarray(X), 4)
    shards_y = np.split(np.asarray(Y), 4)
    feeds = {}
    for r, (xr, yr) in enumerate(phs):
        feeds[xr.ref] = jnp.array(shards_x[r])
        feeds[yr.ref] = jnp.array(shards_y[r])
    for _ in range(10):
        par.run(upd2.ref, feeds)
    W_par = np.asarray(par.variable_value("W"))
    np.testing.assert_allclose(W_par, W_seq, rtol=1e-5, atol=1e-6)


def test_async_data_parallel_still_converges():
    """§7 bottom: hogwild-style replicas updating shared variables from
    client threads (looser guarantee: convergence, not equivalence)."""
    import threading

    X, Y = _data(n=64)
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.zeros((3, 1), "float32"))
    x = b.placeholder("x")
    y = b.placeholder("y")
    loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
    (gW,) = gradients(b.graph, [loss], [W])
    upd = b.assign(W, b.sub(W, b.mul(b.constant(jnp.array(0.03), name="lr"), gW)))
    sess = Session(b.graph)

    def replica(shard):
        xs, ys = shard
        for _ in range(80):
            sess.run(upd.ref, {x.ref: xs, y.ref: ys})

    shards = list(zip(np.split(np.asarray(X), 4), np.split(np.asarray(Y), 4)))
    threads = [threading.Thread(target=replica,
                                args=((jnp.array(sx), jnp.array(sy)),))
               for sx, sy in shards]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    final = float(sess.run(loss.ref, {x.ref: X, y.ref: Y}))
    assert final < 0.05


def test_functional_adamw_matches_graph_adamw():
    X, Y = _data()
    b, W, x, y, loss, op = _regression_graph("adamw", lr=0.05,
                                             weight_decay=0.0)
    sess = Session(b.graph)
    for _ in range(20):
        sess.run(op.ref, {x.ref: X, y.ref: Y})
    w_graph = np.asarray(sess.variable_value("W"))

    def loss_f(w):
        return jnp.mean((X @ w - Y) ** 2)

    params = jnp.zeros((3, 1))
    state = adamw_init(params)
    for _ in range(20):
        g = jax.grad(loss_f)(params)
        params, state = adamw_update(params, g, state, lr=0.05,
                                     weight_decay=0.0, grad_clip=None)
    np.testing.assert_allclose(w_graph, params, rtol=1e-4, atol=1e-5)
