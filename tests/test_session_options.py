"""DESIGN.md §15 SessionOptions consolidation tests.

One object carries every Session knob with one documented resolution
order (explicit > ``REPRO_*`` env > default); the legacy per-field
``Session(...)`` kwargs keep working through a deprecation shim; the
RunSignature derives all options-dependent cache-key components from the
resolved options in one place; and the shared ``launch/cli.py`` builder
turns parsed args into the same object for train.py AND serve.py.
"""
import argparse
import dataclasses

import jax.numpy as jnp
import pytest

import repro.core.session as session_mod
from repro.core import GraphBuilder, Session
from repro.core.executable import RunSignature
from repro.core.options import SessionOptions, parse_guard
from repro.launch.cli import (add_cluster_options, add_engine_options,
                              session_options_from_args)


def _tiny_session(**kw):
    b = GraphBuilder()
    x = b.constant(jnp.ones((2, 2)), name="x")
    out = b.add(x, x, name="out")
    return b, out, Session(b.graph, **kw)


# --- resolution order -------------------------------------------------------

def test_defaults_resolve(monkeypatch):
    for var in ("REPRO_VERIFY", "REPRO_FUSE_REGIONS", "REPRO_FUSE_NUMERICS",
                "REPRO_NUMERICS_GUARD", "REPRO_KERNEL_BACKEND"):
        monkeypatch.delenv(var, raising=False)
    o = SessionOptions().resolve()
    assert (o.verify, o.fuse_regions, o.numerics, o.backend) == (
        "warn", True, "strict", "generic")


def test_env_beats_default_and_explicit_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_FUSE_NUMERICS", "fast")
    monkeypatch.setenv("REPRO_VERIFY", "off")
    monkeypatch.setenv("REPRO_FUSE_REGIONS", "0")
    assert SessionOptions().resolve().numerics == "fast"
    assert SessionOptions().resolve().verify == "off"
    assert SessionOptions().resolve().fuse_regions is False
    o = SessionOptions(numerics="strict", verify="error",
                       fuse_regions=True).resolve()
    assert (o.numerics, o.verify, o.fuse_regions) == ("strict", "error", True)


def test_invalid_values_raise(monkeypatch):
    with pytest.raises(ValueError):
        SessionOptions(numerics="sloppy").resolve()
    with pytest.raises(ValueError):
        SessionOptions(verify="maybe").resolve()
    with pytest.raises(ValueError):
        SessionOptions(backend="cuda-classic").resolve()


def test_standby_string_splits():
    o = SessionOptions(standby="a:1, b:2,").resolve()
    assert o.standby == ("a:1", "b:2")


def test_parse_guard_policies():
    assert parse_guard(True) == (True, None)
    assert parse_guard("0") == (False, None)
    assert parse_guard("off") == (False, None)
    assert parse_guard("sample:8") == (True, 8)
    assert parse_guard(4) == (True, 4)
    with pytest.raises(ValueError):
        parse_guard("sample:0")


# --- legacy-kwarg deprecation shim -----------------------------------------

def test_legacy_kwargs_warn_once_and_fold_into_options():
    session_mod._warned_legacy_kwargs = False
    with pytest.warns(DeprecationWarning, match="SessionOptions"):
        _b, _out, sess = _tiny_session(numerics="fast", parity_guard=False,
                                       fuse_regions=True)
    assert sess.options.numerics == "fast"
    assert sess.numerics == "fast"  # mirrored attr keeps working
    assert sess.parity_guard is False
    sess.close()
    # once per process: the second legacy construction stays quiet
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _b, _out, sess = _tiny_session(numerics="strict")
    sess.close()


def test_explicit_kwarg_overrides_options_field():
    session_mod._warned_legacy_kwargs = True  # silence, tested above
    _b, _out, sess = _tiny_session(
        options=SessionOptions(numerics="strict"), numerics="fast")
    assert sess.options.numerics == "fast"
    sess.close()


# --- RunSignature derives from the resolved options -------------------------

def test_run_signature_tracks_option_fields():
    session_mod._warned_legacy_kwargs = True
    b = GraphBuilder()
    x = b.constant(jnp.ones((2, 2)), name="x")
    out = b.add(x, x, name="out")
    sigs = set()
    for opts in (SessionOptions(),
                 SessionOptions(numerics="fast", parity_guard=False),
                 SessionOptions(backend="pallas"),
                 SessionOptions(fuse_regions=False),
                 SessionOptions(verify="error")):
        sess = Session(b.graph, options=opts)
        sigs.add(RunSignature.for_session(sess, (out.ref,), frozenset()))
        sess.close()
    assert len(sigs) == 5  # every flip re-keys the Executable cache


# --- launch/cli.py shared options builder -----------------------------------

def _parser(**kw):
    ap = argparse.ArgumentParser()
    add_engine_options(ap)
    add_cluster_options(ap, **kw)
    return ap


def test_cli_roundtrip_to_options():
    args = _parser().parse_args(
        ["--numerics", "strict", "--backend", "pallas",
         "--cluster", "h:1,h:2"])
    o = session_options_from_args(args)
    assert o.numerics == "strict"
    assert o.backend == "pallas"
    assert o.cluster == "h:1,h:2"


def test_cli_absent_flags_fall_through_to_env(monkeypatch):
    args = _parser().parse_args([])  # --backend stays None
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    o = session_options_from_args(args)
    assert o.backend is None  # unset: the options resolution order decides
    assert o.resolve().backend == "pallas"


def test_cli_replication_flags():
    args = _parser(replication=True, standby=True).parse_args(
        ["--cluster", "h:1", "--replicas", "4", "--mode", "async",
         "--standby", "h:9"])
    assert (args.replicas, args.mode) == (4, "async")
    o = session_options_from_args(args)
    assert o.resolve().standby == ("h:9",)
    # train/serve share one surface: no replication flags unless asked
    with pytest.raises(SystemExit):
        _parser().parse_args(["--replicas", "4"])


def test_cli_overrides_win():
    args = _parser().parse_args(["--numerics", "fast"])
    o = session_options_from_args(args, numerics="strict", parity_guard=False)
    assert o.numerics == "strict"
    assert o.parity_guard is False
