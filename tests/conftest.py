import os
import sys
import types

# Tests run single-device (the dry-run pins 512 host devices in its own
# process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_stub() -> None:
    """Make ``hypothesis`` optional: in offline environments the 5
    property-based test modules must still *collect* — ``@given`` tests
    skip cleanly and every plain test in those modules keeps running."""
    import pytest

    class _Strategy:
        """Inert stand-in for any hypothesis strategy object."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def given(*_a, **_k):
        def deco(fn):
            # deliberately NOT functools.wraps: the wrapper must expose a
            # no-argument signature so pytest doesn't treat the strategy
            # parameters as missing fixtures
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed; property-based test skipped")

            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _Strategy()

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.assume = lambda *a, **k: True
    mod.note = lambda *a, **k: None
    mod.HealthCheck = _Strategy()
    mod.__is_repro_stub__ = True

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
