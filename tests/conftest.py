import os
import sys

# Tests run single-device (the dry-run pins 512 host devices in its own
# process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
