"""§16 observability: metrics registry, span tracing, clock alignment.

Covers the tentpole surfaces (SpanRecorder → merge_streams → Chrome
trace; MetricsRegistry + the legacy-STATS shim) plus the satellite
guarantees: clock-offset estimation under injected skew, merged-trace
monotonicity, trace-off zero-overhead, and the §9.1 summary round-trip.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import pytest

from repro.core import Session
from repro.core.graph import Graph
from repro.core.ops import GraphBuilder
from repro.core.options import SessionOptions
from repro.obs import export as export_mod
from repro.obs import metrics as metrics_mod
from repro.obs import spans as spans_mod
from repro.obs.metrics import MetricsRegistry, StatsDict


# ---------------------------------------------------------------------------
# metrics registry


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("x.count")
    c.inc()
    c.inc(2)
    assert c.value == 3
    assert reg.counter("x.count") is c  # get-or-create

    g = reg.gauge("x.ts")
    assert g.value is None
    g.set(1.5)
    assert g.value == 1.5

    h = reg.histogram("x.lat")
    for v in range(100):
        h.observe(v / 100.0)
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 0.0 and s["max"] == 0.99
    assert 0.45 <= s["p50"] <= 0.55
    assert s["p99"] >= 0.95

    snap = reg.snapshot()
    assert snap["counters"]["x.count"] == 3
    assert snap["gauges"]["x.ts"] == 1.5
    assert snap["histograms"]["x.lat"]["count"] == 100


def test_histogram_reservoir_bounded():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for v in range(10_000):
        h.observe(float(v))
    assert h.count == 10_000  # exact count survives the bounded window
    assert len(h._recent) == h.RESERVOIR
    # quantiles reflect the recent window, not all of history
    assert h.percentile(50) > 5000


def test_stats_dict_is_registry_backed():
    reg = MetricsRegistry()
    stats = StatsDict("mysub", keys=("calls", "hits"), registry=reg)
    stats["calls"] += 1
    stats["calls"] += 1
    stats["hits"] += 1
    assert stats["calls"] == 2
    assert reg.snapshot()["counters"]["mysub.calls"] == 2
    # undeclared keys raise, like a plain dict
    with pytest.raises(KeyError):
        stats["nope"]
    # the legacy reset idiom works and hits the registry too
    for k in stats:
        stats[k] = 0
    assert stats["calls"] == 0
    assert reg.snapshot()["counters"]["mysub.calls"] == 0
    # late declaration through assignment
    stats["new_key"] = 7
    assert dict(stats) == {"calls": 0, "hits": 0, "new_key": 7}


def test_module_stats_dicts_surface_in_global_registry():
    from repro.core import placement

    before = placement.STATS["place_calls"]
    placement.STATS["place_calls"] += 1
    try:
        snap = metrics_mod.snapshot()
        assert snap["counters"]["placement.place_calls"] == before + 1
    finally:
        placement.STATS["place_calls"] = before


def test_verifier_stats_identity_preserved():
    # analysis/__init__.py re-exports the object; the registry-backed
    # swap must not have broken that aliasing
    import repro.analysis as analysis
    from repro.analysis import verifier

    assert analysis.STATS is verifier.STATS
    assert "verify_calls" in verifier.STATS
    assert "frames" in verifier.STATS  # per-pass keys declared via loop


# ---------------------------------------------------------------------------
# spans + export


def _mm_graph():
    # fed input keeps the pre-fusion constant folder from collapsing the
    # whole graph into one Const node
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.constant(np.eye(4, dtype=np.float32) * 3.0, name="y")
    mm = b.matmul(x, y, name="mm")
    s = b.reduce_sum(mm, name="s")
    return b, s, x


_FEED = np.eye(4, dtype=np.float32) * 2.0  # sum((2I)@(3I)) == 24


def test_traced_run_emits_op_spans_and_chrome_trace(tmp_path):
    b, s, x = _mm_graph()
    sess = Session(b.graph, options=SessionOptions(trace_dir=str(tmp_path)))
    try:
        (val,) = sess.run([s.ref], feed_dict={x.ref: _FEED})
        assert float(np.asarray(val)) == pytest.approx(24.0)
        events = sess._spans.snapshot()
        ops = {e.get("args", {}).get("op") for e in events
               if e["cat"] == spans_mod.CAT_OP}
        assert "MatMul" in ops and "ReduceSum" in ops
        path = sess.export_trace()
        assert path and os.path.exists(path)
        with open(path) as f:
            obj = json.load(f)
        info = export_mod.validate_trace(obj)
        assert info["events"] > 0
        assert "master" in info["processes"]
        names = {e["name"] for e in obj["traceEvents"] if e.get("ph") == "X"}
        assert any(n.startswith("MatMul:") for n in names)
    finally:
        spans_mod.install(None)
        sess.close()


def test_fused_region_is_single_span(tmp_path):
    b, s, x = _mm_graph()
    sess = Session(b.graph, options=SessionOptions(
        trace_dir=str(tmp_path), fuse_regions=True, numerics="fast"))
    try:
        sess.run([s.ref], feed_dict={x.ref: _FEED})
        events = sess._spans.snapshot()
        regions = [e for e in events if e["cat"] == spans_mod.CAT_REGION]
        members = [e for e in events if e["cat"] == spans_mod.CAT_OP
                   and e["name"] in ("mm", "s")]
        if regions:  # fusion actually formed a region on this graph
            # ONE span per region, annotated — no per-member op spans
            assert all(e["args"]["members"] >= 1 for e in regions)
            assert not members
    finally:
        spans_mod.install(None)
        sess.close()


def test_trace_off_is_zero_overhead():
    """Tracing disabled = no recorder anywhere: no global slot, no
    session recorder, and a run records nothing (the disabled path is a
    single ``is None`` check, asserted structurally rather than with a
    flaky wall-clock bound — benchmarks/run.py b15 measures the time)."""
    spans_mod.install(None)
    b, s, x = _mm_graph()
    sess = Session(b.graph)
    try:
        assert sess._spans is None
        assert spans_mod.get() is None
        sess.run([s.ref], feed_dict={x.ref: _FEED})
        assert sess._spans is None
        assert spans_mod.get() is None
    finally:
        sess.close()


def test_merge_streams_lanes_and_offsets():
    t0 = 1000.0
    streams = [
        {"process": "master", "offset_s": 0.0, "events": [
            {"name": "step:0", "cat": spans_mod.CAT_STEP, "device": "master",
             "ts": t0, "dur": 1.0},
        ]},
        # worker clock runs 5s ahead; offset_s subtracts it back
        {"process": "worker-task0", "offset_s": 5.0, "events": [
            {"name": "mm", "cat": spans_mod.CAT_OP,
             "device": "/job:worker/task:0/device:cpu:0",
             "ts": t0 + 5.2, "dur": 0.3, "args": {"op": "MatMul"}},
            {"name": "r", "cat": spans_mod.CAT_WAIT,
             "device": "/job:worker/task:0/device:cpu:0",
             "ts": t0 + 5.5, "dur": 0.1},
        ]},
    ]
    obj = export_mod.merge_streams(streams)
    info = export_mod.validate_trace(obj)
    assert set(info["processes"]) == {"master", "worker-task0"}
    # the wait event landed in the rendezvous lane
    assert any(lane.endswith(export_mod.RENDEZVOUS_LANE)
               for lane in info["lanes"])
    xs = {e["name"]: e for e in obj["traceEvents"] if e.get("ph") == "X"}
    # after offset subtraction the worker op starts 0.2s into the trace
    assert xs["MatMul:mm"]["ts"] == pytest.approx(0.2e6, rel=1e-6)
    assert xs["step:0"]["ts"] == pytest.approx(0.0, abs=1e-6)


def test_merged_trace_monotone_under_synthetic_skew():
    """Satellite 4: a causally-ordered pair (master step wraps a worker
    op) stays ordered in the merged trace when the worker clock is
    skewed, provided the estimated offset is applied."""
    skew = 120.0  # worker clock is 2 minutes ahead
    t0 = 5000.0
    master_events = [{"name": "step:0", "cat": spans_mod.CAT_STEP,
                      "device": "master", "ts": t0, "dur": 2.0}]
    # the worker op physically happened 0.5s after the step started,
    # but its timestamps carry the skew
    worker_events = [{"name": "op", "cat": spans_mod.CAT_OP,
                      "device": "d0", "ts": t0 + 0.5 + skew, "dur": 0.2,
                      "args": {"op": "MatMul"}}]
    # NTP-style estimate from a synthetic heartbeat exchange with 40ms
    # RTT (the fault harness's delay hook inflates RTT the same way):
    t_send, rtt = t0 - 1.0, 0.040
    worker_clock = (t_send + rtt / 2.0) + skew  # replied at the midpoint
    est = worker_clock - (t_send + (t_send + rtt)) / 2.0
    assert abs(est - skew) <= rtt / 2.0  # estimator error bound
    obj = export_mod.merge_streams([
        {"process": "master", "offset_s": 0.0, "events": master_events},
        {"process": "worker-task0", "offset_s": est,
         "events": worker_events}])
    xs = {e["name"]: e for e in obj["traceEvents"] if e.get("ph") == "X"}
    start, end = xs["step:0"]["ts"], xs["step:0"]["ts"] + xs["step:0"]["dur"]
    assert start <= xs["MatMul:op"]["ts"] <= end  # nested, not 2 minutes away
    # without the offset the merge would be wildly non-causal
    bad = export_mod.merge_streams([
        {"process": "master", "offset_s": 0.0, "events": master_events},
        {"process": "worker-task0", "offset_s": 0.0,
         "events": worker_events}])
    bad_xs = {e["name"]: e for e in bad["traceEvents"] if e.get("ph") == "X"}
    assert bad_xs["MatMul:op"]["ts"] > end


def test_master_clock_offset_estimation_with_injected_delay():
    """Satellite 4, live half: Master._note_clock against a Worker whose
    heartbeat is slowed by the fault harness's client-side delay hook —
    the RTT inflation must widen, not corrupt, the estimate."""
    from repro.distrib.master import Master

    m = Master("127.0.0.1:9", heartbeat_interval=0)  # no hb thread
    try:
        skew = 30.0
        # two samples: a slow (fault-delayed) one first, then a tight one
        t = time.time()
        m._note_clock(0, worker_clock=t + skew + 0.25, t_send=t,
                      t_recv=t + 0.5)  # 500ms RTT — the delayed probe
        est_loose = m.clock_offset(0)
        assert abs(est_loose - skew) <= 0.25 + 1e-6
        m._note_clock(0, worker_clock=t + 1.0 + 0.001 + skew,
                      t_send=t + 1.0, t_recv=t + 1.002)  # 2ms RTT
        est_tight = m.clock_offset(0)
        assert abs(est_tight - skew) <= 0.001 + 1e-6
        # a later, looser sample must not displace the tight one
        m._note_clock(0, worker_clock=t + 2.0 + skew + 1.0, t_send=t + 2.0,
                      t_recv=t + 4.0)
        assert m.clock_offset(0) == est_tight
    finally:
        m.stop()


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        export_mod.validate_trace({"not": "a trace"})
    with pytest.raises(ValueError):
        export_mod.validate_trace({"traceEvents": [{"ph": "X"}]})


# ---------------------------------------------------------------------------
# legacy tracer rides the span stream


def test_tracer_adapter_wait_spans():
    from repro.tools.tracing import Tracer

    tr = Tracer()
    t = time.time()
    tr.record("mm", "MatMul", "d0", t, t + 0.001)
    tr.record_wait("recv_x", "d0", t + 0.001, t + 0.010)
    stalls = tr.critical_stalls(threshold_us=100.0)
    assert [e["name"] for e in stalls] == ["recv_x"]
    # a slow *op* is not a stall — only wait spans qualify
    tr.record("big", "MatMul", "d0", t, t + 1.0)
    assert [e["name"] for e in tr.critical_stalls()] == ["recv_x"]


# ---------------------------------------------------------------------------
# §9.1 summary round-trip through train()


def test_train_summary_dir_round_trip(tmp_path):
    from repro.launch.train import train
    from repro.tools.summary import read_events

    train(steps=3, batch=2, seq=16, log_every=10,
          summary_dir=str(tmp_path / "sum"))
    events = read_events(str(tmp_path / "sum"))
    assert len(events["train/loss"]) == 3
    assert len(events["train/tokens_per_sec"]) == 3
    steps = [s for s, _ in events["train/loss"]]
    assert steps == [1, 2, 3]
    assert all(v > 0 for _, v in events["train/tokens_per_sec"])


# ---------------------------------------------------------------------------
# profile CLI


def test_profile_cli_renders_and_validates(tmp_path, capsys):
    from repro.obs import profile as profile_mod

    streams = [{"process": "worker-task0", "offset_s": 0.0, "events": [
        {"name": "mm", "cat": spans_mod.CAT_OP, "device": "d0",
         "ts": 100.0, "dur": 0.001, "args": {"op": "MatMul"}},
        {"name": "recv_x", "cat": spans_mod.CAT_WAIT, "device": "d0",
         "ts": 100.001, "dur": 0.05},
    ]}]
    path = str(tmp_path / "trace.json")
    export_mod.write_trace(path, streams)
    assert profile_mod.main([path, "--validate"]) == 0
    out = capsys.readouterr().out
    assert "MatMul" in out
    assert "recv_x" in out  # the stall table names the blocked node
