"""§13 deterministic fault injection: FaultPlan grammar + protocol hooks.

Everything here runs against an in-process Worker (no subprocess spawn):
the client-side hooks (drop/delay/refuse) fire in the Channel and the
server-side stall_hb fires in the serve loop regardless of process
boundaries.  ``kill`` rules are deliberately never installed in-process —
``os._exit`` would take the test runner with it; the multi-process kill
paths live in test_partial_replacement.py.
"""
import time

import pytest

from repro.distrib import faults
from repro.distrib.faults import FaultPlan, FaultRule, InjectedFault
from repro.distrib.protocol import Channel, ProtocolError
from repro.distrib.worker import Worker


@pytest.fixture(scope="module")
def worker():
    w = Worker(task=0)
    w.start()
    yield w
    w.stop()


@pytest.fixture(autouse=True)
def _clear_plan():
    # plans are process-global: never leak one into the next test
    yield
    faults.install(None)


@pytest.fixture
def channel(worker):
    ch = Channel(worker.host, worker.port)
    yield ch
    ch.close()


# ---------------------------------------------------------------------------
# grammar


def test_parse_describe_roundtrip():
    spec = "seed=7;kill:step=3,task=1;refuse:port=7077,times=2;delay:ms=5"
    plan = FaultPlan.parse(spec)
    assert plan.seed == 7
    assert [r.action for r in plan.rules] == ["kill", "refuse", "delay"]
    # describe() is the canonical replay spec: parsing it again is stable
    again = FaultPlan.parse(plan.describe())
    assert again.describe() == plan.describe()
    assert [r.spec() for r in again.rules] == [r.spec() for r in plan.rules]


def test_bad_rules_rejected():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultPlan.parse("explode:times=1")
    with pytest.raises(ValueError, match="kill rule requires"):
        FaultPlan.parse("kill:task=1")
    with pytest.raises(ValueError, match="delay rule requires"):
        FaultPlan.parse("delay:rpc=heartbeat")


def test_jitter_rng_replays_with_seed():
    faults.install("seed=42;delay:ms=1,rpc=never")
    a = [faults.jitter_rng().random() for _ in range(5)]
    faults.install("seed=42;delay:ms=1,rpc=never")
    b = [faults.jitter_rng().random() for _ in range(5)]
    assert a == b  # retry-backoff timing replays with the plan


def test_after_window_skips_first_matches():
    rule = FaultRule("drop", rpc="heartbeat", times=1, after=2)
    assert not rule._consume() and not rule._consume()  # skipped window
    assert rule._consume()       # fires on the 3rd match
    assert not rule._consume()   # times exhausted


# ---------------------------------------------------------------------------
# client-side hooks through a real Channel


def test_drop_retried_for_idempotent_rpc(channel):
    plan = faults.install("drop:rpc=heartbeat,times=2")
    rep = channel.call("heartbeat", _timeout=30.0)
    assert rep["task"] == 0
    assert plan.rules[0].fired == 2  # both injected drops retried through


def test_drop_with_single_attempt_surfaces(channel):
    faults.install("drop:rpc=heartbeat")
    with pytest.raises(InjectedFault):
        # the heartbeat monitor's contract: its loop is the retry, so a
        # single-attempt probe must see the raw failure
        channel.call("heartbeat", _attempts=1)
    assert channel.call("heartbeat")["task"] == 0  # rule exhausted


def test_run_graph_drop_is_fail_fast(channel):
    plan = faults.install("drop:rpc=run_graph,times=3")
    with pytest.raises(InjectedFault):
        channel.call("run_graph", handle="nope", execution_id="e0")
    # non-idempotent: exactly one attempt, no retry budget consumed
    assert plan.rules[0].fired == 1


def test_injected_fault_is_a_transport_error():
    # the runtime's failure classification hinges on this: an injected
    # drop must condemn exactly like a real dead connection
    assert issubclass(InjectedFault, ConnectionError)
    assert issubclass(InjectedFault, OSError)


def test_key_substring_targets_individual_tensors():
    faults.install(FaultPlan(
        [FaultRule("drop", rpc="recv_tensor", key="|pred")]))
    # non-matching key: no fire
    faults.on_call("recv_tensor", {"key": "e1|data;t0;t1;0"}, "h", 1)
    with pytest.raises(InjectedFault):
        faults.on_call("recv_tensor", {"key": "e1|pred;t0;t1;0"}, "h", 1)


def test_refused_connections_retry_then_succeed(worker):
    # satellite: Channel connect retry + backoff, covered with the
    # injector refusing K times before letting the dial through
    plan = faults.install(f"refuse:times=2,port={worker.port}")
    ch = Channel(worker.host, worker.port)
    try:
        assert ch.call("heartbeat")["task"] == 0
    finally:
        ch.close()
    assert plan.rules[0].fired == 2


def test_refusals_beyond_attempts_surface(worker):
    faults.install("refuse:times=99")
    ch = Channel(worker.host, worker.port, connect_attempts=2)
    try:
        with pytest.raises(ConnectionRefusedError):
            ch.call("heartbeat", _attempts=1)
    finally:
        ch.close()


def test_refuse_scoped_to_other_port_never_fires(worker):
    plan = faults.install(f"refuse:times=1,port={worker.port + 1}")
    ch = Channel(worker.host, worker.port)
    try:
        assert ch.call("heartbeat")["task"] == 0
    finally:
        ch.close()
    assert plan.rules[0].fired == 0


def test_delay_injects_latency(channel):
    faults.install("delay:ms=150,rpc=heartbeat")
    t0 = time.monotonic()
    channel.call("heartbeat")
    assert time.monotonic() - t0 >= 0.15


# ---------------------------------------------------------------------------
# server-side stall_hb through a real serve loop


def test_stall_hb_drops_without_reply_then_recovers(channel):
    plan = faults.install("stall_hb:times=2,task=0")
    with pytest.raises(ProtocolError, match="mid-call"):
        channel.call("heartbeat", _attempts=1)
    # second stall still pending: the default idempotent retry budget
    # rides through it and reaches the (perfectly healthy) worker
    assert channel.call("heartbeat")["task"] == 0
    assert plan.rules[0].fired == 2


def test_stall_hb_scoped_to_other_task_never_fires(channel):
    plan = faults.install("stall_hb:times=1,task=5")
    assert channel.call("heartbeat", _attempts=1)["task"] == 0
    assert plan.rules[0].fired == 0
