"""§2 'Operations and Kernels': per-device kernel registration — the
Pallas matmul becomes the MatMul kernel on tpu-kind devices."""
import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Session
from repro.core.ops import REGISTRY
from repro.kernels.ops import register_tpu_kernels


def test_pallas_matmul_dispatched_for_tpu_device_kind():
    register_tpu_kernels(interpret=True)  # interpret: kernel body on CPU
    assert "tpu" in REGISTRY["MatMul"].kernels

    b = GraphBuilder()
    a = b.constant(jnp.ones((128, 128)), name="a")
    m = b.matmul(a, a, name="mm")
    out = b.reduce_sum(m)

    # run the kernel through the executor with a tpu device_kind context
    from repro.core.executor import ExecutionContext, run_kernel
    from repro.runtime.containers import VariableStore

    ctx = ExecutionContext(variables=VariableStore(), device_kind="tpu")
    (res,) = run_kernel(ctx, b.graph.nodes["mm"],
                        [jnp.ones((128, 128)), jnp.ones((128, 128))])
    np.testing.assert_allclose(res, 128.0 * np.ones((128, 128)), rtol=1e-5)

    # cpu context still uses the reference kernel
    ctx_cpu = ExecutionContext(variables=VariableStore(), device_kind="cpu")
    (res2,) = run_kernel(ctx_cpu, b.graph.nodes["mm"],
                         [jnp.ones((128, 128)), jnp.ones((128, 128))])
    np.testing.assert_allclose(res, res2, rtol=1e-5)
