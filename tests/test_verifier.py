"""§14 static graph verifier: one known-bad fixture per pass asserting the
exact diagnostic code, the Session/Executable wiring (modes, caching), the
lint CLI, suppression annotations, and the false-positive guard that the
shipped graphs verify clean under verify="error"."""
import os
import subprocess
import sys
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CODES, STATS, GraphVerifyWarning,
                            task_slice_diagnostics, verify_graph)
from repro.analysis import lint as lint_cli
from repro.analysis import selftest
from repro.core import GraphBuilder, Session, cond, while_loop
from repro.core import partition as pt
from repro.core.graph import GraphError
from repro.runtime.devices import DeviceSet

pytestmark = pytest.mark.verifier

T0 = "/job:worker/task:0"
T1 = "/job:worker/task:1"
D0 = "/job:worker/task:0/device:cpu:0"
D1 = "/job:worker/task:1/device:cpu:0"


def codes(report):
    return [d.code for d in report.diagnostics]


# --- pass 1: variable races -------------------------------------------------
def test_v101_unordered_writes():
    rep = verify_graph(selftest.bad_graph().graph)
    assert "V101" in codes(rep)
    v101 = next(d for d in rep.diagnostics if d.code == "V101")
    assert "racy_a" in v101.nodes and "racy_b" in v101.nodes
    assert v101.severity == "error" and v101.pass_name == "races"


def test_v101_fixed_by_control_edge():
    rep = verify_graph(selftest.clean_graph().graph)
    assert codes(rep) == []


def test_v102_restore_unordered_with_read():
    b = GraphBuilder()
    v = b.variable("v", init_value=jnp.zeros((2,), "float32"))
    b.neg(v, name="read_v")
    b.restore([v], "/tmp/ckpt", name="restore_v")
    rep = verify_graph(b.graph)
    assert "V102" in codes(rep)
    d = next(d for d in rep.diagnostics if d.code == "V102")
    assert "restore_v" in d.nodes


def test_v103_assign_to_non_variable():
    b = GraphBuilder()
    c = b.constant(jnp.zeros((2,)), name="c")
    b.graph.add_node("Assign", [c, c], name="bad_assign")
    assert "V103" in codes(verify_graph(b.graph))


# --- pass 2: send/recv + deadlock ------------------------------------------
def test_c201_orphan_recv():
    rep = verify_graph(selftest.bad_graph().graph)
    d = next(d for d in rep.diagnostics if d.code == "C201")
    assert "orphan_recv" in d.nodes and d.severity == "error"


def test_c203_duplicate_send():
    b = GraphBuilder()
    c = b.constant(jnp.array(1.0), name="c")
    for n in ("s1", "s2"):
        b.graph.add_node("Send", [c], name=n,
                         attrs={"rendezvous_key": "k;a;b;0"})
    b.graph.add_node("Recv", [], name="r",
                     attrs={"rendezvous_key": "k;a;b;0"})
    assert "C203" in codes(verify_graph(b.graph))


def test_c204_send_in_loop_recv_at_root():
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    while_loop(b, lambda i: b.less(i, lim),
               lambda i: [b.add(i, one, name="inc")], [i0])
    b.graph.add_node("Send", ["inc"], name="s",
                     attrs={"rendezvous_key": "k;a;b;0"})
    b.graph.add_node("Recv", [], name="r",
                     attrs={"rendezvous_key": "k;a;b;0"})
    rep = verify_graph(b.graph)
    assert "C204" in codes(rep)
    d = next(d for d in rep.diagnostics if d.code == "C204")
    assert "s" in d.nodes and "r" in d.nodes


def test_c206_pingpong_deadlock_cycle():
    b = GraphBuilder()
    g = b.graph
    ra = g.add_node("Recv", [], name="ra", attrs={"rendezvous_key": "kb"})
    g.add_node("Send", [ra], name="sa", attrs={"rendezvous_key": "ka"})
    rb = g.add_node("Recv", [], name="rb", attrs={"rendezvous_key": "ka"})
    g.add_node("Send", [rb], name="sb", attrs={"rendezvous_key": "kb"})
    rep = verify_graph(g)
    d = next(d for d in rep.diagnostics if d.code == "C206")
    assert set(d.nodes) == {"ra", "sa", "rb", "sb"}


# --- pass 3: frame well-formedness -----------------------------------------
def test_f301_enter_without_frame_attr():
    b = GraphBuilder()
    c = b.constant(jnp.array(1.0), name="c")
    b.graph.add_node("Enter", [c], name="e")
    rep = verify_graph(b.graph)
    assert "F301" in codes(rep)
    assert any("e" in d.nodes for d in rep.diagnostics if d.code == "F301")


def test_f302_predicate_off_home_device():
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    while_loop(b, lambda i: b.less(i, lim, name="pred"),
               lambda i: [b.add(i, one, name="inc")], [i0])
    placement = {n: D0 for n in b.graph.nodes}
    placement["pred"] = D1
    rep = verify_graph(b.graph, placement=placement)
    d = next(d for d in rep.diagnostics if d.code == "F302")
    assert "pred" in d.nodes and D0 in d.devices and D1 in d.devices


def _nested_loops():
    """Inner loop seeded from the outer loop variable — genuinely nested
    (static frame depth 2), unlike an inner loop with root-frame inits."""
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(2), name="lim")
    one = b.constant(jnp.array(1), name="one")

    def outer_body(i):
        inner = while_loop(b, lambda j: b.less(j, lim),
                           lambda j: [b.add(j, one, name="inner_inc")],
                           [i], name="inner")
        return [inner[0]]

    while_loop(b, lambda i: b.less(i, lim), outer_body, [i0], name="outer")
    return b


def test_f303_nested_loop_straddles_devices():
    b = _nested_loops()
    placement = {n: D0 for n in b.graph.nodes}
    placement["inner_inc"] = D1
    rep = verify_graph(b.graph, placement=placement)
    d = next(d for d in rep.diagnostics if d.code == "F303")
    assert D0 in d.devices and D1 in d.devices


# --- pass 4: static shapes/dtypes ------------------------------------------
def test_s401_matmul_shape_mismatch():
    b = GraphBuilder()
    x = b.placeholder("x", shape=(2, 3), dtype=jnp.float32)
    y = b.placeholder("y", shape=(4, 5), dtype=jnp.float32)
    b.matmul(x, y, name="mm")
    rep = verify_graph(b.graph)
    d = next(d for d in rep.diagnostics if d.code == "S401")
    assert "mm" in d.nodes and d.severity == "error"


def test_s401_clean_when_shapes_agree():
    b = GraphBuilder()
    x = b.placeholder("x", shape=(2, 3), dtype=jnp.float32)
    y = b.placeholder("y", shape=(3, 5), dtype=jnp.float32)
    b.matmul(x, y, name="mm")
    assert codes(verify_graph(b.graph)) == []


def test_s402_assign_changes_variable_shape():
    b = GraphBuilder()
    v = b.variable("v", init_value=jnp.zeros((2,), "float32"))
    b.assign(v, b.constant(jnp.zeros((3,), "float32")), name="grow")
    rep = verify_graph(b.graph)
    d = next(d for d in rep.diagnostics if d.code == "S402")
    assert d.severity == "warning" and "grow" in d.nodes


# --- pass 5: deadness -------------------------------------------------------
def _cond_graph():
    b = GraphBuilder()
    p = b.placeholder("p")
    x = b.constant(jnp.array(2.0), name="x")
    res = cond(b, p,
               lambda t: [b.mul(t, t, name="tb")],
               lambda f: [b.neg(f, name="fb")], [x])
    return b, res


def test_d501_dead_branch_fetch():
    b, _ = _cond_graph()
    rep = verify_graph(b.graph, fetches=["fb:0"], feed_keys=["p:0"])
    d = next(d for d in rep.diagnostics if d.code == "D501")
    assert "fb" in d.nodes and d.severity == "warning"


def test_d501_clean_when_fetching_merge():
    b, res = _cond_graph()
    rep = verify_graph(b.graph, fetches=res, feed_keys=["p:0"])
    assert "D501" not in codes(rep)


# --- wire-plan slice containment -------------------------------------------
def test_p601_cross_task_edge_without_sendrecv():
    b = GraphBuilder()
    c = b.constant(jnp.array(1.0), name="c")
    b.neg(c, name="n")
    diags = task_slice_diagnostics(b.graph, {"w:0": {"c"}, "w:1": {"n"}})
    assert [d.code for d in diags] == ["P601"]
    assert set(diags[0].nodes) == {"n", "c"}


# --- Session wiring: modes, caching, signature -----------------------------
def _racy_fetches():
    b = selftest.bad_graph()
    return b, ["racy_a:0", "racy_b:0"]


def test_session_verify_error_raises_before_execution():
    b, fetches = _racy_fetches()
    with pytest.raises(GraphError, match="V101"):
        Session(b.graph, verify="error").run(fetches)


def test_session_verify_warn_warns_and_runs():
    b, fetches = _racy_fetches()
    with pytest.warns(GraphVerifyWarning, match="V101"):
        vals = Session(b.graph, verify="warn").run(fetches)
    assert len(vals) == 2


def test_session_verify_off_is_silent():
    b, fetches = _racy_fetches()
    with warnings.catch_warnings():
        warnings.simplefilter("error", GraphVerifyWarning)
        Session(b.graph, verify="off").run(fetches)


def test_session_verify_mode_validated():
    with pytest.raises(ValueError, match="verify"):
        Session(GraphBuilder().graph, verify="bogus")


def test_session_verify_mode_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "error")
    assert Session(GraphBuilder().graph).verify == "error"
    monkeypatch.delenv("REPRO_VERIFY")
    assert Session(GraphBuilder().graph).verify == "warn"


def test_cache_hit_reruns_no_analysis():
    b = selftest.clean_graph()
    sess = Session(b.graph)
    before = dict(STATS)
    sess.run("second:0")
    after_first = dict(STATS)
    assert after_first["verify_calls"] == before["verify_calls"] + 1
    for pname in ("races", "sendrecv", "frames", "shapes", "deadness"):
        assert after_first[pname] == before[pname] + 1
    sess.run("second:0")
    assert sess.cache_stats["hits"] >= 1
    assert dict(STATS) == after_first  # cache hit: zero analysis re-run


def test_flipping_verify_mode_rebuilds_and_enforces():
    b, fetches = _racy_fetches()
    sess = Session(b.graph, verify="warn")
    with pytest.warns(GraphVerifyWarning):
        sess.run(fetches)
    sess.verify = "error"  # part of RunSignature: must rebuild + raise
    with pytest.raises(GraphError, match="V101"):
        sess.run(fetches)


def test_executable_report_single_vs_partitioned():
    b = GraphBuilder()
    c0 = b.constant(jnp.array(1.0), name="c0", device=T0)
    c1 = b.constant(jnp.array(2.0), name="c1", device=T1)
    s = b.add(c0, c1, name="s", device=T0)
    sess = Session(b.graph, devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
                   verify="error")
    exe = sess.executable([s.ref], frozenset())
    assert exe.verify_report.where == "partitioned plan"
    assert exe.verify_report.errors() == []

    b2 = selftest.clean_graph()
    sess2 = Session(b2.graph, verify="error")
    from repro.core import TensorRef
    exe2 = sess2.executable([TensorRef("second", 0)], frozenset())
    assert exe2.verify_report.where == "pruned graph"


# --- suppression escape hatch ----------------------------------------------
def test_verify_ignore_annotation_suppresses():
    b = selftest.bad_graph()
    # verify: ignore[V101] — deliberate racy fixture, keep the C201
    b.graph.nodes["racy_a"].attrs["verify_ignore"] = ("V101",)
    rep = verify_graph(b.graph)
    assert "V101" not in codes(rep)
    assert "C201" in codes(rep)
    assert rep.suppressed == 1


def test_verify_ignore_is_code_specific():
    b = selftest.bad_graph()
    b.graph.nodes["racy_a"].attrs["verify_ignore"] = ("C201",)
    assert "V101" in codes(verify_graph(b.graph))


# --- false-positive guard: shipped graphs are clean under "error" ----------
def test_single_device_loop_and_cond_clean_under_error():
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(4), name="lim")
    one = b.constant(jnp.array(1), name="one")
    outs = while_loop(b, lambda i: b.less(i, lim),
                      lambda i: [b.add(i, one, name="inc")], [i0])
    assert int(Session(b.graph, verify="error").run(outs)[0]) == 4

    b2, res = _cond_graph()
    sess = Session(b2.graph, verify="error")
    from repro.core import TensorRef
    assert float(sess.run(res, {TensorRef("p", 0): jnp.array(True)})[0]) == 4.0


def test_multi_device_loop_clean_under_error():
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0", device=T0)
    acc0 = b.constant(jnp.array(0.0), name="acc0", device=T0)
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    half = b.constant(jnp.array(0.5), name="half")
    outs = while_loop(
        b, lambda i, a: b.less(i, lim),
        lambda i, a: [b.add(i, one, name="inc", device=T1),
                      b.add(a, half, name="acc", device=T0)],
        [i0, acc0])
    sess = Session(b.graph, devices=DeviceSet.make_cluster(2, 1, kind="cpu"),
                   verify="error")
    vals = sess.run(outs)
    assert int(vals[0]) == 3 and float(vals[1]) == 1.5


def test_lint_suite_shipped_graphs_clean():
    assert lint_cli.main(["--suite"]) == 0


# --- lint CLI ---------------------------------------------------------------
def test_lint_cli_fails_on_seeded_bad_factory(capsys):
    rc = lint_cli.main(["repro.analysis.selftest:bad_graph"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "V101" in out and "C201" in out and "FAILED" in out


def test_lint_cli_passes_clean_factory():
    assert lint_cli.main(["repro.analysis.selftest:clean_graph"]) == 0


def test_lint_cli_warn_mode_never_fails():
    assert lint_cli.main(["repro.analysis.selftest:bad_graph",
                          "--mode", "warn"]) == 0


def test_lint_cli_writes_diagnostic_dot(tmp_path):
    rc = lint_cli.main(["repro.analysis.selftest:bad_graph",
                        "--dot", str(tmp_path)])
    assert rc == 1
    dots = list(tmp_path.glob("*.dot"))
    assert dots
    text = dots[0].read_text()
    assert "color=red" in text and "V101" in text


def test_lint_cli_subprocess_entrypoint():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         "repro.analysis.selftest:bad_graph"],
        cwd=root, env=env, capture_output=True, text=True, timeout=120)
    assert p.returncode == 1, p.stdout + p.stderr


# --- graphviz rendering -----------------------------------------------------
def test_graphviz_renders_diagnostics_red():
    from repro.tools import graphviz as gv
    b = selftest.bad_graph()
    rep = verify_graph(b.graph)
    node_dot = gv.to_dot_diagnostics(b.graph, rep.diagnostics)
    assert "color=red" in node_dot and "V101" in node_dot
    block_dot = gv.to_dot(b.graph, diagnostics=rep.diagnostics)
    assert "color=red" in block_dot and "C201" in block_dot


def test_graphviz_clean_graph_has_no_red():
    from repro.tools import graphviz as gv
    b = selftest.clean_graph()
    rep = verify_graph(b.graph)
    assert "color=red" not in gv.to_dot_diagnostics(b.graph, rep.diagnostics)


# --- satellite 6: structural errors name nodes + devices -------------------
def test_partition_nested_straddle_error_names_nodes_and_devices():
    b = _nested_loops()
    placement = {n: D0 for n in b.graph.nodes}
    placement["inner_inc"] = D1
    with pytest.raises(GraphError) as ei:
        pt.partition(b.graph, placement)
    msg = str(ei.value)
    # which cross-device nested edge is reported first depends on
    # traversal order; the frame path and both devices are always named
    assert "F303" in msg and D0 in msg and D1 in msg and "outer/inner" in msg


def test_placement_loop_predicate_conflict_names_f302():
    from repro.core import placement as pl
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    while_loop(b, lambda i: b.less(i, lim, name="pred"),
               lambda i: [b.add(i, one, name="inc")], [i0])
    b.graph.nodes["pred"].device = T1
    ln = next(iter(b.graph.loop_specs))
    b.graph.nodes[b.graph.loop_specs[ln].switch_names[0]].device = T0
    with pytest.raises(pl.PlacementError) as ei:
        pl.place(b.graph, DeviceSet.make_cluster(2, 1, kind="cpu"))
    msg = str(ei.value)
    assert "F302" in msg and "pred" in msg and T1 in msg


# --- code table hygiene -----------------------------------------------------
def test_code_table_is_stable_api():
    for code, (pass_name, severity, desc) in CODES.items():
        assert severity in ("error", "warning")
        assert pass_name and desc
    assert {"V101", "V102", "C201", "C206", "F301", "F302", "F303",
            "S401", "D501", "P601"} <= set(CODES)
