"""§4.4 distributed control flow: loops/conds whose bodies span devices.

The paper: "if the loop contains nodes assigned to multiple devices,
TensorFlow partitions the loop into distributed execution across devices"
— the partitioner replicates the frame's control skeleton per device and
broadcasts the loop predicate from the frame's home device once per
iteration (DESIGN.md §8).  These tests pin the contract: a multi-device
loop partitions without raising, runs through the cached Executable path,
and matches the single-device execution bit-for-bit.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, TensorRef, while_loop, cond
from repro.core import partition as pt
from repro.runtime.devices import DeviceSet

T0 = "/job:worker/task:0"
T1 = "/job:worker/task:1"


def _two_workers():
    return DeviceSet.make_cluster(2, 1, kind="cpu")


def _split_loop(split: bool, limit=5):
    """while (i < limit): i += 1; acc += f(i) — body straddles two tasks
    when ``split`` (the increment on task:1, the accumulate on task:0)."""
    b = GraphBuilder()
    d0, d1 = (T0, T1) if split else (None, None)
    i0 = b.constant(jnp.array(0), name="i0", device=d0)
    acc0 = b.constant(jnp.array(0.0), name="acc0", device=d0)
    lim = b.constant(jnp.array(limit), name="lim")
    one = b.constant(jnp.array(1), name="one")

    def cnd(i, a):
        return b.less(i, lim)

    def body(i, a):
        ii = b.add(i, one, name="body/inc", device=d1)
        sq = b.mul(b.cast(i, "float32"), b.cast(i, "float32"),
                   name="body/sq", device=d1)
        aa = b.add(a, sq, name="body/acc", device=d0)
        return [ii, aa]

    return b, while_loop(b, cnd, body, [i0, acc0])


def test_two_device_while_partitions_and_matches_single_bitwise():
    b1, outs_s = _split_loop(split=False)
    single = Session(b1.graph).run(outs_s)
    b2, outs_m = _split_loop(split=True)
    sess = Session(b2.graph, devices=_two_workers())
    multi = sess.run(outs_m)
    # genuinely distributed: the body spans both workers and the loop
    # frame was replicated (a ctl skeleton exists on the non-home device)
    exe = sess.executable(outs_m, set())
    p = exe.partitioned
    assert p.placement["body/inc"] != p.placement["body/acc"]
    assert any("/ctl" in n for n in p.graph.nodes), "frame not replicated"
    assert int(multi[0]) == int(single[0]) == 5
    np.testing.assert_array_equal(np.asarray(multi[1]), np.asarray(single[1]))


def test_two_device_while_parity_fast_numerics(monkeypatch):
    monkeypatch.setenv("REPRO_FUSE_NUMERICS", "fast")
    b1, outs_s = _split_loop(split=False, limit=7)
    single = Session(b1.graph).run(outs_s)
    b2, outs_m = _split_loop(split=True, limit=7)
    multi = Session(b2.graph, devices=_two_workers()).run(outs_m)
    assert int(multi[0]) == int(single[0]) == 7
    np.testing.assert_array_equal(np.asarray(multi[1]), np.asarray(single[1]))


def test_two_device_while_runs_through_cached_executable():
    b, outs = _split_loop(split=True)
    sess = Session(b.graph, devices=_two_workers())
    first = sess.run(outs)
    second = sess.run(outs)
    assert sess.cache_stats["hits"] >= 1  # §3.2 "caches these graphs"
    np.testing.assert_array_equal(np.asarray(first[1]), np.asarray(second[1]))


def test_two_device_vector_state_loop():
    """Loop-carried vector state crossing devices every iteration."""
    def build(split):
        b = GraphBuilder()
        d0, d1 = (T0, T1) if split else (None, None)
        x0 = b.constant(jnp.linspace(0.1, 1.0, 8), name="x0", device=d0)
        i0 = b.constant(jnp.array(0), name="i0", device=d0)
        lim = b.constant(jnp.array(4), name="lim")
        one = b.constant(jnp.array(1), name="one")
        outs = while_loop(
            b, lambda i, x: b.less(i, lim),
            lambda i, x: [b.add(i, one, name="inc", device=d0),
                          b.add(b.mul(x, x, name="sq", device=d1), x,
                                name="upd", device=d1)],
            [i0, x0])
        return b, outs

    b1, o1 = build(False)
    b2, o2 = build(True)
    single = Session(b1.graph).run(o1)
    multi = Session(b2.graph, devices=_two_workers()).run(o2)
    np.testing.assert_array_equal(np.asarray(multi[1]), np.asarray(single[1]))


def test_cross_device_cond_both_branches():
    """Branches on different devices: deadness crosses the wire (§4.4)."""
    def build(split):
        b = GraphBuilder()
        d0, d1 = (T0, T1) if split else (None, None)
        p = b.placeholder("p")
        x = b.constant(jnp.array(3.0), name="x", device=d0)
        res = cond(b, p,
                   lambda t: [b.mul(t, t, name="tb", device=d1)],
                   lambda f: [b.neg(f, name="fb", device=d0)], [x])
        return b, res

    b2, res = build(True)
    sess = Session(b2.graph, devices=_two_workers())
    assert float(sess.run(res, {TensorRef("p", 0): jnp.array(True)})[0]) == 9.0
    assert float(sess.run(res, {TensorRef("p", 0): jnp.array(False)})[0]) == -3.0


def test_two_device_loop_under_fed_placeholder():
    """The loop bound arrives via feed: prune stops at the fed edge and the
    per-signature Executable reruns with different bounds (§4.2)."""
    b = GraphBuilder()
    limp = b.placeholder("lim")
    i0 = b.constant(jnp.array(0), name="i0", device=T0)
    one = b.constant(jnp.array(1), name="one")
    outs = while_loop(b, lambda i: b.less(i, limp),
                      lambda i: [b.add(i, one, name="inc", device=T1)],
                      [i0])
    sess = Session(b.graph, devices=_two_workers())
    assert int(sess.run(outs, {limp.ref: jnp.array(3)})[0]) == 3
    assert int(sess.run(outs, {limp.ref: jnp.array(7)})[0]) == 7
    assert sess.cache_stats["hits"] >= 1


def test_topo_sort_on_back_edged_multi_device_graph():
    """The previous crash path: topo_sort over a placed, partitioned loop
    graph returns a valid order instead of raising (back edges are
    non-ordering; §4.4)."""
    b, outs = _split_loop(split=True)
    g = b.graph
    order = g.topo_sort()
    assert sorted(order) == sorted(g.nodes)
    pos = {n: i for i, n in enumerate(order)}
    for node in g.nodes.values():
        for d in g.deps(node):
            if g.nodes[d].op == "NextIteration":
                continue  # the one legal back edge
            assert pos[d] < pos[node.name], f"{d} must precede {node.name}"
    # and the partitioned graph (ctl skeleton + tokened Recvs) sorts too
    from repro.core import placement as pl

    devs = _two_workers()
    place = pl.place(g, devs)
    parted = pt.partition(g, place)
    order2 = parted.graph.topo_sort()
    assert sorted(order2) == sorted(parted.graph.nodes)


def test_multi_device_loop_strict_vs_unfused_escape_hatch():
    """fuse_regions=False (the escape hatch) agrees with the default."""
    b1, o1 = _split_loop(split=True)
    fused = Session(b1.graph, devices=_two_workers()).run(o1)
    b2, o2 = _split_loop(split=True)
    unfused = Session(b2.graph, devices=_two_workers(),
                      fuse_regions=False).run(o2)
    np.testing.assert_array_equal(np.asarray(fused[1]), np.asarray(unfused[1]))


def test_zero_iteration_two_device_loop():
    """Predicate false on iteration 0: every device must still terminate
    (the broadcast pred kills the replicated skeletons immediately)."""
    b, outs = _split_loop(split=True, limit=0)
    multi = Session(b.graph, devices=_two_workers()).run(outs)
    assert int(multi[0]) == 0 and float(multi[1]) == 0.0


def test_control_edge_out_of_loop_frame_to_other_device():
    """Regression: a control edge whose producer lives inside a loop frame
    and whose consumer sits at root depth on ANOTHER device used to hang —
    the partitioner materialised a frame-blind ctok Const whose delivery
    could never satisfy the consumer's exec-depth check.  The edge is now
    routed through an Exit-gated token: the consumer fires exactly once,
    after the final iteration of the producer."""
    b, outs = _split_loop(split=True, limit=3)
    after = b.constant(jnp.array(7.0), name="after", device=T0)
    gated = b.graph.add_node("Add", [after, after], name="gated",
                             control_inputs=["body/inc"], device=T0)
    sess = Session(b.graph, devices=_two_workers())
    exe = sess.executable([outs[0], outs[1], gated.ref], frozenset())
    vals = exe.run({}, timeout=20)  # bounded: a regression hangs, not fails
    assert int(vals[0]) == 3
    assert float(vals[1]) == 0.0 + 1.0 + 4.0  # 0^2 + 1^2 + 2^2
    assert float(vals[2]) == 14.0
    # the gate is structural: an Exit-gated token exists in the partition
    p = exe.partitioned
    assert any(p.graph.nodes[n].op == "Exit" and "/ctl_exit" in n
               for n in p.graph.nodes), "control edge not Exit-gated"


def test_same_frame_cross_device_control_edge():
    """A control edge between two body nodes on different devices must be
    honoured per iteration (token rides the frame's iteration skeleton)."""
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0", device=T0)
    acc0 = b.constant(jnp.array(0.0), name="acc0", device=T0)
    lim = b.constant(jnp.array(4), name="lim")
    half = b.constant(jnp.array(0.5), name="half")
    one = b.constant(jnp.array(1), name="one")

    def body(i, a):
        ii = b.add(i, one, name="body/inc", device=T1)
        aa = b.graph.add_node("Add", [a, half], name="body/acc",
                              control_inputs=["body/inc"], device=T0)
        return [ii, aa]

    outs = while_loop(b, lambda i, a: b.less(i, lim), body, [i0, acc0])
    sess = Session(b.graph, devices=_two_workers())
    vals = sess.run(outs)
    assert int(vals[0]) == 4 and float(vals[1]) == 2.0
