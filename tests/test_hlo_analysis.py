"""The trip-count-aware HLO analyzer that feeds §Roofline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HloAnalyzer, analyze_text, parse_module


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    L, M, K = 24, 64, 128

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return jnp.sum(c)

    comp = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                    jax.ShapeDtypeStruct((L, K, K), jnp.float32))
    st = analyze_text(comp.as_text(), 1)
    want = L * 2 * M * K * K
    assert abs(st.flops - want) / want < 0.05
    assert any(trips == L for _, trips in st.loops)


def test_nested_scan_multiplies_both_levels():
    Lo, Li, M = 4, 6, 32

    def f(x, w):
        def outer(c, wo):
            def inner(ci, _):
                return jnp.tanh(ci @ wo), None
            ci, _ = jax.lax.scan(inner, c, None, length=Li)
            return ci, None
        c, _ = jax.lax.scan(outer, x, w)
        return jnp.sum(c)

    comp = _compile(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                    jax.ShapeDtypeStruct((Lo, M, M), jnp.float32))
    st = analyze_text(comp.as_text(), 1)
    want = Lo * Li * 2 * M * M * M
    assert abs(st.flops - want) / want < 0.05


def test_no_loop_plain_dot():
    def f(a, b):
        return a @ b

    comp = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                    jax.ShapeDtypeStruct((256, 64), jnp.float32))
    st = analyze_text(comp.as_text(), 1)
    want = 2 * 128 * 256 * 64
    assert abs(st.flops - want) / want < 0.01
    assert st.collective_bytes == 0


def test_parse_module_finds_computations():
    def f(x):
        return jnp.sum(jnp.tanh(x))

    comp = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    comps = parse_module(comp.as_text())
    assert comps
    az = HloAnalyzer(comp.as_text())
    assert az.entry in comps


def test_collective_ring_model():
    """all-reduce across 4 shards: wire bytes = 2*(g-1)/g * result."""
    import os
    devs = jax.devices()
    if len(devs) < 2:
        # single-device CI: synthesize HLO text instead
        text = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
}
"""
        st = analyze_text(text, 4)
        want = 2 * 4096 * 3 / 4
        assert abs(st.collective_bytes - want) < 1.0
        assert st.collectives["all-reduce"]["count"] == 1
