"""§2/§4.2/§5.1 graph IR, pruning, partial execution, CSE."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph, GraphBuilder, GraphError, Session, TensorRef
from repro.core.cse import eliminate_common_subexpressions


def test_unique_names_and_edges():
    b = GraphBuilder()
    c1 = b.constant(1.0, name="c")
    c2 = b.constant(2.0, name="c")
    assert c1.name == "c" and c2.name == "c_1"
    with pytest.raises(GraphError):
        b.graph.add_node("Add", ["nope", c1])


def test_transitive_closure_prunes_unneeded():
    b = GraphBuilder()
    a = b.constant(jnp.array(1.0), name="a")
    bb = b.constant(jnp.array(2.0), name="b")
    c = b.add(a, bb, name="c")
    d = b.mul(a, a, name="d")       # not needed for c
    e = b.add(d, c, name="e")
    needed = b.graph.transitive_closure(["c"])
    assert needed == {"a", "b", "c"}
    assert "d" in b.graph.transitive_closure(["e"])


def test_topo_sort_respects_deps_and_is_deterministic():
    b = GraphBuilder()
    a = b.constant(1.0, name="a")
    c = b.add(a, a, name="c")
    d = b.add(c, a, name="d")
    order = b.graph.topo_sort()
    assert order.index("a") < order.index("c") < order.index("d")
    assert order == b.graph.topo_sort()


def test_cycle_detection():
    b = GraphBuilder()
    a = b.constant(1.0, name="a")
    c = b.add(a, a, name="c")
    # manually create a cycle
    c.inputs[0] = TensorRef("d", 0)
    b.graph.nodes["d"] = type(c)(name="d", op="Add",
                                 inputs=[TensorRef("c", 0), TensorRef("c", 0)])
    with pytest.raises(GraphError):
        b.graph.topo_sort()


def test_run_fetches_and_feeds():
    """Figure 6: feeding an intermediate edge bypasses its producers."""
    b = GraphBuilder()
    a = b.placeholder("a")
    bb = b.constant(jnp.array(3.0), name="b")
    c = b.add(a, bb, name="c")
    d = b.mul(c, c, name="d")
    e = b.mul(d, bb, name="e")          # e = d*3
    sess = Session(b.graph)
    # full: (2+3)^2 * 3 = 75
    assert float(sess.run(e.ref, {a.ref: jnp.array(2.0)})) == 75.0
    # feed d directly: placeholder never needed
    trace = []
    out = sess.run(e.ref, {d.ref: jnp.array(10.0)}, trace=trace)
    assert float(out) == 30.0
    assert "c" not in trace and "d" not in trace  # pruned per §4.2


def test_run_executes_only_needed_nodes():
    b = GraphBuilder()
    a = b.constant(jnp.array(1.0), name="a")
    c = b.add(a, a, name="c")
    d = b.mul(a, a, name="d")
    sess = Session(b.graph)
    trace = []
    sess.run(c.ref, trace=trace)
    assert "d" not in trace


def test_control_dependency_ordering():
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.array(0.0))
    w1 = b.assign(v, b.constant(jnp.array(1.0), name="one"), name="w1")
    # read must happen after w1 via control edge
    read = b.graph.add_node("Variable", [], name="v_read",
                            attrs={"init": None}, control_inputs=["w1"])
    # simpler check: trace order
    sess = Session(b.graph)
    trace = []
    sess.run([w1.ref], trace=trace)
    assert "w1" in trace


def test_cse_merges_identical_pure_ops():
    b = GraphBuilder()
    x = b.constant(jnp.array(2.0), name="x")
    m1 = b.mul(x, x, name="m1")
    m2 = b.mul(x, x, name="m2")
    s = b.add(m1, m2, name="s")
    before = len(b.graph.nodes)
    replaced = eliminate_common_subexpressions(b.graph)
    assert len(replaced) == 1
    assert len(b.graph.nodes) == before - 1
    assert float(Session(b.graph).run(s.ref)) == 8.0


def test_cse_preserves_stateful_and_different_attrs():
    b = GraphBuilder()
    v1 = b.variable("v1", init_value=lambda: jnp.array(1.0))
    v2 = b.variable("v2", init_value=lambda: jnp.array(1.0))
    x = b.constant(jnp.array(1.0), name="x")
    r1 = b.reshape(x, (1,), name="r1")
    r2 = b.reshape(x, (1, 1), name="r2")
    replaced = eliminate_common_subexpressions(b.graph)
    assert "v1" in b.graph.nodes and "v2" in b.graph.nodes
    assert "r1" in b.graph.nodes and "r2" in b.graph.nodes
    assert not replaced


def test_extend_merges_graphs():
    b1 = GraphBuilder()
    a = b1.constant(jnp.array(1.0), name="a")
    sess = Session(b1.graph)
    g2 = Graph()
    g2.nodes["a2"] = type(a)(name="a2", op="Const", attrs={"value": jnp.array(2.0)})
    sess.extend(g2)
    assert float(sess.run(TensorRef("a2", 0))) == 2.0
    with pytest.raises(GraphError):
        sess.extend(g2)  # duplicate
