"""§4.6 queues, §4.7 containers, §3.2.2 rendezvous."""
import threading
import time

import pytest

from repro.runtime.queues import FIFOQueue, ShufflingQueue, QueueClosed
from repro.runtime.containers import Container, ContainerManager, VariableStore
from repro.runtime.rendezvous import Rendezvous, make_key


def test_fifo_order_and_blocking_dequeue():
    q = FIFOQueue(capacity=4, timeout=2.0)
    got = []

    def consumer():
        got.append(q.dequeue())

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    q.enqueue(42)
    t.join(timeout=2)
    assert got == [42]
    q.enqueue_many([1, 2, 3])
    assert [q.dequeue() for _ in range(3)] == [1, 2, 3]


def test_enqueue_blocks_until_space():
    q = FIFOQueue(capacity=1, timeout=2.0)
    q.enqueue("a")
    done = []

    def producer():
        q.enqueue("b")  # must block until a dequeue
        done.append(True)

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.05)
    assert not done
    assert q.dequeue() == "a"
    t.join(timeout=2)
    assert done and q.dequeue() == "b"


def test_dequeue_many_waits_for_minimum():
    q = FIFOQueue(capacity=8, timeout=2.0)
    res = []

    def consumer():
        res.extend(q.dequeue_many(3))

    t = threading.Thread(target=consumer)
    t.start()
    q.enqueue(1)
    q.enqueue(2)
    time.sleep(0.05)
    assert not res  # still waiting for the 3rd
    q.enqueue(3)
    t.join(timeout=2)
    assert res == [1, 2, 3]


def test_shuffling_queue_permutes():
    q = ShufflingQueue(capacity=128, seed=0, timeout=1.0)
    items = list(range(64))
    q.enqueue_many(items)
    q.close()
    out = [q.dequeue() for _ in range(64)]
    assert sorted(out) == items
    assert out != items  # shuffled


def test_closed_queue_raises():
    q = FIFOQueue(timeout=0.2)
    q.close()
    with pytest.raises(QueueClosed):
        q.dequeue()


def test_containers_share_state_across_sessions_and_reset():
    """§4.7: state shared across disjoint graphs; named containers reset."""
    import jax.numpy as jnp
    from repro.core import GraphBuilder, Session

    mgr = ContainerManager()
    b1 = GraphBuilder()
    v1 = b1.variable("shared_v", init_value=lambda: jnp.array(1.0),
                     container="exp1")
    s1 = Session(b1.graph, containers=mgr)
    s1.run(b1.assign(v1, b1.constant(jnp.array(5.0), name="c")).ref)

    b2 = GraphBuilder()
    v2 = b2.variable("shared_v", init_value=lambda: jnp.array(1.0),
                     container="exp1")
    s2 = Session(b2.graph, containers=mgr)
    assert float(s2.run(v2.ref)) == 5.0  # sees s1's write

    mgr.reset("exp1")
    assert float(s2.run(v2.ref)) == 1.0  # re-initialized after reset


def test_rendezvous_send_recv_and_duplicate_send():
    r = Rendezvous(timeout=1.0)
    key = make_key("x:0", "/job:a", "/job:b")
    r.send(key, 123)
    with pytest.raises(RuntimeError):
        r.send(key, 456)
    assert r.recv(key) == 123
    with pytest.raises(TimeoutError):
        r.recv(make_key("y:0", "/job:a", "/job:b"))


def test_rendezvous_cross_thread():
    r = Rendezvous(timeout=2.0)
    key = make_key("t:0", "/job:a", "/job:b")
    out = []

    def rx():
        out.append(r.recv(key))

    t = threading.Thread(target=rx)
    t.start()
    time.sleep(0.05)
    r.send(key, "payload")
    t.join(timeout=2)
    assert out == ["payload"]
