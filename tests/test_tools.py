"""§9 tools: summaries (TensorBoard analogue) + EEG-style tracing."""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Session
from repro.tools import (SummaryWriter, attach_scalar_summary, read_events,
                         Tracer, chrome_trace)
from repro.tools.summary import attach_histogram_summary


def test_scalar_summary_nodes_and_log_roundtrip(tmp_path):
    b = GraphBuilder()
    x = b.placeholder("x")
    loss = b.reduce_mean(b.square(x), name="loss")
    s1 = attach_scalar_summary(b, loss, "loss")
    s2 = attach_histogram_summary(b, x, "x_hist", bins=4)
    sess = Session(b.graph)
    w = SummaryWriter(str(tmp_path), flush_every=1)
    for step in range(5):
        xv = jnp.full((8,), float(step))
        vals = sess.run([s1.ref, s2.ref], {x.ref: xv})
        w.add_fetched(step, [s1, s2], vals)
    w.close()
    events = read_events(str(tmp_path), tag="loss")
    assert [t for t, _ in events["loss"]] == [0, 1, 2, 3, 4]
    assert events["loss"][3][1] == 9.0  # mean(3^2)
    wall = read_events(str(tmp_path), tag="loss", time_axis="wall_time")
    assert all(t2 >= t1 for (t1, _), (t2, _) in
               zip(wall["loss"], wall["loss"][1:]))


def test_tracer_records_kernels_and_chrome_format():
    b = GraphBuilder()
    a = b.constant(jnp.ones((16, 16)), name="a")
    m = b.matmul(a, a, name="mm")
    out = b.reduce_sum(m, name="out")
    tr = Tracer()
    Session(b.graph).run(out.ref, tracer=tr)
    ops = {e["op"] for e in tr.events}
    assert "MatMul" in ops and "ReduceSum" in ops
    summ = tr.summarize()
    assert summ["MatMul"]["count"] == 1
    doc = json.loads(chrome_trace(tr))
    names = [e["name"] for e in doc["traceEvents"]]
    assert any("MatMul:mm" in n for n in names)


def test_tracer_multi_device_lanes():
    from repro.runtime.devices import DeviceSet

    b = GraphBuilder()
    c1 = b.constant(jnp.ones((4, 4)), name="c1", device="/job:worker/task:0")
    c2 = b.constant(jnp.ones((4, 4)), name="c2", device="/job:worker/task:1")
    mm = b.matmul(c1, c2, name="mm")
    out = b.reduce_sum(mm)
    tr = Tracer()
    sess = Session(b.graph, devices=DeviceSet.make_cluster(2, 1, kind="cpu"))
    sess.run(out.ref, tracer=tr)
    devices = {e["device"] for e in tr.events}
    assert len(devices) == 2  # one lane per worker (Fig. 12-14 style)
    assert any(e["op"] in ("Send", "Recv") for e in tr.events)
