"""§3.2 placement + Send/Recv partitioning + §5.2 scheduling."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, TensorRef, while_loop
from repro.core import placement as pl
from repro.core import partition as pt
from repro.core import scheduler as sched
from repro.runtime.devices import DeviceSet, DeviceName, Device


def _two_workers():
    return DeviceSet.make_cluster(2, 1, kind="cpu")


def test_device_name_parsing_roundtrip():
    n = DeviceName.parse("/job:worker/task:17/device:gpu:3")
    assert (n.job, n.task, n.kind, n.index) == ("worker", 17, "gpu", 3)
    assert str(n) == "/job:worker/task:17/device:gpu:3"


def test_constraint_restricts_placement():
    b = GraphBuilder()
    c = b.constant(jnp.ones((4,)), name="c",
                   device="/job:worker/task:1")
    d = b.square(c, name="d")
    devs = _two_workers()
    place = pl.place(b.graph, devs)
    assert place["c"].startswith("/job:worker/task:1")


def test_colocation_union_find():
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.zeros(4),
                   device="/job:worker/task:0")
    upd = b.assign_add(v, b.constant(jnp.ones(4), name="delta"))
    other = b.constant(jnp.ones(2), name="other")
    other.attrs["colocate_with"] = "v"
    devs = _two_workers()
    place = pl.place(b.graph, devs)
    assert place["v"] == place[upd.name] == place["other"]


def test_infeasible_colocation_raises():
    b = GraphBuilder()
    a = b.constant(1.0, name="a", device="/job:worker/task:0")
    c = b.constant(2.0, name="c", device="/job:worker/task:1")
    c.attrs["colocate_with"] = "a"
    with pytest.raises(pl.PlacementError):
        pl.place(b.graph, _two_workers())


def test_greedy_placement_prefers_fast_device():
    devs = DeviceSet([
        Device(DeviceName(kind="cpu", index=0), flops_per_sec=1e9, bytes_per_sec=1e9),
        Device(DeviceName(job="worker", kind="tpu", index=0),
               flops_per_sec=1e14, bytes_per_sec=1e12),
    ])
    b = GraphBuilder()
    a = b.constant(jnp.ones((64, 64)), name="a")
    m = b.matmul(a, a, name="m")
    cm = pl.CostModel()
    cm.measured_bytes[("a", 0)] = 64 * 64 * 4
    place = pl.place(b.graph, devs, cm)
    assert "tpu" in place["m"]


def test_partition_canonicalizes_one_recv_per_tensor_devpair():
    """§3.2.2: b and c consume the same remote tensor -> ONE transfer."""
    b = GraphBuilder()
    x = b.constant(jnp.ones((4,)), name="x", device="/job:worker/task:0")
    u = b.square(x, name="u", device="/job:worker/task:1")
    w = b.neg(x, name="w", device="/job:worker/task:1")
    place = {"x": "/job:worker/task:0/device:cpu:0",
             "u": "/job:worker/task:1/device:cpu:0",
             "w": "/job:worker/task:1/device:cpu:0"}
    parted = pt.partition(b.graph, place)
    sends = [n for n in parted.graph.nodes.values() if n.op == "Send"]
    recvs = [n for n in parted.graph.nodes.values() if n.op == "Recv"]
    assert len(sends) == 1 and len(recvs) == 1
    assert parted.n_transfers == 1


def test_partition_same_device_needs_no_transfer():
    b = GraphBuilder()
    x = b.constant(jnp.ones((4,)), name="x")
    u = b.square(x, name="u")
    place = {"x": "/job:localhost/task:0/device:cpu:0",
             "u": "/job:localhost/task:0/device:cpu:0"}
    parted = pt.partition(b.graph, place)
    assert parted.n_transfers == 0


def test_multi_device_execution_matches_single():
    b = GraphBuilder()
    c1 = b.constant(jnp.ones((4, 4)), name="c1", device="/job:worker/task:0")
    c2 = b.constant(2 * jnp.ones((4, 4)), name="c2", device="/job:worker/task:1")
    mm = b.matmul(c1, c2, name="mm")
    out = b.reduce_sum(mm)
    single = Session(b.graph)
    multi = Session(b.graph, devices=_two_workers())
    assert float(single.run(out.ref)) == float(multi.run(out.ref)) == 128.0


def test_multi_device_with_compression_stays_close():
    b = GraphBuilder()
    c1 = b.constant(jnp.linspace(0.1, 1.0, 16).reshape(4, 4), name="c1",
                    device="/job:worker/task:0")
    sq = b.square(c1, name="sq", device="/job:worker/task:1")
    sess = Session(b.graph, devices=_two_workers())
    node_set = sess.pruned_nodes([sq.ref], {})
    from repro.core import distributed_runner as dr

    (out,) = dr.run_partitioned(sess, node_set, [sq.ref], {}, compress=True)
    np.testing.assert_allclose(out, np.linspace(0.1, 1.0, 16).reshape(4, 4) ** 2,
                               rtol=2 ** -6)


def test_scheduler_delays_recv():
    """§5.2: a Recv with slack gets a delaying control edge."""
    b = GraphBuilder()
    x = b.constant(jnp.ones((4,)), name="x", device="/job:worker/task:0")
    # long local chain on task:1
    a = b.constant(jnp.ones((4,)), name="a", device="/job:worker/task:1")
    c1 = b.square(a, name="c1", device="/job:worker/task:1")
    c2 = b.square(c1, name="c2", device="/job:worker/task:1")
    c3 = b.square(c2, name="c3", device="/job:worker/task:1")
    # the remote value is needed only at the very end
    final = b.add(c3, x, name="final", device="/job:worker/task:1")
    devs = _two_workers()
    place = pl.place(b.graph, devs)
    parted = pt.partition(b.graph, place)
    added = sched.schedule_recvs(parted.graph, set(parted.graph.nodes),
                                 pl.CostModel(), devs, parted.placement)
    recvs = [n for n in parted.graph.nodes.values() if n.op == "Recv"]
    assert len(recvs) == 1
    assert added >= 1
    assert recvs[0].control_inputs  # delayed until just before needed


def test_schedule_recvs_tolerates_pruned_deps_and_loop_adjacent_subgraph():
    """Regression: ``_times`` must only consult deps inside ``names`` —
    fed edges leave consumers whose producer was pruned from the executed
    set but still sits in ``g.nodes`` — and must never walk the
    ``NextIteration -> Merge`` back edge of a loop-adjacent subgraph
    (KeyError: the back-edge producer sorts *after* its consumer)."""
    b = GraphBuilder()
    x = b.placeholder("x")       # fed -> pruned from the executed names
    u = b.square(x, name="u")    # executed node whose dep is pruned
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(2), name="lim")
    one = b.constant(jnp.array(1), name="one")
    while_loop(b, lambda i: b.less(i, lim),
               lambda i: [b.add(i, one, name="inc")], [i0])
    g = b.graph
    g.add_node("Recv", [], name="recv/r", attrs={"rendezvous_key": "k"})
    g.add_node("Add", [u.ref, TensorRef("recv/r", 0)], name="w")
    names = set(g.nodes) - {"x"}
    added = sched.schedule_recvs(g, names, pl.CostModel())
    assert added >= 0  # no KeyError / GraphError


def test_loop_skeleton_colocates_but_body_can_split():
    """§4.4: the control skeleton + predicate land on one home device even
    when the body is pinned across two tasks."""
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0", device=f"/job:worker/task:0")
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    while_loop(b, lambda i: b.less(i, lim),
               lambda i: [b.add(i, one, name="inc", device="/job:worker/task:1")],
               [i0])
    place = pl.place(b.graph, _two_workers())
    spec = b.graph.loop_specs["while"]
    skeleton_devs = {place[m] for m in
                     (spec.merge_names + spec.switch_names + spec.exit_names
                      + spec.cond_nodes + ["while/cond"])}
    assert len(skeleton_devs) == 1  # one home device
    assert place["inc"] != next(iter(skeleton_devs))  # body still split
