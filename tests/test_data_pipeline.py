"""§4.5/§4.6 input pipeline: readers, prefetch queues, determinism."""
import os

import numpy as np

from repro.data import (SyntheticLMDataset, FileRecordReader, Prefetcher,
                        input_pipeline)


def test_synthetic_dataset_deterministic_and_bounded():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, seed=3)
    b1 = ds.batch(4, step=7)
    b2 = ds.batch(4, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 128 and b1["tokens"].min() >= 0
    assert b1["labels"].shape == (4, 16)
    # labels are next-token shifted
    full = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], b1["labels"])
    b3 = ds.batch(4, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_dataset_is_learnable_structure():
    """75% of successors follow the bigram table (so loss CAN decrease)."""
    ds = SyntheticLMDataset(vocab_size=64, seq_len=128, seed=0)
    b = ds.batch(16, step=0)
    follows = ds._succ[b["tokens"]] == b["labels"]
    assert 0.6 < follows.mean() < 0.9


def test_file_record_reader_roundtrip(tmp_path):
    records = [bytes([i]) * (i + 1) for i in range(10)]
    path = os.path.join(str(tmp_path), "data.rec")
    FileRecordReader.write_records(path, records)
    got = list(FileRecordReader([path]))
    assert got == records


def test_prefetcher_preserves_order_and_closes():
    src = iter(range(20))
    pf = Prefetcher(src, capacity=4).start()
    assert list(pf) == list(range(20))


def test_prefetcher_shuffling():
    # Deflaked: Prefetcher(shuffle=True) now pre-fills the window
    # (min_after_dequeue defaults to capacity//2), so the shuffle buffer
    # can never collapse to ~1 item when the consumer keeps pace with
    # the producer — the stream is guaranteed to shuffle across a >=32
    # item window rather than "usually, if the producer wins the race".
    pf = Prefetcher(iter(range(64)), capacity=64, shuffle=True, seed=0).start()
    out = list(pf)
    assert sorted(out) == list(range(64))
    assert out != list(range(64))
    displaced = sum(1 for i, v in enumerate(out) if v != i)
    assert displaced >= 16  # a real window, not a lucky swap


def test_input_pipeline_end_to_end():
    pipe = input_pipeline(vocab_size=100, seq_len=8, batch_size=4, prefetch=2)
    b = pipe.get()
    assert b["tokens"].shape == (4, 8)
    b2 = pipe.get()
    assert not np.array_equal(b["tokens"], b2["tokens"])
    pipe.stop()
