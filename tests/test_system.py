"""End-to-end system behaviour: the whole paper stack working together.

train: data pipeline (§4.5/4.6) -> Session graph with loss + §4.1
gradients + optimizer-as-nodes -> §10 lowering -> jax.jit, with §3.3
periodic checkpointing.  Asserts: loss actually decreases on the
structured synthetic LM task, and eager Session.run matches the compiled
path step for step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, FileCheckpointIO
from repro.configs import get_config
from repro.core import GraphBuilder, Session, compile_subgraph, gradients
from repro.data import SyntheticLMDataset
from repro.launch.steps import build_step
from repro.models.api import Model
from repro.models.params import init_params
from repro.optim import adamw_init


def _tiny_cfg():
    from repro.models.config import ModelConfig

    return ModelConfig(arch_id="tiny-lm", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=128, tie_embeddings=True)


def test_training_loss_decreases_end_to_end(tmp_path):
    cfg = _tiny_cfg()
    sb = build_step(cfg, "train_4k",
                    hparam_overrides={"compute_dtype": jnp.float32},
                    lr=2e-3)
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len=32, seed=0)
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    variables = {"params": params, "opt": adamw_init(params)}
    step = jax.jit(sb.fn)
    io = FileCheckpointIO(str(tmp_path))
    mgr = CheckpointManager(io, every_steps=20, keep=2)

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(8, i).items()}
        loss, variables = step(batch, variables)
        losses.append(float(loss))
        if mgr.should_save(i):
            mgr.save(i, {"variables": variables})

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.3, (first, last)
    assert np.isfinite(losses).all()
    assert mgr.latest_step() is not None
    restored = mgr.restore_latest()
    assert "variables" in restored


def test_eager_session_matches_compiled_training():
    """The same Session graph run eagerly (§3.1 executor) and through the
    §10 lowering gives identical parameter trajectories."""
    rs = np.random.RandomState(0)
    X = jnp.array(rs.randn(32, 4).astype("f"))
    Y = jnp.array((np.asarray(X) @ np.array([[1.], [2.], [-1.], [0.5]], "f")))

    def build():
        b = GraphBuilder()
        W = b.variable("W", init_value=lambda: jnp.zeros((4, 1), "f"))
        x = b.placeholder("x")
        y = b.placeholder("y")
        loss = b.reduce_mean(b.square(b.sub(b.matmul(x, W), y)), name="loss")
        (gW,) = gradients(b.graph, [loss], [W])
        upd = b.assign(W, b.sub(W, b.mul(
            b.constant(jnp.array(0.05), name="lr"), gW)))
        return b, W, x, y, loss, upd

    b, W, x, y, loss, upd = build()
    sess = Session(b.graph)
    for _ in range(15):
        sess.run(upd.ref, {x.ref: X, y.ref: Y})
    w_eager = np.asarray(sess.variable_value("W"))

    b2, W2, x2, y2, loss2, upd2 = build()
    low = compile_subgraph(Session(b2.graph), [loss2.ref], [x2.ref, y2.ref],
                           extra_updates=[upd2.name])
    jf = jax.jit(low.fn)
    vals = {"W": jnp.zeros((4, 1), "f")}
    for _ in range(15):
        _, new = jf({"x:0": X, "y:0": Y}, vals)
        vals.update(new)
    np.testing.assert_allclose(vals["W"], w_eager, rtol=1e-5, atol=1e-6)


def test_sharded_jit_path_on_host_mesh():
    """The mesh/sharding machinery end to end on a degenerate 1x1 mesh."""
    from repro.launch import mesh as mesh_mod
    from repro.parallel import sharding as shd

    if not mesh_mod.host_mesh_supported():
        pytest.skip("this jax cannot build the 1x1 host mesh "
                    "(launch/mesh.py gate)")
    cfg = get_config("smollm-360m", smoke=True)
    mesh = mesh_mod.make_host_mesh()
    rules = mesh_mod.mesh_rules(mesh)
    with shd.axis_rules(rules, mesh):
        sb = build_step(cfg, "train_4k", mesh, rules,
                        hparam_overrides={"compute_dtype": jnp.float32})
        jf = jax.jit(sb.fn,
                     in_shardings=(sb.feed_shardings, sb.var_shardings),
                     out_shardings=sb.out_shardings)
        params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
        variables = {"params": params, "opt": adamw_init(params)}
        rs = np.random.RandomState(0)
        batch = {
            "tokens": jnp.array(rs.randint(0, cfg.vocab_size, (2, 32)), jnp.int32),
            "labels": jnp.array(rs.randint(0, cfg.vocab_size, (2, 32)), jnp.int32),
        }
        loss, variables = jf(batch, variables)
    assert np.isfinite(float(loss))


def test_serve_graph_cache_threading():
    """Decode through the graph path: cache Variable advances per step."""
    cfg = _tiny_cfg()
    sb = build_step(cfg, "decode_32k",
                    hparam_overrides={"compute_dtype": jnp.float32})
    model = sb.model
    B, S = 2, 8
    params = model.init(jax.random.PRNGKey(0))
    cache = init_params(model.init_cache_desc(batch=B, max_seq=S),
                        jax.random.PRNGKey(1))
    rs = np.random.RandomState(0)
    tokens = jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32)

    from repro.models import lm

    hid, _ = lm.forward(cfg, model.plan, params, tokens)
    want = lm.logits_from_hidden(cfg, model.plan, params, hid)

    step = jax.jit(sb.fn)
    variables = {"params": params, "cache": cache}
    worst = 0.0
    for t in range(S):
        logits, new_vars = step(
            {"tokens": tokens[:, t:t + 1], "pos": jnp.array(t, jnp.int32)},
            variables)
        variables = {"params": params, **new_vars}
        worst = max(worst, float(jnp.max(jnp.abs(logits[:, 0] - want[:, t]))))
    assert worst < 1e-3


def test_inception_style_parameter_accounting():
    """§6 lesson 1: tools to count parameters catch spec flaws.  We check
    the param-count tool against a hand computation for a small dense cfg."""
    from repro.models.params import count_params

    cfg = _tiny_cfg()
    model = Model.for_config(cfg)
    D, H, KV, hd, F, V = 64, 4, 2, 16, 128, 128
    per_layer = (D + D * H * hd + 2 * D * KV * hd + H * hd * D  # ln1+qkv+o
                 + D + 3 * D * F)                                # ln2+mlp
    want = V * D + D + 2 * per_layer  # embed(tied) + final_norm + 2 layers
    assert count_params(model.describe_params()) == want
