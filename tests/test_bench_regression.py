"""Opt-in perf-regression gate: `pytest -m benchcheck`.

Re-runs the key benchmarks (b1 dispatch overhead, b2 fused-fast eager
engine, b9 train throughput, b12 cached multi-device step, b13 fused
multi-device step) and fails if
any regressed by more than 25% against the committed
``benchmarks/BENCH_latest.json``.  Deselected by default (see pyproject
``addopts``) because a fresh run costs ~a minute; CI or a developer
opts in explicitly, or runs ``python benchmarks/run.py --check``.
"""
import importlib.util
import os

import pytest

_RUN_PY = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "run.py")


def _load_bench_module():
    spec = importlib.util.spec_from_file_location("bench_run", _RUN_PY)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.benchcheck
def test_key_benchmarks_within_regression_budget():
    bench = _load_bench_module()
    if not os.path.exists(bench.BASELINE_PATH):
        pytest.skip("no committed BENCH_latest.json baseline")
    failures = bench.run_check(threshold=0.25)
    assert failures == 0, (
        f"{failures} key metric(s) regressed >25% vs BENCH_latest.json "
        "(see '# CHECK FAIL' lines above)")
