"""Launch-layer hparam levers: exactness guarantees for the §Perf knobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import build_step
from repro.models.params import init_params
from repro.optim import adamw_init


def _feeds(cfg, B=4, S=32, seed=0):
    rs = np.random.RandomState(seed)
    f = {"tokens": jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
         "labels": jnp.array(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        f["frames"] = jnp.array(
            (rs.randn(B, cfg.enc_seq, cfg.d_model) * 0.1).astype("f"))
    return f


def test_microbatch_gradient_accumulation_is_exact():
    """EXPERIMENTS §Perf H1 lever: k-microbatch accumulation == full batch."""
    cfg = get_config("smollm-360m", smoke=True)
    feeds = _feeds(cfg)
    results = {}
    for k in (1, 2, 4):
        sb = build_step(cfg, "train_4k",
                        hparam_overrides={"compute_dtype": jnp.float32,
                                          "microbatch": k})
        params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
        loss, newv = sb.fn(feeds, {"params": params, "opt": adamw_init(params)})
        results[k] = (float(loss), newv["params"])
    for k in (2, 4):
        assert abs(results[k][0] - results[1][0]) < 1e-4
        for a, b in zip(jax.tree.leaves(results[1][1]),
                        jax.tree.leaves(results[k][1])):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


def test_microbatch_moe_arch_runs():
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    sb = build_step(cfg, "train_4k",
                    hparam_overrides={"compute_dtype": jnp.float32,
                                      "microbatch": 2})
    feeds = _feeds(cfg)
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    loss, _ = sb.fn(feeds, {"params": params, "opt": adamw_init(params)})
    assert np.isfinite(float(loss))


def test_serve_param_dtype_bf16():
    """§Perf H2 lever: bf16 serving weights thread through the serve step."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    sb = build_step(cfg, "decode_32k",
                    hparam_overrides={"param_dtype": jnp.bfloat16})
    leaves = jax.tree.leaves(sb.var_specs["params"])
    assert all(l.dtype == jnp.bfloat16 for l in leaves)
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    cache = init_params(sb.model.init_cache_desc(batch=2, max_seq=8,
                                                 dtype=jnp.bfloat16),
                        jax.random.PRNGKey(1))
    logits, _ = sb.fn({"tokens": jnp.zeros((2, 1), jnp.int32),
                       "pos": jnp.array(0, jnp.int32)},
                      {"params": params, "cache": cache})
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_seq_res_rules_preserve_loss_on_host_mesh():
    """SP sharding rules are semantics-preserving (1x1 mesh sanity)."""
    from repro.launch import mesh as mesh_mod
    from repro.parallel import sharding as shd

    if not mesh_mod.host_mesh_supported():
        pytest.skip("this jax cannot build the 1x1 host mesh "
                    "(launch/mesh.py gate)")

    cfg = get_config("smollm-360m", smoke=True)
    feeds = _feeds(cfg)
    losses = {}
    for tag, overrides in [("base", None), ("sp", {"seq_res": "model"})]:
        mesh = mesh_mod.make_host_mesh()
        rules = mesh_mod.mesh_rules(mesh, overrides=overrides)
        with shd.axis_rules(rules, mesh):
            sb = build_step(cfg, "train_4k", mesh, rules,
                            hparam_overrides={"compute_dtype": jnp.float32})
            params = init_params(sb.model.describe_params(),
                                 jax.random.PRNGKey(0))
            loss, _ = jax.jit(sb.fn)(feeds, {"params": params,
                                             "opt": adamw_init(params)})
            losses[tag] = float(loss)
    assert abs(losses["base"] - losses["sp"]) < 1e-5
