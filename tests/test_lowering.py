"""§10 lowering: eager == compiled (incl. property over random graphs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GraphBuilder, Session, compile_subgraph, LoweringError


def test_variable_update_parity_with_eager():
    def build():
        b = GraphBuilder()
        v = b.variable("v", init_value=lambda: jnp.array(2.0))
        g = b.mul(v, b.constant(jnp.array(3.0), name="k"))
        upd = b.assign_add(v, b.neg(g))
        return b, v, g, upd

    b, v, g, upd = build()
    sess = Session(b.graph)
    for _ in range(3):
        sess.run(upd.ref)
    eager_v = float(sess.variable_value("v"))

    b2, v2, g2, upd2 = build()
    low = compile_subgraph(Session(b2.graph), [upd2.ref], [])
    vals = {"v": jnp.array(2.0)}
    for _ in range(3):
        _, new = low.fn({}, vals)
        vals.update(new)
    assert float(vals["v"]) == pytest.approx(eager_v)


def test_lowered_fn_is_jittable():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.add(b.square(x), b.constant(jnp.array(1.0), name="c"))
    low = compile_subgraph(Session(b.graph), [y.ref], [x.ref])
    jf = jax.jit(low.fn)
    (out,), _ = jf({"x:0": jnp.array(3.0)}, {})
    assert float(out) == 10.0


def test_unsupported_ops_raise():
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.array(1.0))
    save = b.save([v], "ckpt/x")
    sess = Session(b.graph)
    low = compile_subgraph(sess, [save.ref], [])
    with pytest.raises(LoweringError):
        low.fn({}, {"v": jnp.array(1.0)})


def test_cse_runs_in_lowering():
    b = GraphBuilder()
    x = b.placeholder("x")
    m1 = b.mul(x, x, name="m1")
    m2 = b.mul(x, x, name="m2")
    s = b.add(m1, m2)
    low = compile_subgraph(Session(b.graph), [s.ref], [x.ref])
    assert low.n_nodes < 4  # one of m1/m2 eliminated
    (out,), _ = low.fn({"x:0": jnp.array(2.0)}, {})
    assert float(out) == 8.0


_OPS = ["add", "sub", "mul", "square", "tanh", "relu", "neg"]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(_OPS), min_size=1, max_size=10),
       st.integers(0, 2 ** 31 - 1))
def test_eager_equals_compiled_property(opseq, seed):
    rs = np.random.RandomState(seed)
    b = GraphBuilder()
    x = b.placeholder("x")
    vals = [x.ref]
    for i, op in enumerate(opseq):
        if op in ("add", "sub", "mul"):
            s1 = vals[rs.randint(len(vals))]
            s2 = vals[rs.randint(len(vals))]
            vals.append(getattr(b, op)(s1, s2, name=f"n{i}").ref)
        else:
            vals.append(getattr(b, op)(vals[rs.randint(len(vals))], name=f"n{i}").ref)
    out = b.reduce_sum(vals[-1], name="out")
    xin = jnp.array(rs.randn(4).astype("float32"))
    sess = Session(b.graph)
    eager = sess.run(out.ref, {x.ref: xin})
    (compiled,), _ = compile_subgraph(sess, [out.ref], [x.ref]).fn(
        {"x:0": xin}, {})
    np.testing.assert_allclose(eager, compiled, rtol=1e-5, atol=1e-6)
