"""DESIGN.md §15 wire-shippable Call factories.

A factory Call carries only an importable ``"module:qualname"`` spec plus
static picklable args — no closure — so the identical graph executes
in-process and after a pickle round-trip in a worker process.  These
tests pin the format (attrs survive pickling, closures are rejected at
build time), the resolution semantics (memoised per ``(factory, args)``,
fresh-process rebuild works), and gradient flow through a factory Call.
"""
import pickle

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session
from repro.core import ops as ops_mod
from repro.core.autodiff import gradients
from repro.core.options import SessionOptions


def scale_factory(k):
    """Module-level test factory (importable as tests.test_call_factory)."""
    def kernel(x):
        return x * k
    return kernel


def pair_factory(k, *, bias=0.0):
    def kernel(x):
        return x * k + bias, x - k
    return kernel


SPEC = "tests.test_call_factory:scale_factory"
PAIR = "tests.test_call_factory:pair_factory"


def _fresh_caches():
    ops_mod._CALL_NODE_CACHE.clear()
    ops_mod._CALL_FACTORY_CACHE.clear()


def test_factory_call_runs_and_attrs_pickle():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.call_factory(SPEC, [x], args=(3.0,), name="scaled")
    sess = Session(b.graph, options=SessionOptions())
    out = sess.run(y.ref, {x.ref: jnp.asarray([1.0, 2.0])})
    np.testing.assert_allclose(np.asarray(out), [3.0, 6.0])
    sess.close()

    node = b.graph.nodes["scaled"]
    attrs2 = pickle.loads(pickle.dumps(node.attrs))
    assert attrs2["call_factory"] == SPEC
    assert attrs2["factory_args"] == (3.0,)
    # the resolved kernel itself must never leak into the shipped attrs
    assert not any(callable(v) for v in attrs2.values())


def test_resolution_is_memoised_per_factory_and_args():
    _fresh_caches()
    b = GraphBuilder()
    x = b.placeholder("x")
    n1 = b.call_factory(SPEC, [x], args=(2.0,), name="c1")
    n2 = b.call_factory(SPEC, [x], args=(2.0,), name="c2")
    n3 = b.call_factory(SPEC, [x], args=(5.0,), name="c3")
    f1 = ops_mod.resolve_call_fn(b.graph.nodes[n1.name])
    f2 = ops_mod.resolve_call_fn(b.graph.nodes[n2.name])
    f3 = ops_mod.resolve_call_fn(b.graph.nodes[n3.name])
    assert f1 is f2  # same (factory, args): one rebuild
    assert f1 is not f3
    assert len(ops_mod._CALL_FACTORY_CACHE) == 2


def test_fresh_process_rebuild_after_pickle_roundtrip():
    """The worker path: a node reconstructed from pickled attrs (caches
    cleared = fresh interpreter) resolves and computes."""
    b = GraphBuilder()
    x = b.placeholder("x")
    node = b.call_factory(PAIR, [x], args=(2.0,), kwargs={"bias": 1.0},
                          name="pair", n_out=2)
    shipped = pickle.loads(pickle.dumps(b.graph.nodes[node.name].attrs))
    _fresh_caches()
    rebuilt = type(b.graph.nodes[node.name])(
        name="pair", op="Call", inputs=list(b.graph.nodes[node.name].inputs),
        attrs=shipped)
    fn = ops_mod.resolve_call_fn(rebuilt)
    a, c = fn(jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(a), [3.0, 7.0])
    np.testing.assert_allclose(np.asarray(c), [-1.0, 1.0])


def test_bad_factory_spec_rejected():
    b = GraphBuilder()
    x = b.placeholder("x")
    with pytest.raises(ValueError, match="module:qualname"):
        b.call_factory("not-importable", [x])


def test_gradient_flows_through_factory_call():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.call_factory(SPEC, [x], args=(4.0,), name="y")
    loss = b.reduce_sum(y, name="loss")
    (gx,) = gradients(b.graph, [loss], [x])
    sess = Session(b.graph, options=SessionOptions())
    g = sess.run(gx, {x.ref: jnp.asarray([1.0, 2.0, 3.0])})
    np.testing.assert_allclose(np.asarray(g), [4.0, 4.0, 4.0])
    sess.close()
