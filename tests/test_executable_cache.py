"""Executable-cache correctness (DESIGN.md §5).

The Session must place/partition/schedule once per run *signature*, not
once per run; cached Executables must return fresh values (Variables are
read at run time), invalidate on Session.extend and device-set changes,
and tolerate concurrent runs.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session
from repro.core import placement as pl
from repro.core import partition as pt
from repro.core import scheduler as sc
from repro.core.executor import ExecutorError
from repro.core.ops import register
from repro.runtime.devices import DeviceSet


@register("SleepTest")
def _sleep_test(ctx, node, x):
    time.sleep(node.attrs.get("seconds", 3.0))
    return (x,)


def _reset_pass_stats():
    pl.STATS["place_calls"] = 0
    pt.STATS["partition_calls"] = 0
    sc.STATS["schedule_calls"] = 0


def _two_workers():
    return DeviceSet.make_cluster(2, 1, kind="cpu")


def _multi_device_graph():
    b = GraphBuilder()
    c1 = b.constant(jnp.ones((4, 4)), name="c1", device="/job:worker/task:0")
    c2 = b.constant(2 * jnp.ones((4, 4)), name="c2", device="/job:worker/task:1")
    out = b.reduce_sum(b.matmul(c1, c2, name="mm"), name="out")
    return b, out


def test_pipeline_runs_once_across_repeated_runs():
    """§3.2/§4.2: prune/place/partition/schedule happen once per signature."""
    b, out = _multi_device_graph()
    sess = Session(b.graph, devices=_two_workers())
    _reset_pass_stats()
    for _ in range(5):
        assert float(sess.run(out.ref)) == 128.0
    assert pl.STATS["place_calls"] == 1
    assert pt.STATS["partition_calls"] == 1
    assert sc.STATS["schedule_calls"] == 1
    assert sess.cache_stats["misses"] == 1
    assert sess.cache_stats["hits"] == 4


def test_uncached_session_rebuilds_every_run():
    """max_cached_executables=0 is the benchmark baseline: rebuild per run."""
    b, out = _multi_device_graph()
    sess = Session(b.graph, devices=_two_workers(), max_cached_executables=0)
    _reset_pass_stats()
    for _ in range(3):
        assert float(sess.run(out.ref)) == 128.0
    assert pl.STATS["place_calls"] == 3
    assert pt.STATS["partition_calls"] == 3


def test_cached_run_returns_fresh_variable_values():
    """Reuse must not freeze state: Variables are read per run."""
    b = GraphBuilder()
    v = b.variable("v", init_value=lambda: jnp.zeros(()))
    upd = b.assign_add(v, b.constant(jnp.ones(()), name="one"))
    sess = Session(b.graph)
    got = [float(sess.run(upd.ref)) for _ in range(3)]
    assert got == [1.0, 2.0, 3.0]
    assert sess.cache_stats["misses"] == 1
    assert sess.cache_stats["hits"] == 2
    # a different signature (reading v) still sees the latest value
    assert float(sess.run(v.ref)) == 3.0


def test_feed_values_change_without_rebuild():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.add(b.mul(x, x), b.constant(jnp.ones(2), name="c"), name="y")
    sess = Session(b.graph)
    for val in (1.0, 2.0, 3.0):
        out = sess.run(y.ref, {x.ref: val * jnp.ones(2)})
        np.testing.assert_allclose(out, val * val + 1.0)
    assert sess.cache_stats["misses"] == 1
    assert sess.cache_stats["hits"] == 2


def test_extend_invalidates_executable():
    """Graph version is part of the RunSignature: Extend rebuilds."""
    b, out = _multi_device_graph()
    sess = Session(b.graph, devices=_two_workers())
    _reset_pass_stats()
    sess.run(out.ref)
    v0 = sess.graph.version
    other = GraphBuilder()
    other.constant(jnp.ones(2), name="late")
    sess.extend(other.graph)
    assert sess.graph.version > v0
    assert float(sess.run(out.ref)) == 128.0
    assert pl.STATS["place_calls"] == 2  # rebuilt after Extend
    assert sess.cache_stats["misses"] == 2
    assert sess.cache_stats["invalidations"] >= 1  # stale entry purged


def test_device_set_change_invalidates():
    b, out = _multi_device_graph()
    sess = Session(b.graph)  # single virtual device first
    assert float(sess.run(out.ref)) == 128.0
    sess.devices = _two_workers()
    _reset_pass_stats()
    assert float(sess.run(out.ref)) == 128.0
    assert pl.STATS["place_calls"] == 1  # multi-device pipeline ran
    assert sess.cache_stats["misses"] == 2


def test_concurrent_runs_share_one_executable():
    """One cached Executable, many simultaneous runs, no state bleed."""
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.add(b.mul(x, x), b.constant(jnp.zeros(()), name="z"), name="y")
    sess = Session(b.graph)
    sess.run(y.ref, {x.ref: jnp.asarray(1.0)})  # warm the cache

    results = {}
    errors = []

    def runner(val):
        try:
            results[val] = float(sess.run(y.ref, {x.ref: jnp.asarray(float(val))}))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=runner, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors
    assert results == {i: float(i * i) for i in range(8)}
    assert sess.cache_stats["misses"] == 1  # everyone reused the warm entry


def test_concurrent_multi_device_runs_do_not_mix_rendezvous():
    b, out = _multi_device_graph()
    sess = Session(b.graph, devices=_two_workers())
    sess.run(out.ref)  # warm
    vals, errors = [], []

    def runner():
        try:
            vals.append(float(sess.run(out.ref)))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=runner) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors
    assert vals == [128.0] * 4
    assert sess.cache_stats["misses"] == 1


def test_stuck_worker_raises_naming_device():
    """§3.3 failure reporting: a hung worker is a clear error, not a
    silent KeyError on a missing fetch."""
    from repro.core import distributed_runner as dr

    b = GraphBuilder()
    c = b.constant(jnp.ones(2), name="c", device="/job:worker/task:0")
    slow = b.graph.add_node("SleepTest", [c], name="sleeper",
                            attrs={"seconds": 3.0}, device="/job:worker/task:1")
    sess = Session(b.graph, devices=_two_workers())
    node_set = sess.pruned_nodes([slow.ref], {})
    with pytest.raises(ExecutorError) as ei:
        dr.run_partitioned(sess, node_set, [slow.ref], {}, timeout=0.3)
    msg = str(ei.value)
    assert "task:1" in msg and "timed out" in msg


def test_make_callable_steady_state_hits_cache():
    b = GraphBuilder()
    x = b.placeholder("x")
    y = b.mul(x, x, name="y")
    sess = Session(b.graph)
    call = sess.make_callable([y.ref], [x.ref])
    for v in range(4):
        (out,) = call(jnp.asarray(float(v)))
        assert float(out) == v * v
    assert sess.cache_stats["misses"] == 1
    assert sess.cache_stats["hits"] == 3
