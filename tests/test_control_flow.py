"""§4.4 Switch/Merge/Enter/Exit/NextIteration: eager frames + lowering."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, while_loop, cond, compile_subgraph


def _sum_loop(b, limit=5):
    i0 = b.constant(jnp.array(0), name="i0")
    acc0 = b.constant(jnp.array(0.0), name="acc0")
    lim = b.constant(jnp.array(limit), name="lim")
    one = b.constant(jnp.array(1), name="one")

    def cnd(i, a):
        return b.less(i, lim)

    def body(i, a):
        return [b.add(i, one), b.add(a, b.cast(i, "float32"))]

    return while_loop(b, cnd, body, [i0, acc0])


def test_while_loop_eager():
    b = GraphBuilder()
    outs = _sum_loop(b)
    i, acc = Session(b.graph).run(outs)
    assert int(i) == 5 and float(acc) == 10.0


def test_while_loop_compiled_matches_eager():
    b = GraphBuilder()
    outs = _sum_loop(b, limit=7)
    sess = Session(b.graph)
    eager = sess.run(outs)
    (compiled, _) = compile_subgraph(sess, outs, []).fn({}, {})
    assert int(compiled[0]) == int(eager[0])
    assert float(compiled[1]) == float(eager[1])


def test_while_zero_iterations():
    b = GraphBuilder()
    outs = _sum_loop(b, limit=0)
    i, acc = Session(b.graph).run(outs)
    assert int(i) == 0 and float(acc) == 0.0


def test_cond_both_branches_eager_and_compiled():
    b = GraphBuilder()
    p = b.placeholder("p")
    x = b.constant(jnp.array(3.0), name="x")
    res = cond(b, p, lambda t: [b.mul(t, t)], lambda f: [b.neg(f)], [x])
    sess = Session(b.graph)
    assert float(sess.run(res, {p.ref: jnp.array(True)})[0]) == 9.0
    assert float(sess.run(res, {p.ref: jnp.array(False)})[0]) == -3.0
    low = compile_subgraph(sess, res, [p.ref])
    assert float(low.fn({"p:0": jnp.array(True)}, {})[0][0]) == 9.0
    assert float(low.fn({"p:0": jnp.array(False)}, {})[0][0]) == -3.0


def test_cond_untaken_branch_not_executed_eagerly():
    """Dead-tensor propagation skips the untaken branch (§4.4)."""
    b = GraphBuilder()
    p = b.placeholder("p")
    x = b.constant(jnp.array(2.0), name="x")
    res = cond(b, p,
               lambda t: [b.mul(t, t, name="true_branch")],
               lambda f: [b.neg(f, name="false_branch")], [x])
    # verify: ignore[D501] — this test fetches the dead branch on purpose
    # to assert the runtime's dead-tensor behaviour; the verifier is right
    # that it would be a bug anywhere else.
    b.graph.nodes["false_branch"].attrs["verify_ignore"] = ("D501",)
    trace = []
    out = Session(b.graph).run(res, {p.ref: jnp.array(True)}, trace=trace)
    assert float(out[0]) == 4.0
    assert "true_branch" in trace
    # the false branch node fires only to propagate deadness; its kernel
    # must not have produced a live value — fetching it must fail
    with pytest.raises(Exception):
        Session(b.graph).run("false_branch:0", {p.ref: jnp.array(True)})


def test_loop_over_vector_state():
    b = GraphBuilder()
    x0 = b.constant(jnp.ones((4,)), name="x0")
    i0 = b.constant(jnp.array(0), name="i0")
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    two = b.constant(jnp.array(2.0), name="two")
    outs = while_loop(b,
                      lambda i, x: b.less(i, lim),
                      lambda i, x: [b.add(i, one), b.mul(x, two)],
                      [i0, x0])
    i, x = Session(b.graph).run(outs)
    np.testing.assert_allclose(x, np.full((4,), 8.0))


def test_loop_outputs_consumed_by_downstream_compute():
    """Exit values feed post-loop compute (§4.4).  Regression: a dead
    Exit fired on every *continuing* iteration and poisoned root-frame
    consumers (marked them done-with-dead) before the terminating
    iteration delivered the live value — dead Exits are now swallowed
    like dead NextIterations."""
    b = GraphBuilder()
    lim = b.constant(jnp.array(3), name="lim")
    one = b.constant(jnp.array(1), name="one")
    i0 = b.constant(jnp.array(0), name="i0")
    a0 = b.constant(jnp.array(2.0), name="a0")
    outs = while_loop(b, lambda i, a: b.less(i, lim),
                      lambda i, a: [b.add(i, one), b.add(a, a)], [i0, a0])
    post = b.mul(outs[1], outs[1], name="post")
    total = b.add(post, b.cast(outs[0], "float32"), name="total")
    for fuse in (False, True):
        got = Session(b.graph, fuse_regions=fuse).run(total.ref)
        assert float(got) == 16.0 * 16.0 + 3.0
