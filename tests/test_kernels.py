"""Per-kernel allclose vs ref.py oracles, hypothesis shape/dtype sweeps
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

_DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([64, 128, 256]), st.sampled_from([128, 256]),
       st.sampled_from([128, 384]), st.sampled_from(_DTYPES),
       st.integers(0, 2 ** 31 - 1))
def test_matmul_sweep(m, n, k, dtype, seed):
    rs = np.random.RandomState(seed)
    a = jnp.array(rs.randn(m, k), dtype)
    b = jnp.array(rs.randn(k, n), dtype)
    got = matmul_pallas(a, b, bm=64, bn=128, bk=128, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([8, 64, 256]), st.sampled_from([128, 384, 512]),
       st.sampled_from(_DTYPES), st.integers(0, 2 ** 31 - 1))
def test_rmsnorm_sweep(rows, d, dtype, seed):
    rs = np.random.RandomState(seed)
    x = jnp.array(rs.randn(rows, d), dtype)
    w = jnp.array(rs.randn(d), dtype)
    got = rmsnorm_pallas(x, w, br=8, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(2, 128, 64), (4, 256, 64), (1, 256, 128)]),
       st.booleans(), st.sampled_from([0, 64]),
       st.sampled_from(_DTYPES), st.integers(0, 2 ** 31 - 1))
def test_flash_attention_sweep(dims, causal, window, dtype, seed):
    bh, s, d = dims
    if not causal and window:
        window = 0
    rs = np.random.RandomState(seed)
    q = jnp.array(rs.randn(bh, s, d), dtype)
    k = jnp.array(rs.randn(bh, s, d), dtype)
    v = jnp.array(rs.randn(bh, s, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=64, bkv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_flash_attention_cross_lengths():
    rs = np.random.RandomState(0)
    q = jnp.array(rs.randn(2, 64, 64).astype("float32"))
    k = jnp.array(rs.randn(2, 256, 64).astype("float32"))
    v = jnp.array(rs.randn(2, 256, 64).astype("float32"))
    got = flash_attention_pallas(q, k, v, causal=False, bq=64, bkv=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(2, 64, 16, 8), (4, 128, 32, 16), (1, 64, 64, 32)]),
       st.sampled_from([16, 32]), st.integers(0, 2 ** 31 - 1))
def test_ssd_scan_sweep(dims, chunk, seed):
    bh, s, p, n = dims
    rs = np.random.RandomState(seed)
    x = jnp.array((rs.randn(bh, s, p) * 0.5).astype("float32"))
    dt = jnp.array((rs.rand(bh, s) * 0.5).astype("float32"))
    a = -jnp.exp(jnp.array(rs.rand(bh).astype("float32")))
    Bc = jnp.array((rs.randn(bh, s, n) * 0.3).astype("float32"))
    Cc = jnp.array((rs.randn(bh, s, n) * 0.3).astype("float32"))
    got = ssd_scan_pallas(x, dt, a, Bc, Cc, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, a, Bc, Cc)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_ssd_kernel_wrapper_matches_model_layer():
    """ops.ssd_scan (kernel layout adapter) == models.layers.ssd_chunked."""
    from repro.models import layers as L

    rs = np.random.RandomState(0)
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = jnp.array((rs.randn(B, S, H, P) * 0.5).astype("float32"))
    dt = jnp.array((rs.rand(B, S, H) * 0.5).astype("float32"))
    A_log = jnp.array(rs.rand(H).astype("float32"))
    Bc = jnp.array((rs.randn(B, S, G, N) * 0.3).astype("float32"))
    Cc = jnp.array((rs.randn(B, S, G, N) * 0.3).astype("float32"))
    D = jnp.array(rs.randn(H).astype("float32"))
    got = ops.ssd_scan(x, dt, A_log, Bc, Cc, D, chunk=16, interpret=True)
    want, _ = L.ssd_chunked(x, dt, A_log, Bc, Cc, D, chunk=16)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_gqa_wrapper_matches_model_attention():
    from repro.models import layers as L

    rs = np.random.RandomState(1)
    B, S, KV, G, Dh = 2, 128, 2, 2, 64
    q = jnp.array(rs.randn(B, S, KV, G, Dh).astype("float32"))
    k = jnp.array(rs.randn(B, S, KV, Dh).astype("float32"))
    v = jnp.array(rs.randn(B, S, KV, Dh).astype("float32"))
    pos = jnp.arange(S)
    got = ops.flash_attention_gqa(q, k, v, causal=True, interpret=True)
    want = L.attention(q, k, v, pos_q=pos, pos_kv=pos, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_compress16_sweep(kilo, seed):
    rs = np.random.RandomState(seed)
    x = jnp.array((rs.randn(kilo * 1024) * 10 ** rs.randint(-3, 3)
                   ).astype("float32"))
    w = ops.compress16(x, interpret=True)
    assert bool(jnp.all(w == ref.compress16_ref(x)))
    rt = ops.decompress16(w, interpret=True)
    np.testing.assert_array_equal(np.asarray(rt),
                                  np.asarray(ref.decompress16_ref(w)))
    rel = np.abs(np.asarray(rt) - np.asarray(x)) / np.maximum(
        np.abs(np.asarray(x)), 1e-30)
    assert rel.max() <= 2 ** -7


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(4, 256, 64), (2, 512, 128), (8, 128, 64)]),
       st.sampled_from([64, 128]), st.integers(0, 2 ** 31 - 1))
def test_flash_decode_sweep(dims, bkv, seed):
    from repro.kernels.flash_decode import flash_decode_pallas

    bh, t, d = dims
    rs = np.random.RandomState(seed)
    q = jnp.array(rs.randn(bh, d).astype("f"))
    k = jnp.array(rs.randn(bh, t, d).astype("f"))
    v = jnp.array(rs.randn(bh, t, d).astype("f"))
    valid = jnp.array(rs.randint(1, t + 1, (bh,)), jnp.int32)
    got = flash_decode_pallas(q, k, v, valid, bkv=bkv, interpret=True)
    want = ref.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_decode_zero_valid_rows_are_zero():
    from repro.kernels.flash_decode import flash_decode_pallas

    q = jnp.ones((2, 64))
    k = jnp.ones((2, 128, 64))
    v = jnp.ones((2, 128, 64))
    valid = jnp.array([0, 128], jnp.int32)
    out = flash_decode_pallas(q, k, v, valid, bkv=64, interpret=True)
    np.testing.assert_allclose(out[0], np.zeros(64))
    np.testing.assert_allclose(out[1], np.ones(64), rtol=1e-5)
