"""Extensions beyond the assignment: graph viz, extra pool archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import GraphBuilder
from repro.tools.graphviz import collapse_summary, to_dot


def test_graph_collapse_by_prefix_and_bookkeeping():
    b = GraphBuilder()
    w = b.variable("shared_w", init_value=lambda: jnp.ones(4))
    for layer in range(3):
        h = b.mul(w, w, name=f"layer{layer}/mul")
        b.add(h, w, name=f"layer{layer}/add")
    # shared_w has degree >= 8? 3*3=9 uses -> bookkeeping separation
    blocks = collapse_summary(b.graph, depth=1, high_degree=8)
    assert "layer0" in blocks and blocks["layer0"]["n_nodes"] == 2
    assert "__bookkeeping__" in blocks
    dot = to_dot(b.graph)
    assert dot.startswith("digraph") and '"layer1"' in dot
    assert "__bookkeeping__" in dot


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b"])
def test_extra_pool_archs_smoke(arch):
    from repro.launch.steps import build_step
    from repro.models.params import init_params
    from repro.optim import adamw_init

    cfg = get_config(arch, smoke=True)
    sb = build_step(cfg, "train_4k",
                    hparam_overrides={"compute_dtype": jnp.float32})
    rs = np.random.RandomState(0)
    feeds = {"tokens": jnp.array(rs.randint(0, cfg.vocab_size, (2, 16)), jnp.int32),
             "labels": jnp.array(rs.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    params = init_params(sb.model.describe_params(), jax.random.PRNGKey(0))
    loss, _ = sb.fn(feeds, {"params": params, "opt": adamw_init(params)})
    assert np.isfinite(float(loss))
