"""§4.6 queue OPS inside graphs (async kernels, §5.3): Enqueue/Dequeue
nodes coordinate producer and consumer graphs through a shared queue."""
import threading

import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Session
from repro.runtime.queues import FIFOQueue


def test_enqueue_dequeue_ops_between_sessions():
    q = FIFOQueue(capacity=4, timeout=5.0)

    # producer graph: enqueue a computed tensor
    bp = GraphBuilder()
    x = bp.placeholder("x")
    sq = bp.square(x, name="sq")
    enq = bp.graph.add_node("QueueEnqueue", [sq], name="enq",
                            attrs={"queue": "q"})
    prod = Session(bp.graph)
    prod.register_queue("q", q)

    # consumer graph: dequeue and keep computing
    bc = GraphBuilder()
    deq = bc.graph.add_node("QueueDequeue", [], name="deq",
                            attrs={"queue": "q", "n_components": 1})
    out = bc.reduce_sum(deq, name="out")
    cons = Session(bc.graph)
    cons.register_queue("q", q)

    results = []

    def consume():
        for _ in range(3):
            results.append(float(cons.run(out.ref)))

    t = threading.Thread(target=consume)
    t.start()
    for v in (2.0, 3.0, 4.0):
        prod.run(enq.ref, {x.ref: jnp.full((2,), v)})
    t.join(timeout=10)
    assert results == [8.0, 18.0, 32.0]  # 2*v^2 in arrival order


def test_queue_as_gradient_accumulator():
    """§4.6: 'accumulating many gradients ... over a larger batch'."""
    from repro.core import gradients

    q = FIFOQueue(capacity=16, timeout=5.0)
    b = GraphBuilder()
    W = b.variable("W", init_value=lambda: jnp.array([[2.0]]))
    x = b.placeholder("x")
    loss = b.reduce_mean(b.square(b.matmul(x, W)), name="loss")
    (gW,) = gradients(b.graph, [loss], [W])
    enq = b.graph.add_node("QueueEnqueue", [gW], name="enq",
                           attrs={"queue": "gq"})
    sess = Session(b.graph)
    sess.register_queue("gq", q)
    for v in (1.0, 2.0, 3.0):
        sess.run(enq.ref, {x.ref: jnp.array([[v]])})
    grads = q.dequeue_many(3)  # each entry is the enqueue's value tuple
    combined = sum(np.asarray(g[0]) for g in grads) / 3
    # d/dW mean((xW)^2) = 2 x^2 W ; mean over {1,4,9} = 2*2*14/3
    np.testing.assert_allclose(combined, [[2 * 2.0 * (1 + 4 + 9) / 3]])
