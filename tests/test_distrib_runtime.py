"""DESIGN.md §11 multi-process runtime: real OS processes over TCP.

The acceptance contract of the distributed subsystem: a 2-process run
over the wire rendezvous bit-matches the equivalent in-process strict
run (straight-line pipelines, train steps with §4.1 gradients, §4.4
loops — including zero-iteration — and cross-process conds), §5.5
compressed edges behave identically, and killing a worker mid-training
recovers from the last checkpoint.

Worker processes are spawned once per module (jax import dominates
startup); the kill/recovery test owns its own pools.
"""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, Session, TensorRef, cond, while_loop
from repro.core.executable import Executable
from repro.core.executor import ExecutorError
from repro.launch.steps import build_wire_train_step
from repro.runtime.devices import DeviceSet
from repro.distrib import start_worker_processes, stop_worker_processes

T0, T1 = "/job:worker/task:0", "/job:worker/task:1"
TASKS = [T0, T1]


@pytest.fixture(scope="module")
def pool():
    procs, spec = start_worker_processes(2)
    yield spec
    stop_worker_processes(procs, spec)


@pytest.fixture
def sessions():
    created = []
    yield created
    for s in created:
        s.close()


def _session(sessions, graph, **kw):
    s = Session(graph, **kw)
    sessions.append(s)
    return s


def _in_process_devices():
    return DeviceSet.make_cluster(2, 1, kind="cpu")


def _pipeline_graph():
    b = GraphBuilder()
    data = b.constant(jnp.asarray(np.random.RandomState(0).randn(64, 64),
                                  dtype=jnp.float32), name="data", device=T0)
    w = b.constant(jnp.asarray(np.random.RandomState(1).randn(64, 64) * 0.05,
                               dtype=jnp.float32), name="w", device=T1)
    h = b.relu(b.matmul(data, w, name="mm", device=T1), name="h", device=T1)
    out = b.reduce_sum(h, name="out", device=T0)
    return b, out


def test_two_process_pipeline_bitmatches_in_process(pool, sessions):
    b, out = _pipeline_graph()
    sess = _session(sessions, b.graph, cluster=pool)
    wire1 = sess.run(out.ref)
    wire2 = sess.run(out.ref)
    assert sess.cache_stats["hits"] >= 1  # §3.2 "caches these graphs"
    b2, out2 = _pipeline_graph()
    ref = _session(sessions, b2.graph, devices=_in_process_devices()).run(out2.ref)
    np.testing.assert_array_equal(np.asarray(wire1), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(wire2), np.asarray(ref))
    # genuinely two processes moving tensors over the wire
    plan = sess.executable([out.ref], set()).wire_plan
    assert sum(s["remote_fetches"] for s in plan.last_run_stats.values()) > 0
    # pids arrive on the heartbeat monitor's cadence: poll, don't race it
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        pids = {plan.master._info.get(t, {}).get("pid") for t in (0, 1)}
        pids.discard(None)
        if len(pids) == 2:
            break
        time.sleep(0.1)
    assert os.getpid() not in pids and len(pids) == 2


def _batch(i, n=32):
    rs = np.random.RandomState(1000 + i)
    return (jnp.asarray(rs.randn(n, 16).astype("f")),
            jnp.asarray(rs.randint(0, 8, (n,)).astype("i")))


def test_two_process_train_step_bitmatches_in_process_strict(pool, sessions):
    """The acceptance criterion: N train steps (forward, §4.1 backward,
    SGD Assigns) over the wire == the in-process strict run, bit for bit
    — losses each step AND final Variable state."""
    ws = build_wire_train_step(TASKS, seed=3)
    ref_sess = _session(sessions, ws.builder.graph, devices=_in_process_devices())
    ref_run = ref_sess.make_callable([ws.loss, ws.train_op],
                                     [ws.feed_x, ws.feed_y])
    ref_losses = [np.asarray(ref_run(*_batch(i))[0]) for i in range(4)]

    ws2 = build_wire_train_step(TASKS, seed=3)
    sess = _session(sessions, ws2.builder.graph, cluster=pool)
    run = sess.make_callable([ws2.loss, ws2.train_op],
                             [ws2.feed_x, ws2.feed_y])
    wire_losses = [np.asarray(run(*_batch(i))[0]) for i in range(4)]
    np.testing.assert_array_equal(np.asarray(wire_losses),
                                  np.asarray(ref_losses))
    pulled = sess.pull_cluster_variables()
    for name in ws.var_names:
        np.testing.assert_array_equal(np.asarray(pulled[name]),
                                      np.asarray(ref_sess.variable_value(name)))
    assert sess.cache_stats["hits"] >= 3  # one Executable, many runs


def _loop_graph(limit):
    b = GraphBuilder()
    i0 = b.constant(jnp.array(0), name="i0", device=T0)
    acc0 = b.constant(jnp.array(0.0), name="acc0", device=T0)
    lim = b.constant(jnp.array(limit), name="lim")
    one = b.constant(jnp.array(1), name="one")
    outs = while_loop(
        b, lambda i, a: b.less(i, lim),
        lambda i, a: [b.add(i, one, name="inc", device=T1),
                      b.add(a, b.mul(b.cast(i, "float32"),
                                     b.cast(i, "float32"), name="sq",
                                     device=T1),
                            name="acc", device=T0)],
        [i0, acc0])
    return b, outs


def test_cross_process_loop_bitmatches_single_device(pool, sessions):
    """§4.4 distributed control flow across *processes*: the per-iteration
    predicate broadcast and the DEAD_TENSOR terminating markers all cross
    the wire inside loop-frame-tagged rendezvous keys."""
    b, outs = _loop_graph(5)
    multi = _session(sessions, b.graph, cluster=pool).run(outs)
    b2, outs2 = _loop_graph(5)
    single = _session(sessions, b2.graph).run(outs2)
    assert int(multi[0]) == int(single[0]) == 5
    np.testing.assert_array_equal(np.asarray(multi[1]), np.asarray(single[1]))


def test_zero_iteration_loop_across_processes(pool, sessions):
    """Predicate false on iteration 0: the broadcast kills the replica
    skeleton in the other *process* immediately — every in-frame Recv sees
    a dead iteration token and the dead marker crosses the wire."""
    b, outs = _loop_graph(0)
    multi = _session(sessions, b.graph, cluster=pool).run(outs)
    assert int(multi[0]) == 0 and float(multi[1]) == 0.0


def test_cross_process_cond_both_branches(pool, sessions):
    """Branches on different processes: §4.4 deadness as a wire marker."""
    b = GraphBuilder()
    p = b.placeholder("p")
    x = b.constant(jnp.array(3.0), name="x", device=T0)
    res = cond(b, p,
               lambda t: [b.mul(t, t, name="tb", device=T1)],
               lambda f: [b.neg(f, name="fb", device=T0)], [x])
    sess = _session(sessions, b.graph, cluster=pool)
    assert float(sess.run(res, {TensorRef("p", 0): jnp.array(True)})[0]) == 9.0
    assert float(sess.run(res, {TensorRef("p", 0): jnp.array(False)})[0]) == -3.0


def test_compress16_edges_match_in_process_compressed_run(pool, sessions):
    """§5.5 lossy compression on cross-process edges: identical bits to
    the in-process compressed run (compression happens producer-side, the
    uint16 wire format rides the codec untouched)."""
    b, out = _pipeline_graph()
    sess = _session(sessions, b.graph, cluster=pool)
    exe = Executable(sess, [out.ref], set(),
                     node_set=sess.pruned_nodes([out.ref], {}), compress=True)
    wire_lossy = exe.run({})[0]

    b2, out2 = _pipeline_graph()
    s2 = _session(sessions, b2.graph, devices=_in_process_devices())
    exe2 = Executable(s2, [out2.ref], set(),
                      node_set=s2.pruned_nodes([out2.ref], {}), compress=True,
                      force_partitioned=True)
    local_lossy = exe2.run({})[0]
    np.testing.assert_array_equal(np.asarray(wire_lossy),
                                  np.asarray(local_lossy))
    exact = s2.run(out2.ref)
    # sum over 64 products of compressed factors: loose sanity bound only
    rel = abs(float(wire_lossy) - float(exact)) / max(abs(float(exact)), 1e-6)
    assert rel < 64 * 2 ** -7


def test_single_worker_cluster_still_executes_in_worker_process(pool, sessions):
    """A one-task cluster must not silently fall back to local execution."""
    from repro.distrib.wire import ClusterSpec

    solo = ClusterSpec((pool.workers[0],))
    b = GraphBuilder()
    x = b.constant(jnp.arange(4.0, dtype=jnp.float32), name="x", device=T0)
    y = b.reduce_sum(b.mul(x, x, name="xx", device=T0), name="y", device=T0)
    sess = _session(sessions, b.graph, cluster=solo)
    assert float(sess.run(y.ref)) == float(np.sum(np.arange(4.0) ** 2))
    exe = sess.executable([y.ref], set())
    assert exe.wire_plan is not None


def test_second_executable_does_not_reset_worker_variables(pool, sessions):
    """Registering a new run signature mid-training (e.g. an eval-only
    fetch) must SEED-only: the workers' stores hold the trained weights,
    and the master's stale initial values must never clobber them."""
    ws = build_wire_train_step(TASKS, seed=11)
    ref_sess = _session(sessions, ws.builder.graph,
                        devices=_in_process_devices())
    ref_run = ref_sess.make_callable([ws.loss, ws.train_op],
                                     [ws.feed_x, ws.feed_y])
    for i in range(4):
        ref_run(*_batch(i))

    ws2 = build_wire_train_step(TASKS, seed=11)
    sess = _session(sessions, ws2.builder.graph, cluster=pool)
    run = sess.make_callable([ws2.loss, ws2.train_op],
                             [ws2.feed_x, ws2.feed_y])
    for i in range(2):
        run(*_batch(i))
    # a different signature -> new Executable -> new WirePlan registration
    eval_loss = sess.run(ws2.loss, {ws2.feed_x: _batch(0)[0],
                                    ws2.feed_y: _batch(0)[1]})
    assert np.isfinite(float(eval_loss))
    for i in range(2, 4):
        run(*_batch(i))
    final = sess.pull_cluster_variables()
    for name in ws.var_names:
        np.testing.assert_array_equal(np.asarray(final[name]),
                                      np.asarray(ref_sess.variable_value(name)))


def test_worker_kill_recovery_from_checkpoint():
    """§3.3 end to end: kill a worker mid-training, detect it with an
    ExecutorError naming the lost process/host, restart the pool, restore
    the last checkpoint, and finish bit-identical to an uninterrupted
    in-process run."""
    ws = build_wire_train_step(TASKS, seed=7)
    ref_sess = Session(ws.builder.graph, devices=_in_process_devices())
    ref_run = ref_sess.make_callable([ws.loss, ws.train_op],
                                     [ws.feed_x, ws.feed_y])
    for i in range(6):
        ref_run(*_batch(i))
    ref_vars = {n: np.asarray(ref_sess.variable_value(n))
                for n in ws.var_names}

    procs, spec = start_worker_processes(2, rendezvous_timeout=10.0)
    sess = None
    procs2 = spec2 = None
    try:
        ws2 = build_wire_train_step(TASKS, seed=7)
        sess = Session(ws2.builder.graph, cluster=spec)
        run = sess.make_callable([ws2.loss, ws2.train_op],
                                 [ws2.feed_x, ws2.feed_y])
        ckpts = {}
        for i in range(3):
            run(*_batch(i))
            # master-side checkpoint: pull Variable state from the pool
            ckpts[i + 1] = {k: np.asarray(v)
                            for k, v in sess.pull_cluster_variables().items()}
        procs[1].kill()  # hard kill: no shutdown handshake, no flush
        time.sleep(0.2)
        with pytest.raises(ExecutorError) as ei:
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:  # first post-kill run may race
                run(*_batch(3))
        msg = str(ei.value)
        assert "task:1" in msg  # names the lost process, not just a device
        assert spec.workers[1].rsplit(":", 1)[1] in msg  # ...and its endpoint

        # restart the pool, restore the last checkpoint, resume
        procs2, spec2 = start_worker_processes(2, rendezvous_timeout=10.0)
        for name, value in ckpts[3].items():
            sess.set_variable(name, value)
        sess.rebind_cluster(spec2)
        for i in range(3, 6):
            run(*_batch(i))
        final = {k: np.asarray(v)
                 for k, v in sess.pull_cluster_variables().items()}
        for name in ws.var_names:
            np.testing.assert_array_equal(final[name], ref_vars[name])
    finally:
        if sess is not None:
            sess.close()
        stop_worker_processes(procs, spec)
        if procs2 is not None:
            stop_worker_processes(procs2, spec2)


def test_rebinding_to_wrong_shape_pool_is_rejected():
    from repro.distrib.wire import ClusterSpec
    from repro.distrib.master import Master

    m = Master(ClusterSpec(("127.0.0.1:1", "127.0.0.1:2")),
               heartbeat_interval=0)  # no hb thread: topology check only
    with pytest.raises(ValueError, match="placement is per-task"):
        m.reset(ClusterSpec(("127.0.0.1:1",)))
    m.stop()
