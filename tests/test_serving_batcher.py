"""Continuous-batching serving layer: correctness vs sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import Model
from repro.models.params import init_params
from repro.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_decode(model, params, prompt, n_new, max_seq=64):
    cache = init_params(model.init_cache_desc(batch=1, max_seq=max_seq),
                        jax.random.PRNGKey(1))
    toks = list(prompt)
    out = []
    pos = 0
    logits = None
    for t in toks:
        logits, cache = model.serve_step(
            params, cache, jnp.array([[t]], jnp.int32), jnp.array(pos))
        pos += 1
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0, 0, : model.cfg.vocab_size]))
        out.append(nxt)
        logits, cache = model.serve_step(
            params, cache, jnp.array([[nxt]], jnp.int32), jnp.array(pos))
        pos += 1
    return out


def test_batched_requests_match_sequential(setup):
    cfg, model, params = setup
    rs = np.random.RandomState(0)
    prompts = [list(rs.randint(0, cfg.vocab_size, (n,)))
               for n in (3, 5, 4, 6, 2)]
    want = [_sequential_decode(model, params, p, 6) for p in prompts]

    batcher = ContinuousBatcher(model, params, n_slots=3, max_seq=64)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    results = batcher.run_until_drained()
    assert len(results) == len(prompts)
    for i in range(len(prompts)):
        assert results[i].tokens == want[i], (i, results[i].tokens, want[i])


def test_continuous_refill_keeps_slots_busy(setup):
    cfg, model, params = setup
    rs = np.random.RandomState(1)
    batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
    for i in range(6):
        batcher.submit(Request(rid=i, prompt=list(rs.randint(0, 64, (2,))),
                               max_new_tokens=3))
    results = batcher.run_until_drained()
    assert len(results) == 6
    # 6 requests through 2 slots: slots were refilled continuously
    assert batcher.occupancy() > 0.8


def test_eos_terminates_early(setup):
    cfg, model, params = setup
    # find the greedy first token, then use it as eos
    first = _sequential_decode(model, params, [1, 2, 3], 1)[0]
    batcher = ContinuousBatcher(model, params, n_slots=1, max_seq=64)
    batcher.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10,
                           eos_id=first))
    results = batcher.run_until_drained()
    assert results[0].tokens == [first]
