"""§9.1 graph-structure visualisation (TensorBoard's graph pane).

The paper's approach for 36k-node graphs: collapse nodes into high-level
blocks by name prefix, and separate out high-degree "bookkeeping" nodes.
``to_dot`` renders a repro.core Graph as Graphviz DOT with exactly those
two transforms; ``collapse_summary`` gives the textual block view used by
tests and terminals.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

from ..core.graph import Graph


def _block_of(name: str, depth: int) -> str:
    parts = name.split("/")
    return "/".join(parts[:depth]) if len(parts) > depth else name


def collapse_summary(g: Graph, depth: int = 1,
                     high_degree: int = 8) -> Dict[str, Dict]:
    """Collapse nodes into prefix blocks; returns
    {block: {n_nodes, ops, edges_out}} with high-degree nodes separated."""
    degree: Dict[str, int] = defaultdict(int)
    for node in g.nodes.values():
        for d in g.deps(node):
            degree[d] += 1
    bookkeeping = {n for n, c in degree.items() if c >= high_degree}

    blocks: Dict[str, Dict] = {}
    block_of: Dict[str, str] = {}
    for name, node in g.nodes.items():
        blk = "__bookkeeping__" if name in bookkeeping else _block_of(name, depth)
        block_of[name] = blk
        b = blocks.setdefault(blk, {"n_nodes": 0, "ops": set(), "edges_out": set()})
        b["n_nodes"] += 1
        b["ops"].add(node.op)
    for name, node in g.nodes.items():
        for d in g.deps(node):
            if d in block_of and block_of[d] != block_of[name]:
                blocks[block_of[d]]["edges_out"].add(block_of[name])
    return blocks


def to_dot(g: Graph, depth: int = 1, high_degree: int = 8,
           title: str = "graph") -> str:
    blocks = collapse_summary(g, depth=depth, high_degree=high_degree)
    lines = [f'digraph "{title}" {{', "  rankdir=TB;",
             '  node [shape=box, style=rounded];']
    for blk, info in sorted(blocks.items()):
        label = f"{blk}\\n{info['n_nodes']} nodes"
        shape = ', shape=ellipse, style=dashed' if blk == "__bookkeeping__" else ""
        lines.append(f'  "{blk}" [label="{label}"{shape}];')
    for blk, info in sorted(blocks.items()):
        for dst in sorted(info["edges_out"]):
            lines.append(f'  "{blk}" -> "{dst}";')
    lines.append("}")
    return "\n".join(lines)
