"""§9.1 graph-structure visualisation (TensorBoard's graph pane).

The paper's approach for 36k-node graphs: collapse nodes into high-level
blocks by name prefix, and separate out high-degree "bookkeeping" nodes.
``to_dot`` renders a repro.core Graph as Graphviz DOT with exactly those
two transforms; ``collapse_summary`` gives the textual block view used by
tests and terminals.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

from ..core.graph import Graph


def _block_of(name: str, depth: int) -> str:
    parts = name.split("/")
    return "/".join(parts[:depth]) if len(parts) > depth else name


def collapse_summary(g: Graph, depth: int = 1,
                     high_degree: int = 8) -> Dict[str, Dict]:
    """Collapse nodes into prefix blocks; returns
    {block: {n_nodes, ops, edges_out}} with high-degree nodes separated."""
    degree: Dict[str, int] = defaultdict(int)
    for node in g.nodes.values():
        for d in g.deps(node):
            degree[d] += 1
    bookkeeping = {n for n, c in degree.items() if c >= high_degree}

    blocks: Dict[str, Dict] = {}
    block_of: Dict[str, str] = {}
    for name, node in g.nodes.items():
        blk = "__bookkeeping__" if name in bookkeeping else _block_of(name, depth)
        block_of[name] = blk
        b = blocks.setdefault(blk, {"n_nodes": 0, "ops": set(), "edges_out": set()})
        b["n_nodes"] += 1
        b["ops"].add(node.op)
    for name, node in g.nodes.items():
        for d in g.deps(node):
            if d in block_of and block_of[d] != block_of[name]:
                blocks[block_of[d]]["edges_out"].add(block_of[name])
    return blocks


def to_dot(g: Graph, depth: int = 1, high_degree: int = 8,
           title: str = "graph", diagnostics=()) -> str:
    """Block-collapsed DOT.  With §14 verifier ``diagnostics``, blocks
    containing offending nodes are outlined red and carry the diagnostic
    codes in their label + tooltip — a lint failure links to a picture."""
    blocks = collapse_summary(g, depth=depth, high_degree=high_degree)
    flagged = _codes_by_node(diagnostics)
    block_codes: Dict[str, Set[str]] = defaultdict(set)
    degree: Dict[str, int] = defaultdict(int)
    for node in g.nodes.values():
        for d in g.deps(node):
            degree[d] += 1
    bookkeeping = {n for n, c in degree.items() if c >= high_degree}
    for name, codes in flagged.items():
        if name in g.nodes:
            blk = ("__bookkeeping__" if name in bookkeeping
                   else _block_of(name, depth))
            block_codes[blk] |= codes
    lines = [f'digraph "{title}" {{', "  rankdir=TB;",
             '  node [shape=box, style=rounded];']
    for blk, info in sorted(blocks.items()):
        label = f"{blk}\\n{info['n_nodes']} nodes"
        shape = ', shape=ellipse, style=dashed' if blk == "__bookkeeping__" else ""
        extra = ""
        if blk in block_codes:
            codes = ",".join(sorted(block_codes[blk]))
            label += f"\\n[{codes}]"
            extra = (f', color=red, penwidth=2.0'
                     f', tooltip="{codes}"')
        lines.append(f'  "{blk}" [label="{label}"{shape}{extra}];')
    for blk, info in sorted(blocks.items()):
        for dst in sorted(info["edges_out"]):
            lines.append(f'  "{blk}" -> "{dst}";')
    lines.append("}")
    return "\n".join(lines)


def _codes_by_node(diagnostics) -> Dict[str, Set[str]]:
    out: Dict[str, Set[str]] = defaultdict(set)
    for d in diagnostics or ():
        for n in d.nodes:
            out[n].add(d.code)
    return dict(out)


def to_dot_diagnostics(g: Graph, diagnostics, title: str = "lint",
                       context: int = 1) -> str:
    """Node-level DOT focused on §14 verifier findings: every offending
    node outlined red with its diagnostic codes in the label and the full
    messages in the tooltip, plus ``context`` hops of neighborhood so the
    picture shows where the bad edge should have been.  Falls back to the
    whole graph when nothing is flagged (or the graph is small)."""
    flagged = _codes_by_node(diagnostics)
    messages: Dict[str, List[str]] = defaultdict(list)
    for d in diagnostics or ():
        for n in d.nodes:
            messages[n].append(f"{d.code}: {d.message}")
    keep: Set[str] = set(flagged) & set(g.nodes)
    if not keep or len(g.nodes) <= 60:
        keep = set(g.nodes)
    else:
        for _ in range(max(context, 0)):
            grow = set(keep)
            for name, node in g.nodes.items():
                ds = set(g.deps(node))
                if name in keep:
                    grow |= ds
                elif ds & keep:
                    grow.add(name)
            keep = grow & set(g.nodes)
    lines = [f'digraph "{title}" {{', "  rankdir=TB;",
             '  node [shape=box, fontsize=10];']
    for name in sorted(keep):
        node = g.nodes[name]
        label = f"{name}\\n{node.op}"
        extra = ""
        if name in flagged:
            codes = ",".join(sorted(flagged[name]))
            tip = "; ".join(messages[name])[:500].replace('"', "'")
            label += f"\\n[{codes}]"
            extra = f', color=red, penwidth=2.0, tooltip="{tip}"'
        lines.append(f'  "{name}" [label="{label}"{extra}];')
    for name in sorted(keep):
        node = g.nodes[name]
        for ref in node.inputs:
            if ref.node in keep:
                lines.append(f'  "{ref.node}" -> "{name}";')
        for c in node.control_inputs:
            if c in keep:
                lines.append(f'  "{c}" -> "{name}" [style=dashed];')
    lines.append("}")
    return "\n".join(lines)
