from .summary import SummaryWriter, attach_scalar_summary, read_events
from .tracing import Tracer, chrome_trace

__all__ = ["SummaryWriter", "attach_scalar_summary", "read_events",
           "Tracer", "chrome_trace"]
