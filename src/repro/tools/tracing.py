"""§9.2 EEG analogue: fine-grained execution tracing (legacy front-end).

:class:`Tracer` is the original in-process tracing API, kept working as
a thin adapter over the §16 span stream (:mod:`repro.obs.spans`): the
executor still calls ``record``/``record_wait`` with raw timestamps, but
the events land in a :class:`~repro.obs.spans.SpanRecorder` and the
legacy ``events`` view is derived from it.  For multi-process tracing
use ``Session(trace_dir=)`` — the span pipeline this adapter rides.

``critical_stalls`` reads the dedicated Recv-*wait* spans, not total
Recv duration: a Recv whose tensor was already sitting in the rendezvous
costs microseconds of transfer and zero wait, and the old
total-duration filter mislabelled exactly those as stalls.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List

from ..obs import spans as spans_mod
from ..obs import export as export_mod


class Tracer:
    def __init__(self) -> None:
        self.spans = spans_mod.SpanRecorder(process="local")
        self._t0 = time.time()

    def record(self, node_name: str, op: str, device: str,
               t_start: float, t_end: float, frame: Any = ()) -> None:
        self.spans.record(node_name, spans_mod.CAT_OP, device, t_start, t_end,
                          args={"op": op, "frame": str(frame)})

    def record_wait(self, node_name: str, device: str,
                    t_start: float, t_end: float, frame: Any = ()) -> None:
        """Time the executor spent blocked on the rendezvous for this
        node (Recv not ready, or a deferral ``wait_any``)."""
        self.spans.record(node_name, spans_mod.CAT_WAIT, device,
                          t_start, t_end,
                          args={"op": "RecvWait", "frame": str(frame)})

    def now(self) -> float:
        return time.time()

    @property
    def events(self) -> List[Dict[str, Any]]:
        """The legacy event view: microseconds relative to construction."""
        out = []
        for e in self.spans.snapshot():
            args = e.get("args", {})
            out.append({
                "name": e["name"],
                "op": args.get("op", e["cat"]),
                "device": e["device"],
                "ts": (e["ts"] - self._t0) * 1e6,
                "dur": max(e["dur"] * 1e6, 0.01),
                "frame": args.get("frame", "()"),
            })
        return out

    def summarize(self) -> Dict[str, Dict[str, float]]:
        """Total time per op type (the EEG 'summarize at detail level')."""
        out: Dict[str, Dict[str, float]] = {}
        for e in self.events:
            s = out.setdefault(e["op"], {"count": 0, "total_us": 0.0})
            s["count"] += 1
            s["total_us"] += e["dur"]
        return out

    def critical_stalls(self, threshold_us: float = 100.0) -> List[Dict]:
        """Rendezvous waits longer than threshold (highlighted with arrows
        in the paper's UI; we just list them).  Reads the wait spans —
        wait time, not transfer time."""
        return [e for e in self.events
                if e["op"] == "RecvWait" and e["dur"] >= threshold_us]


def chrome_trace(tracer: Tracer) -> str:
    """Chrome trace-event JSON for one in-process tracer (single stream
    through the §16 merge — same layout as ``Session(trace_dir=)``)."""
    obj = export_mod.merge_streams([{
        "process": tracer.spans.process,
        "offset_s": 0.0,
        "events": tracer.spans.snapshot(),
    }])
    return json.dumps(obj)
