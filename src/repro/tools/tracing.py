"""§9.2 EEG analogue: fine-grained execution tracing.

A :class:`Tracer` records (node, device, start, end, frame) for every
kernel the eager executor dispatches; ``chrome_trace`` converts the
record stream into the Chrome trace-event JSON format (load in
chrome://tracing or Perfetto — the modern stand-in for the paper's EEG
visualisation server).  Cross-device Send/Recv pairs show up as separate
lanes, making communication stalls visible exactly as in Figures 12-14.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple


class Tracer:
    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    def record(self, node_name: str, op: str, device: str,
               t_start: float, t_end: float, frame: Any = ()) -> None:
        with self._lock:
            self.events.append({
                "name": node_name, "op": op, "device": device,
                "ts": (t_start - self._t0) * 1e6,
                "dur": max((t_end - t_start) * 1e6, 0.01),
                "frame": str(frame),
            })

    def now(self) -> float:
        return time.perf_counter()

    def summarize(self) -> Dict[str, Dict[str, float]]:
        """Total time per op type (the EEG 'summarize at detail level')."""
        out: Dict[str, Dict[str, float]] = {}
        for e in self.events:
            s = out.setdefault(e["op"], {"count": 0, "total_us": 0.0})
            s["count"] += 1
            s["total_us"] += e["dur"]
        return out

    def critical_stalls(self, threshold_us: float = 100.0) -> List[Dict]:
        """Recv-side waits longer than threshold (highlighted with arrows
        in the paper's UI; we just list them)."""
        return [e for e in self.events
                if e["op"] == "Recv" and e["dur"] >= threshold_us]


def chrome_trace(tracer: Tracer) -> str:
    """Chrome trace-event JSON (one lane per device)."""
    devices = sorted({e["device"] for e in tracer.events})
    pid_of = {d: i for i, d in enumerate(devices)}
    events = [{"name": d, "ph": "M", "pid": pid_of[d], "tid": 0,
               "args": {"name": d}, "cat": "__metadata"}
              for d in devices]
    for e in tracer.events:
        events.append({
            "name": f"{e['op']}:{e['name']}", "ph": "X",
            "pid": pid_of[e["device"]], "tid": 0,
            "ts": e["ts"], "dur": e["dur"],
            "args": {"frame": e["frame"]},
        })
    return json.dumps({"traceEvents": events})
