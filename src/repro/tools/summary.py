"""§9.1 TensorBoard analogue: Summary ops + event-log writer/reader.

Summary nodes are inserted into the graph; every so often the client
fetches them alongside the training step and the writer appends
(step, wall_time, tag, value) records to a JSONL log.  ``read_events``
is the "TensorBoard watching the log file" half: it tails the log and
returns time series (by step or wall time), including histogram
summaries (stored as bucket counts).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

import numpy as np

from ..core.graph import Node
from ..core.ops import GraphBuilder, register


@register("ScalarSummary")
def _scalar_summary(ctx, node, value):
    import jax.numpy as jnp

    return (jnp.asarray(value, jnp.float32).reshape(()),)


@register("HistogramSummary")
def _histogram_summary(ctx, node, value):
    import jax.numpy as jnp

    v = jnp.ravel(value).astype(jnp.float32)
    lo, hi = jnp.min(v), jnp.max(v)
    edges = jnp.linspace(lo, hi + 1e-9, node.attrs.get("bins", 16) + 1)
    counts = jnp.histogram(v, bins=edges)[0]
    return (jnp.concatenate([edges[:-1], counts.astype(jnp.float32)]),)


def attach_scalar_summary(b: GraphBuilder, tensor, tag: str) -> Node:
    return b.graph.add_node("ScalarSummary", [tensor],
                            name=f"summary/{tag}", attrs={"tag": tag})


def attach_histogram_summary(b: GraphBuilder, tensor, tag: str,
                             bins: int = 16) -> Node:
    return b.graph.add_node("HistogramSummary", [tensor],
                            name=f"summary_hist/{tag}",
                            attrs={"tag": tag, "bins": bins})


class SummaryWriter:
    def __init__(self, logdir: str, flush_every: int = 16) -> None:
        os.makedirs(logdir, exist_ok=True)
        self.path = os.path.join(logdir, "events.jsonl")
        self._buf: List[str] = []
        self.flush_every = flush_every
        self._t0 = time.time()

    def add(self, step: int, tag: str, value: Any) -> None:
        rec = {"step": int(step), "wall_time": time.time() - self._t0,
               "tag": tag}
        arr = np.asarray(value)
        rec["value"] = float(arr) if arr.ndim == 0 else arr.tolist()
        self._buf.append(json.dumps(rec))
        if len(self._buf) >= self.flush_every:
            self.flush()

    def add_fetched(self, step: int, summary_nodes: Sequence[Node],
                    values: Sequence[Any]) -> None:
        for node, val in zip(summary_nodes, values):
            self.add(step, node.attrs["tag"], val)

    def flush(self) -> None:
        if self._buf:
            with open(self.path, "a") as f:
                f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    def close(self) -> None:
        self.flush()


def read_events(logdir: str, tag: Optional[str] = None,
                time_axis: str = "step") -> Dict[str, List]:
    """Time series per tag: {'tag': [(t, value), ...]} — t is 'step' or
    'wall_time' (the paper's selectable measurement of "time")."""
    path = os.path.join(logdir, "events.jsonl")
    out: Dict[str, List] = {}
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            if tag is not None and rec["tag"] != tag:
                continue
            out.setdefault(rec["tag"], []).append(
                (rec[time_axis], rec["value"]))
    for series in out.values():
        series.sort(key=lambda tv: tv[0])
    return out
