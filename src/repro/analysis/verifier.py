"""The verifier driver: run the §14 pass suite over a graph or plan.

Entry points:

* :func:`verify_graph` — the core: run every pass over (graph, node set,
  fetches, feeds, optional placement), returning a
  :class:`~repro.analysis.diagnostics.VerifyReport`.
* :func:`verify_executable` — called once per Executable *build*
  (core/executable.py); the report rides the Executable, so cache hits
  re-run no analysis.  ``STATS`` counts pass invocations to make that
  property testable.
* :func:`verify_wire_plan` — called by WirePlan before shipping slices
  to workers: per-task slice self-containment plus the global
  rendezvous pairing.
* :func:`enforce` — maps a report through the Session verify mode:
  ``"off"`` (never called), ``"warn"`` (GraphVerifyWarning), ``"error"``
  (GraphError listing every error diagnostic).
"""
from __future__ import annotations

import warnings
from typing import Dict, Iterable, List, Optional

from . import deadness, frames, races, sendrecv, shapes
from .common import AnalysisContext
from .diagnostics import (Diagnostic, GraphVerifyWarning, VerifyReport,
                          apply_suppressions, internal_failure, make)
from ..core.graph import Graph, GraphError
from ..obs.metrics import StatsDict

# (name, pass fn) — shapes runs before sendrecv so the rendezvous
# consistency check (C205) sees the inferred Send payload specs
PASSES = (
    ("frames", frames.run),
    ("shapes", shapes.run),
    ("sendrecv", sendrecv.run),
    ("races", races.run),
    ("deadness", deadness.run),
)

# pass-invocation counters: tests assert an Executable cache hit bumps
# nothing here (same pattern as placement/partition/scheduler STATS);
# registry-backed since §16.4 (verifier.* counters)
STATS = StatsDict("verifier", keys=("verify_calls", "wire_verify_calls"))
for _name, _fn in PASSES:
    STATS[_name] = 0


VERIFY_MODES = ("off", "warn", "error")


def verify_graph(graph: Graph, names: Optional[Iterable[str]] = None, *,
                 fetches: Iterable = (), feed_keys: Iterable = (),
                 placement: Optional[Dict[str, str]] = None,
                 where: str = "graph") -> VerifyReport:
    STATS["verify_calls"] += 1
    ctx = AnalysisContext(graph, names, fetches=fetches,
                          feed_keys=feed_keys, placement=placement,
                          where=where)
    diags: List[Diagnostic] = []
    for pname, fn in PASSES:
        STATS[pname] += 1
        try:
            diags.extend(fn(ctx))
        except Exception as e:  # a broken pass must not break user runs
            diags.append(internal_failure(pname, e))
    kept, n_sup = apply_suppressions(graph, diags)
    kept.sort(key=lambda d: (0 if d.severity == "error" else 1,
                             d.code, d.nodes))
    return VerifyReport(kept, n_sup, where)


def enforce(report: VerifyReport, mode: str) -> None:
    if mode == "off" or not report.diagnostics:
        return
    errs = report.errors()
    if errs and mode == "error":
        raise GraphError(
            f"graph verification failed ({report.where}): "
            f"{len(errs)} error(s)\n"
            + "\n".join("  " + d.format() for d in errs))
    shown = report.diagnostics[:5]
    more = len(report.diagnostics) - len(shown)
    warnings.warn(
        f"graph verification ({report.where}): "
        + "; ".join(d.format() for d in shown)
        + (f"; (+{more} more)" if more else ""),
        GraphVerifyWarning, stacklevel=3)


def verify_executable(exe) -> VerifyReport:
    """Run the suite for one Executable build (DESIGN.md §14 wiring).

    Multi-device builds verify the *partitioned* plan — the per-device
    schedule with its canonical Send/Recv pairs is what actually runs —
    single-device builds verify the pruned subgraph.
    """
    mode = getattr(exe.session, "verify", "warn")
    if mode == "off":
        return VerifyReport([], 0, "off")
    parted = getattr(exe, "partitioned", None)
    if parted is not None:
        report = verify_graph(
            parted.graph, None, fetches=exe.fetches,
            feed_keys=exe.feed_keys, placement=parted.placement,
            where="partitioned plan")
    else:
        report = verify_graph(
            exe.session.graph, exe.node_set, fetches=exe.fetches,
            feed_keys=exe.feed_keys, where="pruned graph")
    enforce(report, mode)
    return report


def task_slice_diagnostics(graph: Graph, slices: Dict[str, set],
                           feed_keys: Iterable = ()) -> List[Diagnostic]:
    """P601: every edge inside a shipped per-task slice must resolve
    within that slice — cross-task edges ride Send/Recv pairs, never raw
    references (a worker cannot see another task's nodes)."""
    diags: List[Diagnostic] = []
    for task in sorted(slices):
        names = slices[task]
        for n in sorted(names):
            node = graph.nodes.get(n)
            if node is None:
                continue
            for d in graph.deps(node):
                if d in graph.nodes and d not in names:
                    diags.append(make(
                        "P601",
                        f"node {n!r} in task {task!r} references {d!r} "
                        f"outside its slice; the worker executing the "
                        f"slice cannot resolve it",
                        nodes=(n, d),
                        fix="partition must rewrite cross-task edges "
                            "into Send/Recv pairs"))
    return diags


def verify_wire_plan(exe, device_nodes: Dict[str, set]) -> VerifyReport:
    """Pre-ship verification for a WirePlan: per-task slice containment
    plus the global Send/Recv pairing over the whole partitioned graph."""
    mode = getattr(exe.session, "verify", "warn")
    if mode == "off":
        return VerifyReport([], 0, "off")
    STATS["wire_verify_calls"] += 1
    from ..runtime.devices import DeviceName

    g = exe.partitioned.graph
    slices: Dict[str, set] = {}
    for dev, names in device_nodes.items():
        dn = DeviceName.parse(dev)
        slices.setdefault(f"{dn.job}:{dn.task}", set()).update(names)
    diags = task_slice_diagnostics(g, slices, exe.feed_keys)
    STATS["sendrecv"] += 1
    ctx = AnalysisContext(g, None, fetches=exe.fetches,
                          feed_keys=exe.feed_keys,
                          placement=exe.partitioned.placement,
                          where="wire plan")
    try:
        diags.extend(sendrecv.run(ctx))
    except Exception as e:
        diags.append(internal_failure("sendrecv", e))
    kept, n_sup = apply_suppressions(g, diags)
    kept.sort(key=lambda d: (0 if d.severity == "error" else 1,
                             d.code, d.nodes))
    report = VerifyReport(kept, n_sup, "wire plan")
    enforce(report, mode)
    return report
