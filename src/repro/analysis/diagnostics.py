"""Structured diagnostics for the pre-execution graph verifier (DESIGN.md §14).

Every analysis pass reports :class:`Diagnostic` records with a *stable*
code from :data:`CODES` — codes are API: tests assert them, the
``verify_ignore`` node annotation suppresses them, and the lint CLI and
CI summary tables key on them.  Severity is fixed per code (the policy
lives in the table, not in call sites) so a pass cannot accidentally
demote an error to a warning.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple


class GraphVerifyWarning(UserWarning):
    """Emitted by ``Session(verify="warn")`` for any diagnostic."""


# code -> (pass name, severity, short description).  Stable: never renumber.
CODES: Dict[str, Tuple[str, str, str]] = {
    # races -------------------------------------------------------------
    "V101": ("races", "error",
             "write/write race: two unordered writes to one Variable"),
    "V102": ("races", "error",
             "read/write race: Variable read unordered with a write"),
    "V103": ("races", "warning",
             "Assign/AssignAdd target is not a Variable node"),
    # send/recv + deadlock ---------------------------------------------
    "C201": ("sendrecv", "error",
             "orphan Recv: no Send produces its rendezvous key"),
    "C202": ("sendrecv", "warning",
             "orphan Send: no Recv consumes its rendezvous key"),
    "C203": ("sendrecv", "error",
             "duplicate Send: multiple Sends share one rendezvous key"),
    "C204": ("sendrecv", "error",
             "frame-mismatched rendezvous: Send and Recv execute in "
             "different frames, so their §4.4 frame-tagged keys never match"),
    "C205": ("sendrecv", "error",
             "inconsistent rendezvous: dtype/shape/compress disagree "
             "across one rendezvous key"),
    "C206": ("sendrecv", "error",
             "deadlock: cross-device cycle through Send/Recv pairing edges"),
    # frame well-formedness --------------------------------------------
    "F301": ("frames", "error",
             "malformed control-flow frame skeleton"),
    "F302": ("frames", "error",
             "loop predicate placed off the loop's home device"),
    "F303": ("frames", "error",
             "nested loop straddles devices"),
    # static shape/dtype ------------------------------------------------
    "S401": ("shapes", "error",
             "shape/dtype mismatch: op rejects its input signatures"),
    "S402": ("shapes", "warning",
             "Assign changes the Variable's shape or dtype"),
    # deadness ----------------------------------------------------------
    "D501": ("deadness", "warning",
             "fetch reachable only through one Switch branch"),
    # wire-plan slice checks -------------------------------------------
    "P601": ("wireplan", "error",
             "task slice not self-contained: edge crosses a task "
             "boundary without a Send/Recv pair"),
    # internal ----------------------------------------------------------
    "X000": ("verifier", "warning",
             "analysis pass failed internally (diagnostic coverage lost)"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding: stable code, offending nodes, suggested fix."""

    code: str
    severity: str            # "error" | "warning"
    pass_name: str
    message: str
    nodes: Tuple[str, ...] = ()
    devices: Tuple[str, ...] = ()
    fix: str = ""

    def format(self) -> str:
        loc = []
        if self.nodes:
            loc.append("nodes: " + ", ".join(self.nodes))
        if self.devices:
            loc.append("devices: " + ", ".join(self.devices))
        head = f"{self.code} [{self.severity}] {self.message}"
        if loc:
            head += "  (" + "; ".join(loc) + ")"
        if self.fix:
            head += f"  fix: {self.fix}"
        return head


def make(code: str, message: str, *, nodes: Sequence[str] = (),
         devices: Sequence[str] = (), fix: str = "") -> Diagnostic:
    pass_name, severity, _ = CODES[code]
    return Diagnostic(code=code, severity=severity, pass_name=pass_name,
                      message=message, nodes=tuple(nodes),
                      devices=tuple(devices), fix=fix)


def internal_failure(pass_name: str, exc: BaseException) -> Diagnostic:
    return Diagnostic(
        code="X000", severity="warning", pass_name=pass_name,
        message=f"pass {pass_name!r} failed internally: "
                f"{type(exc).__name__}: {exc}",
        fix="report this; the pass found nothing, not a clean bill")


def apply_suppressions(graph, diags: Iterable[Diagnostic]
                       ) -> Tuple[List[Diagnostic], int]:
    """Drop diagnostics annotated away (DESIGN.md §14 escape hatch).

    A diagnostic is suppressed when ANY offending node carries its code in
    the node's ``verify_ignore`` attr — set at build time via
    ``attrs={"verify_ignore": ("V101",)}``, conventionally accompanied by
    a ``# verify: ignore[V101]`` comment explaining why, like a linter
    pragma.  Returns (kept, suppressed_count).
    """
    kept: List[Diagnostic] = []
    n_sup = 0
    for d in diags:
        suppressed = False
        for n in d.nodes:
            node = graph.nodes.get(n)
            if node is not None and d.code in tuple(
                    node.attrs.get("verify_ignore", ()) or ()):
                suppressed = True
                break
        if suppressed:
            n_sup += 1
        else:
            kept.append(d)
    return kept, n_sup


@dataclasses.dataclass
class VerifyReport:
    """The verifier's product for one graph/plan: sorted diagnostics."""

    diagnostics: List[Diagnostic]
    suppressed: int = 0
    where: str = "graph"

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    def format(self) -> str:
        if not self.diagnostics:
            return f"{self.where}: clean ({self.suppressed} suppressed)"
        lines = [f"{self.where}: {len(self.errors())} error(s), "
                 f"{len(self.warnings())} warning(s), "
                 f"{self.suppressed} suppressed"]
        lines += ["  " + d.format() for d in self.diagnostics]
        return "\n".join(lines)
