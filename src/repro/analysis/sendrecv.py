"""Send/Recv pairing + deadlock analysis (DESIGN.md §14 pass 2).

Rendezvous is a table keyed by string: a Recv whose key no Send produces
blocks forever (§3.3 hang), a Send nobody consumes leaks its tensor,
duplicate Sends raise at runtime, and — because the executor tags keys
with the execution frame (§4.4) — a Send and Recv that execute in
*different* static frames never meet even when their static key attrs
match.  Finally, pairing edges are happens-before edges: a cross-device
cycle through them deadlocks the whole pool.
"""
from __future__ import annotations

from typing import List

from .common import AnalysisContext
from .diagnostics import Diagnostic, make


def run(ctx: AnalysisContext) -> List[Diagnostic]:
    g = ctx.graph
    diags: List[Diagnostic] = []
    pairs = ctx.pairing()
    frames = ctx.frames()

    def dev(ns):
        return tuple(sorted({d for d in map(ctx.device_of, ns) if d}))

    for key in sorted(pairs):
        sends, recvs = pairs[key]
        if not sends:
            diags.append(make(
                "C201",
                f"Recv(s) {', '.join(map(repr, sorted(recvs)))} wait on "
                f"rendezvous key {key!r} that no Send in the plan produces "
                f"— this run hangs (§3.3)",
                nodes=tuple(sorted(recvs)), devices=dev(recvs),
                fix="add the producing Send, or prune the Recv with its "
                    "consumers"))
            continue
        if len(sends) > 1:
            diags.append(make(
                "C203",
                f"{len(sends)} Sends share rendezvous key {key!r}; the "
                f"runtime rejects the duplicate send",
                nodes=tuple(sorted(sends)), devices=dev(sends),
                fix="give each transfer a distinct key (source node, port, "
                    "destination device)"))
        if not recvs:
            diags.append(make(
                "C202",
                f"Send(s) {', '.join(map(repr, sorted(sends)))} publish "
                f"rendezvous key {key!r} that nothing receives — the "
                f"tensor leaks in the rendezvous table",
                nodes=tuple(sorted(sends)), devices=dev(sends),
                fix="drop the Send or add the consuming Recv"))
        if frames is not None:
            for s in sends:
                for r in recvs:
                    fs, fr = frames.get(s, ()), frames.get(r, ())
                    if fs != fr:
                        diags.append(make(
                            "C204",
                            f"Send {s!r} executes in frame {fs!r} but Recv "
                            f"{r!r} in frame {fr!r}; runtime keys are "
                            f"frame-tagged, so they never rendezvous",
                            nodes=(s, r), devices=dev((s, r)),
                            fix="route the transfer through the loop "
                                "skeleton (Enter/Exit) so both ends share "
                                "a frame"))
        # consistency across the key: dtype/shape (when the shapes pass
        # resolved the Send payloads) and the §5.5 compress flag
        specs = set()
        for s in sends:
            node = g.nodes[s]
            if node.inputs:
                sp = ctx.specs.get((node.inputs[0].node, node.inputs[0].port))
                if sp is not None:
                    specs.add((tuple(sp.shape), str(sp.dtype)))
        if len(specs) > 1:
            diags.append(make(
                "C205",
                f"Sends on rendezvous key {key!r} carry inconsistent "
                f"payloads {sorted(specs)}",
                nodes=tuple(sorted(sends)), devices=dev(sends),
                fix="one key must carry one dtype/shape; split the keys"))
        comp = {bool(g.nodes[n].attrs.get("compress", False))
                for n in sends + recvs}
        if len(comp) > 1:
            diags.append(make(
                "C205",
                f"compress flag disagrees across rendezvous key {key!r}: "
                f"the Recv would mis-decode the §5.5 compressed payload",
                nodes=tuple(sorted(sends + recvs)), devices=dev(sends + recvs),
                fix="set the same compress= on both ends of the pair"))

    _order, cyclic = ctx.order()
    if cyclic:
        members = sorted(cyclic)
        shown = members[:12]
        diags.append(make(
            "C206",
            f"{len(members)} node(s) form a cycle through Send/Recv "
            f"pairing edges ({', '.join(map(repr, shown))}"
            f"{', ...' if len(members) > len(shown) else ''}); every "
            f"device in the cycle waits on another — deadlock",
            nodes=tuple(shown), devices=dev(members),
            fix="break the mutual wait: reorder the transfers so some "
                "device can run first"))
    return diags
