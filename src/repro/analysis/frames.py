"""Frame well-formedness (DESIGN.md §14 pass 3).

Checks the §4.4 Enter/Merge/Switch/NextIteration/Exit skeleton invariants
the executor's tagged-frame interpreter assumes, plus — when a placement
is available — the carried ROADMAP distributed-control-flow rules
(predicate on the loop's home device, no nested loop straddling devices)
as structured diagnostics instead of ad-hoc GraphErrors.
"""
from __future__ import annotations

from typing import List

from .common import AnalysisContext
from .diagnostics import Diagnostic, make


def run(ctx: AnalysisContext) -> List[Diagnostic]:
    g = ctx.graph
    diags: List[Diagnostic] = []

    for n in sorted(ctx.names):
        node = g.nodes[n]
        if node.op == "Enter" and "frame" not in node.attrs:
            diags.append(make(
                "F301",
                f"Enter {n!r} has no 'frame' attr; the executor cannot "
                f"tag its frame",
                nodes=(n,), fix="set attrs={'frame': <loop name>}"))
        if node.op == "Switch" and len(node.inputs) != 2:
            diags.append(make(
                "F301",
                f"Switch {n!r} has {len(node.inputs)} data inputs, "
                f"expected (value, predicate)",
                nodes=(n,), fix="pass exactly [value, pred]"))
        if node.op == "Merge" and not node.inputs:
            diags.append(make(
                "F301", f"Merge {n!r} has no inputs", nodes=(n,),
                fix="a Merge needs at least one live candidate input"))
        if node.op == "Merge" and node.inputs:
            srcs = [g.nodes.get(r.node) for r in node.inputs]
            has_back = any(s is not None and s.op == "NextIteration"
                           for s in srcs)
            has_fwd = any(s is not None and s.op != "NextIteration"
                          for s in srcs)
            if has_back and not has_fwd:
                diags.append(make(
                    "F301",
                    f"Merge {n!r} has only NextIteration back edges and "
                    f"no Enter-side input; the first iteration can never "
                    f"start",
                    nodes=(n,), fix="feed the Merge an Enter of the "
                                    "initial value"))

    frames = ctx.frames()
    if frames is None:
        # static_frames did not converge — name the Enter/Exit nodes so
        # the report is actionable (the old path raised a bare ValueError)
        sus = sorted(n for n in ctx.names
                     if g.nodes[n].op in ("Enter", "Exit"))
        diags.append(make(
            "F301",
            "static frame analysis did not converge: malformed "
            "Enter/Exit nesting",
            nodes=tuple(sus[:12]),
            fix="every Exit must pop a frame some Enter pushed"))
        return diags

    for n in sorted(ctx.names):
        node = g.nodes[n]
        if node.op in ("Exit", "NextIteration"):
            src_frame = (frames.get(node.inputs[0].node, ())
                         if node.inputs else ())
            if not src_frame:
                diags.append(make(
                    "F301",
                    f"{node.op} {n!r} executes at the root frame; it must "
                    f"live inside a loop frame",
                    nodes=(n,),
                    fix="build loops via control_flow.while_loop so the "
                        "skeleton nests correctly"))

    if ctx.placement:
        diags.extend(_placement_rules(ctx, frames))
    return diags


def _placement_rules(ctx: AnalysisContext, frames) -> List[Diagnostic]:
    """Carried ROADMAP limits, reported with nodes + devices (§14)."""
    g = ctx.graph
    diags: List[Diagnostic] = []
    for lname, spec in g.loop_specs.items():
        anchors = [n for n in spec.switch_names + spec.merge_names
                   if n in ctx.names and ctx.device_of(n)]
        if not anchors:
            continue
        home = ctx.device_of(anchors[0])
        pred_nodes = [n for n in spec.cond_nodes + [f"{lname}/cond"]
                      if n in ctx.names]
        off_home = [(n, ctx.device_of(n)) for n in pred_nodes
                    if ctx.device_of(n) not in (None, home)]
        if off_home:
            ns = [n for n, _ in off_home]
            diags.append(make(
                "F302",
                f"loop {lname!r} has home device {home!r} but its "
                f"predicate node(s) "
                f"{', '.join(f'{n!r} on {d!r}' for n, d in off_home)} "
                f"compute elsewhere; the per-iteration predicate "
                f"broadcast (§4.4) requires the predicate on the home "
                f"device",
                nodes=tuple(ns + [anchors[0]]),
                devices=tuple(sorted({home} | {d for _, d in off_home})),
                fix=f"colocate the predicate with the loop skeleton "
                    f"(drop the device constraint or pin it to {home!r})"))
    # nested loops (frame depth >= 2) must live on one device
    by_frame = {}
    for n in ctx.names:
        f = frames.get(n, ())
        if len(f) >= 2:
            d = ctx.device_of(n)
            if d:
                by_frame.setdefault(f, {}).setdefault(d, []).append(n)
    for f, by_dev in sorted(by_frame.items()):
        if len(by_dev) > 1:
            sample = [ns[0] for ns in by_dev.values()]
            diags.append(make(
                "F303",
                f"nested loop frame {'/'.join(f)!r} straddles devices "
                f"{sorted(by_dev)}; the partitioner replicates only "
                f"single-level skeletons (carried ROADMAP limit)",
                nodes=tuple(sorted(sample)),
                devices=tuple(sorted(by_dev)),
                fix="constrain the inner loop's nodes to one device"))
    return diags


def describe_nested_straddle(frame_path, nodes, devices) -> str:
    """Formatter partition.py routes its nested-loop GraphErrors through
    so the §14 satellite guarantee holds: every structural error names
    nodes and devices."""
    d = make("F303",
             f"nested loop frame {'/'.join(frame_path)!r} straddles "
             f"devices {sorted(devices)}",
             nodes=tuple(sorted(nodes)[:8]),
             devices=tuple(sorted(devices)),
             fix="constrain the inner loop's nodes to one device "
                 "(carried ROADMAP limit: nested loops may not straddle)")
    return d.format()
