"""Seeded-bad graph factories for the lint CLI's self-test.

``python -m repro.analysis.lint repro.analysis.selftest:bad_graph`` must
exit non-zero (the acceptance check that the CLI can actually fail);
``clean_graph`` is the matching must-pass fixture.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.ops import GraphBuilder


def bad_graph() -> GraphBuilder:
    """Two unordered writes to one Variable (V101) plus an orphan Recv
    (C201) — one seeded specimen per severity-critical pass family."""
    b = GraphBuilder()
    v = b.variable("v", init_value=jnp.zeros((4,), "float32"))
    b.assign(v, b.constant(jnp.ones((4,), "float32")), name="racy_a")
    b.assign(v, b.constant(2 * jnp.ones((4,), "float32")), name="racy_b")
    b.graph.add_node("Recv", [], name="orphan_recv",
                     attrs={"rendezvous_key": "nobody;sends;this;0"})
    return b


def clean_graph() -> GraphBuilder:
    """Ordered writes: same shape as bad_graph with the control edge the
    V101 fix suggests, and no orphan Recv."""
    b = GraphBuilder()
    v = b.variable("v", init_value=jnp.zeros((4,), "float32"))
    a = b.assign(v, b.constant(jnp.ones((4,), "float32")), name="first")
    b.assign(v, b.constant(2 * jnp.ones((4,), "float32")), name="second",
             control_inputs=[a])
    return b
