"""Deadness analysis (DESIGN.md §14 pass 5).

The executor's §4.4 deadness semantics: a cond-style Switch delivers a
live value on one output port and DEAD on the other, deadness propagates
input->output, and fetching a dead tensor raises at runtime ("fetch is
dead (untaken branch)").  This pass computes, per tensor, the set of
branch *guards* — (switch, port) pairs that must be taken for the tensor
to be live — and flags any fetch whose guard set is non-empty: that
fetch works only while the predicate cooperates.

Merge is the liveness join (live iff ANY input is live), modeled as the
intersection of its inputs' guard sets — complementary branch guards of
one Switch drop out, so properly Merged cond results are unguarded.
Loop switches (detected structurally) are exempt: loop Exits are always
live at termination.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Tuple

from .common import AnalysisContext
from .diagnostics import Diagnostic, make

Guard = Tuple[str, int]  # (switch node, taken output port)


def run(ctx: AnalysisContext) -> List[Diagnostic]:
    g = ctx.graph
    diags: List[Diagnostic] = []
    order, _cyclic = ctx.order()
    gmap: Dict[Tuple[str, int], FrozenSet[Guard]] = {}
    node_guard: Dict[str, FrozenSet[Guard]] = {}

    for n in order:
        node = g.nodes[n]
        base: FrozenSet[Guard] = frozenset()
        for ref in node.inputs:
            base |= gmap.get((ref.node, ref.port),
                             node_guard.get(ref.node, frozenset()))
        for c in node.control_inputs:
            base |= node_guard.get(c, frozenset())
        if node.op == "Switch" and not ctx.is_loop_switch(node):
            gmap[(n, 0)] = base | {(n, 0)}
            gmap[(n, 1)] = base | {(n, 1)}
            node_guard[n] = base
        elif node.op == "Merge":
            cand = [gmap.get((r.node, r.port),
                             node_guard.get(r.node, frozenset()))
                    for r in node.inputs]
            joined = (frozenset.intersection(*cand)
                      if cand else frozenset())
            gmap[(n, 0)] = gmap[(n, 1)] = joined
            node_guard[n] = joined
        else:
            node_guard[n] = base

    for f in ctx.fetches:
        guards = gmap.get((f.node, f.port),
                          node_guard.get(f.node, frozenset()))
        if guards:
            gl = sorted(guards)
            branches = ", ".join(
                f"{s!r} port {p} ({'true' if p == 1 else 'false'} branch)"
                for s, p in gl)
            diags.append(make(
                "D501",
                f"fetch {f} is live only when {branches} is taken; "
                f"fetching it on the other branch raises 'fetch is dead "
                f"(untaken branch)' at runtime",
                nodes=(f.node,) + tuple(s for s, _ in gl),
                fix="fetch the cond's Merge output instead, or only fetch "
                    "this tensor when the predicate is known to hold"))
    return diags
