"""Shared analysis state: one :class:`AnalysisContext` per verified graph.

The context owns the expensive derived structures every pass needs —
cycle-tolerant topological order (with Send/Recv pairing edges treated as
happens-before), ancestor bitsets for O(1) ordering queries, static frame
paths, and the rendezvous-key pairing index — computed lazily and once.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from ..core.graph import Graph, Node, TensorRef
from ..core import control_flow

_UNSET = object()


class AnalysisContext:
    def __init__(self, graph: Graph, names: Optional[Iterable[str]] = None, *,
                 fetches: Iterable = (), feed_keys: Iterable = (),
                 placement: Optional[Dict[str, str]] = None,
                 where: str = "graph") -> None:
        self.graph = graph
        self.names: Set[str] = (set(names) if names is not None
                                else set(graph.nodes))
        self.fetches: Tuple[TensorRef, ...] = tuple(
            TensorRef.parse(f) for f in fetches)
        self.feed_keys: FrozenSet[TensorRef] = frozenset(
            TensorRef.parse(k) for k in feed_keys)
        self.placement = dict(placement) if placement else None
        self.where = where
        # (node, port) -> jax.ShapeDtypeStruct | None; filled by the
        # shapes pass, read by sendrecv's consistency check (C205)
        self.specs: Dict[Tuple[str, int], object] = {}
        self._pairing = _UNSET
        self._order = _UNSET      # (order list, cyclic frozenset)
        self._anc = _UNSET        # name -> ancestor bitset over order index
        self._idx: Dict[str, int] = {}
        self._frames = _UNSET

    # -- basic edges ----------------------------------------------------
    def fwd_deps(self, node: Node) -> List[str]:
        """Forward predecessors: data + control edges inside the analyzed
        set, excluding the legal NextIteration back edge (as topo_sort)."""
        out = []
        for d in self.graph.deps(node):
            if d not in self.names:
                continue
            dn = self.graph.nodes.get(d)
            if dn is not None and dn.op == "NextIteration":
                continue
            out.append(d)
        return out

    def device_of(self, name: str) -> Optional[str]:
        if self.placement and name in self.placement:
            return self.placement[name]
        node = self.graph.nodes.get(name)
        return node.device if node is not None else None

    # -- rendezvous pairing --------------------------------------------
    def pairing(self) -> Dict[str, Tuple[List[str], List[str]]]:
        """rendezvous key -> ([send node names], [recv node names])."""
        if self._pairing is _UNSET:
            pairs: Dict[str, Tuple[List[str], List[str]]] = {}
            for n in self.names:
                node = self.graph.nodes[n]
                if node.op not in ("Send", "Recv"):
                    continue
                key = node.attrs.get("rendezvous_key")
                if key is None:
                    continue
                sends, recvs = pairs.setdefault(str(key), ([], []))
                (sends if node.op == "Send" else recvs).append(n)
            self._pairing = pairs
        return self._pairing

    # -- order + ordering queries --------------------------------------
    def order(self) -> Tuple[List[str], FrozenSet[str]]:
        """Cycle-tolerant topo order over forward edges PLUS Send->Recv
        pairing edges (a Recv cannot fire before its Send completes).

        Returns (order, cyclic): nodes involved in a genuine cycle —
        i.e. a deadlock through pairing edges — are absent from the
        order and reported in ``cyclic``.
        """
        if self._order is _UNSET:
            extra: Dict[str, List[str]] = {}  # recv -> [send] happens-before
            for key, (sends, recvs) in self.pairing().items():
                for r in recvs:
                    extra.setdefault(r, []).extend(sends)
            indeg: Dict[str, int] = {}
            consumers: Dict[str, List[str]] = {n: [] for n in self.names}
            for n in self.graph.nodes:  # insertion order: deterministic
                if n not in self.names:
                    continue
                ds = self.fwd_deps(self.graph.nodes[n]) + [
                    s for s in extra.get(n, ()) if s in self.names]
                indeg[n] = len(ds)
                for d in ds:
                    consumers[d].append(n)
            order: List[str] = []
            ready = [n for n in self.graph.nodes
                     if n in self.names and indeg[n] == 0]
            seen = set(ready)
            while ready:
                n = ready.pop(0)
                order.append(n)
                for c in consumers[n]:
                    indeg[c] -= 1
                    if indeg[c] == 0 and c not in seen:
                        ready.append(c)
                        seen.add(c)
            cyclic = frozenset(self.names - set(order))
            self._order = (order, cyclic)
            self._idx = {n: i for i, n in enumerate(order)}
        return self._order

    def ancestors(self) -> Dict[str, int]:
        """Per-node ancestor set as a bitset (int) over order indices."""
        if self._anc is _UNSET:
            order, _cyclic = self.order()
            idx = self._idx
            extra: Dict[str, List[str]] = {}
            for key, (sends, recvs) in self.pairing().items():
                for r in recvs:
                    extra.setdefault(r, []).extend(sends)
            anc: Dict[str, int] = {}
            for n in order:
                a = 0
                for d in self.fwd_deps(self.graph.nodes[n]) + [
                        s for s in extra.get(n, ()) if s in self.names]:
                    if d in idx:
                        a |= anc.get(d, 0) | (1 << idx[d])
                anc[n] = a
            self._anc = anc
        return self._anc

    def ordered(self, a: str, b: str) -> bool:
        """True iff a happens-before b or b happens-before a on every
        schedule.  Nodes caught in a pairing-edge cycle are reported by
        the deadlock check instead; ordering is vacuously True for them
        so the race pass does not double-report."""
        self.order()
        anc = self.ancestors()
        ia, ib = self._idx.get(a), self._idx.get(b)
        if ia is None or ib is None:
            return True
        return bool((anc[b] >> ia) & 1) or bool((anc[a] >> ib) & 1)

    # -- frames ---------------------------------------------------------
    def frames(self) -> Optional[Dict[str, Tuple[str, ...]]]:
        """Static frame path per node, or None when the skeleton is too
        malformed to converge (the frames pass reports F301 for that)."""
        if self._frames is _UNSET:
            try:
                self._frames = control_flow.static_frames(
                    self.graph, self.names)
            except Exception:
                self._frames = None
        return self._frames

    def is_loop_switch(self, node: Node) -> bool:
        """Loop-skeleton Switch (vs a cond-style Switch): its data input
        is a Merge carrying a NextIteration back edge, or its predicate
        comes from a LoopCond, or a loop spec claims it.  Replicated
        per-device skeletons (partition.py) keep the Merge+back-edge
        shape even though their predicate arrives via Recv."""
        for spec in self.graph.loop_specs.values():
            if node.name in spec.switch_names:
                return True
        if len(node.inputs) >= 2:
            pred = self.graph.nodes.get(node.inputs[1].node)
            if pred is not None and pred.op == "LoopCond":
                return True
        if node.inputs:
            data = self.graph.nodes.get(node.inputs[0].node)
            if data is not None and data.op == "Merge":
                for ref in data.inputs:
                    src = self.graph.nodes.get(ref.node)
                    if src is not None and src.op == "NextIteration":
                        return True
        return False
