"""Variable race detector (DESIGN.md §14 pass 1).

The executor dispatches every ready op, so two accesses to one Variable
container with no happens-before path between them genuinely race: the
final store value (write/write) or the value a read observes (read/write)
depends on dispatch order.  The paper's contract (§3.4) is that stateful
ops are ordered by explicit control/data edges; this pass checks it.

Store-level accesses in this engine:

* read  — executing the ``Variable`` node itself (container read),
* write — ``Assign``/``AssignAdd`` (target = data input 0's node) and
  ``Restore`` (targets = its ``var_names`` attr, no data edges at all —
  which is exactly why Restore races are so easy to build).

An Assign is always ordered after its own Variable's read (the data
edge), so V102 in practice flags Restore-vs-read and other edge-free
write paths — the silent nondeterminism §3.4 warns about.
"""
from __future__ import annotations

from typing import Dict, List

from .common import AnalysisContext
from .diagnostics import Diagnostic, make


def run(ctx: AnalysisContext) -> List[Diagnostic]:
    g = ctx.graph
    diags: List[Diagnostic] = []
    readers: Dict[str, List[str]] = {}   # variable name -> reading nodes
    writers: Dict[str, List[str]] = {}   # variable name -> writing nodes
    for n in sorted(ctx.names):
        node = g.nodes[n]
        if node.op == "Variable":
            readers.setdefault(n, []).append(n)
        elif node.op in ("Assign", "AssignAdd"):
            if not node.inputs:
                continue
            tgt = node.inputs[0].node
            tgt_node = g.nodes.get(tgt)
            if tgt_node is None or tgt_node.op != "Variable":
                diags.append(make(
                    "V103",
                    f"{node.op} {n!r} writes through {tgt!r} "
                    f"(op {getattr(tgt_node, 'op', '?')}), not a Variable — "
                    f"the store write lands under that node's name",
                    nodes=(n, tgt),
                    fix="make data input 0 the Variable node being updated"))
                continue
            writers.setdefault(tgt, []).append(n)
        elif node.op == "Restore":
            for v in node.attrs.get("var_names", ()) or ():
                writers.setdefault(str(v), []).append(n)

    def dev(pair):
        return tuple(sorted({d for d in map(ctx.device_of, pair) if d}))

    for var in sorted(writers):
        ws = writers[var]
        for i, a in enumerate(ws):
            for b in ws[i + 1:]:
                if a != b and not ctx.ordered(a, b):
                    diags.append(make(
                        "V101",
                        f"writes {a!r} and {b!r} to Variable {var!r} have "
                        f"no ordering path; the final value depends on "
                        f"dispatch order",
                        nodes=(a, b, var), devices=dev((a, b)),
                        fix=f"add a control edge between {a!r} and {b!r} "
                            f"(e.g. control_inputs=[...]) or drop one write"))
        for r in readers.get(var, ()):
            for w in ws:
                if r != w and not ctx.ordered(r, w):
                    diags.append(make(
                        "V102",
                        f"read of Variable {var!r} (node {r!r}) and write "
                        f"{w!r} have no ordering path; the read observes "
                        f"either value depending on dispatch order",
                        nodes=(r, w), devices=dev((r, w)),
                        fix=f"add a control edge ordering {r!r} against "
                            f"{w!r}, or fetch them in separate runs"))
    return diags
