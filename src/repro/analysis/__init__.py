"""§14 pre-execution static analysis: the graph verifier.

    from repro.analysis import verify_graph
    report = verify_graph(graph, fetches=[...])
    for d in report.errors(): print(d.format())

Wired through ``Session(verify="off"|"warn"|"error")`` / ``REPRO_VERIFY``
(runs once per Executable build, cached with the Executable), through
WirePlan registration (per-task slices + global pairing before shipping),
and the ``python -m repro.analysis.lint`` CLI.
"""
from .diagnostics import (CODES, Diagnostic, GraphVerifyWarning,
                          VerifyReport, apply_suppressions, make)
from .verifier import (PASSES, STATS, VERIFY_MODES, enforce,
                       task_slice_diagnostics, verify_executable,
                       verify_graph, verify_wire_plan)

__all__ = [
    "CODES", "Diagnostic", "GraphVerifyWarning", "VerifyReport",
    "apply_suppressions", "make", "PASSES", "STATS", "VERIFY_MODES",
    "enforce", "task_slice_diagnostics", "verify_executable",
    "verify_graph", "verify_wire_plan",
]
