"""Graph lint CLI (DESIGN.md §14).

    python -m repro.analysis.lint [factory ...] [--suite] [--mode error]
                                  [--dot DIR]

A *factory* is ``module:qualname`` or ``path/to/file.py:qualname`` — a
zero-argument callable returning a Graph, GraphBuilder, Session, or any
launch-step bundle (anything with ``.graph`` / ``.session`` / ``.builder``).
With no factories, ``--suite`` (implied) lints the shipped launch/example
graph factories.  Exit status: non-zero iff any error-severity diagnostic
survives suppression — the CI ``lint-graphs`` job gates on it.

Multi-device factories (graphs with >= 2 distinct device constraints)
are additionally placed + partitioned so the Send/Recv pairing and the
per-device schedule get verified, exactly like an Executable build.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import os
import sys
from typing import Callable, List, Optional, Tuple

from .diagnostics import CODES, VerifyReport, make
from .verifier import verify_graph
from ..core.graph import Graph, GraphError


# ---------------------------------------------------------------------------
def _load_factory(spec: str) -> Callable:
    path, _, qual = spec.partition(":")
    if not qual:
        raise SystemExit(f"factory spec {spec!r} is not module:qualname")
    if path.endswith(".py") or os.sep in path:
        modname = "_lint_" + os.path.basename(path).replace(".py", "")
        sl = importlib.util.spec_from_file_location(modname, path)
        if sl is None or sl.loader is None:
            raise SystemExit(f"cannot load {path!r}")
        mod = importlib.util.module_from_spec(sl)
        sys.modules[modname] = mod
        sl.loader.exec_module(mod)
    else:
        mod = importlib.import_module(path)
    obj = mod
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj


def _as_graph(obj) -> Graph:
    if isinstance(obj, Graph):
        return obj
    if isinstance(obj, (tuple, list)) and obj:
        return _as_graph(obj[0])
    for attr in ("graph", "session", "builder"):
        inner = getattr(obj, attr, None)
        if inner is not None:
            return inner if isinstance(inner, Graph) else _as_graph(inner)
    raise SystemExit(f"factory returned {type(obj).__name__}; expected a "
                     f"Graph/GraphBuilder/Session/step bundle")


def _sink_fetches(g: Graph) -> List[str]:
    cons = g.consumers()
    return [f"{n}:0" for n, node in g.nodes.items() if not cons[n]]


def lint_graph(g: Graph, where: str) -> VerifyReport:
    """Verify one graph; multi-device graphs also place + partition."""
    devices = sorted({n.device for n in g.nodes.values() if n.device})
    fetches = _sink_fetches(g)
    feed_keys = [f"{n}:0" for n, node in g.nodes.items()
                 if node.op == "Placeholder"]
    if len(devices) < 2:
        return verify_graph(g, fetches=fetches, feed_keys=feed_keys,
                            where=where)
    from ..core import partition as partition_mod
    from ..core import placement as placement_mod
    from ..runtime.devices import Device, DeviceName, DeviceSet

    devset = DeviceSet([Device(DeviceName.parse(d)) for d in devices])
    names = set(g.nodes)
    placement = placement_mod.place(g, devset, placement_mod.CostModel(),
                                    names)
    report = verify_graph(g, names, fetches=fetches, feed_keys=feed_keys,
                          placement=placement, where=where)
    try:
        parted = partition_mod.partition(g, placement, names)
    except GraphError as e:
        report.diagnostics.append(make(
            "F303", f"partition rejected the placed graph: {e}",
            fix="see the partition error above"))
        return report
    p_report = verify_graph(parted.graph, None, fetches=fetches,
                            feed_keys=feed_keys,
                            placement=parted.placement,
                            where=f"{where} (partitioned)")
    report.diagnostics.extend(p_report.diagnostics)
    report.suppressed += p_report.suppressed
    return report


# --- the shipped launch/example factories (--suite) ------------------------
def factory_wire_train():
    from ..launch.steps import build_wire_train_step
    return build_wire_train_step([
        "/job:worker/task:0/device:cpu:0",
        "/job:worker/task:1/device:cpu:0",
    ])


def factory_eager_train():
    from ..configs import get_config
    from ..launch.steps import build_eager_train_step
    from ..models.api import Shape
    return build_eager_train_step(get_config("llama3_8b", smoke=True),
                                  Shape("lint", 64, 2, "train"))


def factory_eager_serve():
    from ..configs import get_config
    from ..launch.steps import build_eager_serve_step
    return build_eager_serve_step(get_config("llama3_8b", smoke=True))


def _example(fname: str, qual: str) -> Optional[str]:
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    path = os.path.join(here, "examples", fname)
    return f"{path}:{qual}" if os.path.exists(path) else None


def suite_specs() -> List[Tuple[str, str]]:
    specs = [
        ("launch:wire_train_2task", "repro.analysis.lint:factory_wire_train"),
        ("launch:eager_train_smoke", "repro.analysis.lint:factory_eager_train"),
        ("launch:eager_serve_smoke", "repro.analysis.lint:factory_eager_serve"),
    ]
    qs = _example("quickstart.py", "build_graph")
    if qs:
        specs.append(("examples:quickstart", qs))
    return specs


# ---------------------------------------------------------------------------
def _summary_table(rows: List[Tuple[str, str, str, str, str]]) -> str:
    head = ("| graph | code | severity | pass | nodes |\n"
            "|---|---|---|---|---|\n")
    if not rows:
        return head + "| _all clean_ | — | — | — | — |\n"
    return head + "".join(
        f"| {g} | {c} | {s} | {p} | {n} |\n" for g, c, s, p, n in rows)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="§14 static graph verifier over graph factories")
    ap.add_argument("factories", nargs="*",
                    help="module:qualname or path.py:qualname")
    ap.add_argument("--suite", action="store_true",
                    help="lint the shipped launch/example factories "
                         "(default when no factories given)")
    ap.add_argument("--mode", choices=("warn", "error"), default="error",
                    help="exit non-zero on errors (default) or never (warn)")
    ap.add_argument("--dot", metavar="DIR", default=None,
                    help="write a per-graph diagnostic-annotated .dot here")
    args = ap.parse_args(argv)

    targets: List[Tuple[str, str]] = [(s, s) for s in args.factories]
    if args.suite or not targets:
        targets = suite_specs() + targets

    rows: List[Tuple[str, str, str, str, str]] = []
    n_errors = 0
    for label, spec in targets:
        try:
            g = _as_graph(_load_factory(spec)())
        except SystemExit:
            raise
        except Exception as e:
            print(f"[lint] {label}: factory failed: {type(e).__name__}: {e}")
            n_errors += 1
            rows.append((label, "X000", "error", "factory",
                         f"factory raised {type(e).__name__}"))
            continue
        report = lint_graph(g, label)
        errs, warns = report.errors(), report.warnings()
        n_errors += len(errs)
        status = ("clean" if not report.diagnostics else
                  f"{len(errs)} error(s), {len(warns)} warning(s)")
        print(f"[lint] {label}: {len(g.nodes)} nodes, {status}"
              + (f", {report.suppressed} suppressed"
                 if report.suppressed else ""))
        for d in report.diagnostics:
            print("    " + d.format())
            rows.append((label, d.code, d.severity, d.pass_name,
                         ", ".join(d.nodes[:4])))
        if args.dot:
            from ..tools import graphviz as gv
            os.makedirs(args.dot, exist_ok=True)
            safe = label.replace(":", "_").replace("/", "_")
            out = os.path.join(args.dot, f"{safe}.dot")
            with open(out, "w") as fh:
                fh.write(gv.to_dot_diagnostics(g, report.diagnostics,
                                               title=label))
            print(f"    wrote {out}")

    print()
    print(_summary_table(rows), end="")
    if args.mode == "error" and n_errors:
        print(f"\n[lint] FAILED: {n_errors} error(s) "
              f"(codes: see DESIGN.md §14 / repro.analysis.CODES)")
        return 1
    print("\n[lint] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
