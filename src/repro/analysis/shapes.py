"""Static shape/dtype propagation (DESIGN.md §14 pass 4).

Seeds abstract values from Placeholder shape/dtype attrs, Const values
and Variable initializers, then propagates through pure ops by abstract
interpretation (``jax.eval_shape`` over the op's reference compute — no
FLOPs, no materialization).  A node whose inputs are fully known but
whose kernel rejects them is exactly the class of error that otherwise
surfaces mid-run as a trace/jit failure; here it becomes S401 *before*
anything executes.  Unknown inputs stay unknown and propagate silently —
the pass is best-effort, never a false positive by construction.

The inferred specs are left on the AnalysisContext for the sendrecv
pass's rendezvous-consistency check (C205); Recv outputs resolve through
the pairing index, so shapes flow across device boundaries too.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax

from .common import AnalysisContext
from .diagnostics import Diagnostic, make
from ..core import ops as ops_mod

# ops handled structurally below; everything else with a registered pure
# compute is abstractly interpreted
_STRUCTURAL = frozenset({
    "Placeholder", "Const", "Variable", "Assign", "AssignAdd", "NoOp",
    "Send", "Recv", "Switch", "Merge", "Enter", "Exit", "NextIteration",
    "LoopCond", "Save", "Restore", "QueueEnqueue", "QueueDequeue",
    "FusedRegion",
})

# skip abstract interpretation entirely above this size (machine-built
# graphs at scale: the structural passes stay, per-node tracing goes)
MAX_NODES = 4000


def _spec_of(value) -> Optional[jax.ShapeDtypeStruct]:
    try:
        x = jax.numpy.asarray(value)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    except Exception:
        return None


def _fmt(sp) -> str:
    return f"{sp.dtype}{list(sp.shape)}" if sp is not None else "?"


def run(ctx: AnalysisContext) -> List[Diagnostic]:
    g = ctx.graph
    diags: List[Diagnostic] = []
    if len(ctx.names) > MAX_NODES:
        return diags
    order, _cyclic = ctx.order()
    specs: Dict[Tuple[str, int], Optional[jax.ShapeDtypeStruct]] = ctx.specs
    send_payload: Dict[str, Optional[jax.ShapeDtypeStruct]] = {}

    def get(ref) -> Optional[jax.ShapeDtypeStruct]:
        return specs.get((ref.node, ref.port))

    for n in order:
        node = g.nodes[n]
        op = node.op
        ins = [get(r) for r in node.inputs]
        try:
            if op == "Placeholder":
                shape, dtype = node.attrs.get("shape"), node.attrs.get("dtype")
                if shape is not None and dtype is not None:
                    specs[(n, 0)] = jax.ShapeDtypeStruct(
                        tuple(shape), jax.numpy.dtype(dtype))
            elif op == "Const":
                specs[(n, 0)] = _spec_of(node.attrs.get("value"))
            elif op == "Variable":
                init = node.attrs.get("init")
                if callable(init):
                    try:
                        specs[(n, 0)] = jax.eval_shape(init)
                    except Exception:
                        pass
                elif init is not None:
                    specs[(n, 0)] = _spec_of(init)
            elif op in ("Assign", "AssignAdd"):
                var_sp = ins[0] if ins else None
                val_sp = ins[1] if len(ins) > 1 else None
                if op == "Assign":
                    specs[(n, 0)] = val_sp
                    if (var_sp is not None and val_sp is not None
                            and (tuple(var_sp.shape) != tuple(val_sp.shape)
                                 or var_sp.dtype != val_sp.dtype)):
                        diags.append(make(
                            "S402",
                            f"Assign {n!r} writes {_fmt(val_sp)} into "
                            f"Variable {node.inputs[0].node!r} initialized "
                            f"as {_fmt(var_sp)}",
                            nodes=(n, node.inputs[0].node),
                            fix="cast/reshape the value, or re-initialize "
                                "the Variable with the new signature"))
                else:
                    if var_sp is not None and val_sp is not None:
                        specs[(n, 0)] = jax.eval_shape(
                            lambda a, b: a + b, var_sp, val_sp)
                    else:
                        specs[(n, 0)] = var_sp
            elif op in ("Enter", "Exit", "NextIteration", "LoopCond"):
                specs[(n, 0)] = ins[0] if ins else None
            elif op == "Switch":
                specs[(n, 0)] = specs[(n, 1)] = ins[0] if ins else None
            elif op == "Merge":
                cands = {(_fmt(s)) for s in ins if s is not None}
                specs[(n, 0)] = (next(s for s in ins if s is not None)
                                 if len(cands) == 1 else None)
                specs[(n, 1)] = jax.ShapeDtypeStruct(
                    (), jax.numpy.dtype("int32"))
            elif op == "Send":
                key = node.attrs.get("rendezvous_key")
                if key is not None and node.inputs:
                    send_payload[str(key)] = ins[0]
            elif op == "Recv":
                key = node.attrs.get("rendezvous_key")
                specs[(n, 0)] = send_payload.get(str(key))
            elif op in _STRUCTURAL:
                pass  # no statically known outputs
            else:
                od = ops_mod.REGISTRY.get(op)
                if od is None or od.stateful:
                    continue
                if any(s is None for s in ins):
                    continue
                outs = jax.eval_shape(
                    lambda *xs: od.compute(None, node, *xs), *ins)
                for p, sp in enumerate(outs):
                    specs[(n, p)] = sp
        except Exception as e:  # an op rejecting known input signatures
            msg = str(e).split("\n", 1)[0][:300]
            sig = ", ".join(f"{r.node}:{r.port}={_fmt(s)}"
                            for r, s in zip(node.inputs, ins))
            diags.append(make(
                "S401",
                f"{op} {n!r} rejects its statically-known inputs "
                f"({sig}): {msg}",
                nodes=(n,) + tuple(r.node for r in node.inputs),
                fix="fix the producer shapes/dtypes; this would fail at "
                    "trace/jit time otherwise"))
    return diags
