from .sharding import (axis_rules, logical_constraint, pspec_of, param_pspecs,
                       set_rules, current_rules)

__all__ = ["axis_rules", "logical_constraint", "pspec_of", "param_pspecs",
           "set_rules", "current_rules"]
