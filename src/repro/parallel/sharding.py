"""Logical-axis sharding rules (the compiled path's placement policy).

The paper's placement algorithm assigns ops to devices; on a homogeneous
SPMD pod the analogous decision is *which mesh axis each tensor dimension
shards over* (DESIGN.md §2).  Model code annotates tensors with LOGICAL
dimension names ("batch", "heads", "ff", "experts", ...); the launch
layer activates a rule set mapping logical names to mesh axes, and
``logical_constraint`` lowers to ``jax.lax.with_sharding_constraint``.
With no rules active (unit tests, single device) everything is a no-op.

This indirection is what the §Perf hillclimbing iterates on: changing a
rule re-shards the whole model without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default production rules (single-pod). "batch" may map to a *tuple* of
# mesh axes (e.g. ("pod", "data") in the multi-pod mesh).
DEFAULT_RULES: Dict[str, Any] = {
    "batch": "data",
    "seq": None,            # sequence stays unsharded (no context parallel)
    "seq_res": None,        # residual-stream seq dim; map to "model" for
                            # Megatron-style sequence parallelism (stored
                            # activations /TP at unchanged collective volume)
    "d_model": "data",      # FSDP: params sharded on d_model over data axis
    "heads": "model",       # tensor parallel
    "kv_heads": "model",    # padded kv heads
    "kv_orig": None,        # original (pre-duplication) kv heads: replicated
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",     # expert parallel
    "expert_cap": None,
    "inner": "model",       # SSM d_inner / heads
    "ssm_heads": "model",
    "state": None,
    "groups": "batch_alias",  # resolved to the batch mapping
    "layers": None,
}


def set_rules(rules: Optional[Dict[str, Any]], mesh: Optional[Mesh] = None) -> None:
    _state.rules = rules
    _state.mesh = mesh


def current_rules() -> Optional[Dict[str, Any]]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any], mesh: Optional[Mesh] = None):
    prev_r, prev_m = current_rules(), current_mesh()
    set_rules(rules, mesh)
    try:
        yield
    finally:
        set_rules(prev_r, prev_m)


def _resolve(rules: Dict[str, Any], name: Optional[str]):
    if name is None:
        return None
    axis = rules.get(name)
    if axis == "batch_alias":
        axis = rules.get("batch")
    return axis


def pspec_of(axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, Any]] = None) -> P:
    """Logical dim names -> PartitionSpec under the active (or given) rules."""
    rules = rules if rules is not None else (current_rules() or {})
    return P(*[_resolve(rules, a) for a in axes])


def logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate activation sharding; identity when no rules are active."""
    rules = current_rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs axes {axes}")
    spec = pspec_of(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_pspecs(param_axes: Any, rules: Optional[Dict[str, Any]] = None) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    rules = rules if rules is not None else (current_rules() or {})
    return jax.tree.map(
        lambda axes: pspec_of(axes, rules),
        param_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
