"""Wire protocol of the multi-process distributed runtime (DESIGN.md §11).

Framing is deliberately minimal: every message is a 4-byte big-endian
length prefix followed by that many payload bytes.  A payload is a
pickled ``dict`` with a ``"kind"`` field naming the RPC
(``register_graph`` / ``run_graph`` / ``recv_tensor`` / ``heartbeat`` /
``get_variables`` / ``set_variables`` / ``cleanup`` / ``shutdown`` /
``collect_trace`` / ``metrics_snapshot``).

Tensors anywhere inside a message are hoisted through an explicit binary
codec (:func:`encode_tensor` / :func:`decode_tensor`) instead of relying
on ndarray pickling internals: the wire layout is ``flags | dtype name |
shape | C-order bytes``, which is deterministic and bit-faithful for
every dtype the graph engine produces (including ``bfloat16`` via
ml_dtypes and the §5.5 ``uint16`` compress16 wire format).  §4.4 dead
tensors are a first-class wire concept — ``DEAD_TENSOR`` crosses a
process boundary as a dedicated flag, never as data — so deadness
propagates through untaken cond branches and terminating loop iterations
exactly as it does between threads.

Graphs ship as pickled :class:`~repro.core.graph.Graph` slices; any
``Call`` node closure is rejected with a clear :class:`ProtocolError`.
Distributed graphs are built from registered primitive ops, module-level
callables, or wire-shippable Call *factories* — attrs carrying an
importable ``module:qualname`` plus static args, rebuilt worker-side at
registration (``GraphBuilder.call_factory``, DESIGN.md §15).
"""
from __future__ import annotations

import io
import pickle
import select
import socket
import struct
import threading
import time
from collections import deque
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import spans as obs_spans
from ..runtime.rendezvous import DEAD_TENSOR, _DeadTensor
from . import faults

MAX_FRAME = 1 << 30  # 1 GiB sanity bound per message

_FLAG_DEAD = 0x01
_FLAG_JAX = 0x02  # value was a jax.Array at the producer


class ProtocolError(Exception):
    """Malformed frame, oversized message, or non-wire-serializable object."""


class WorkerError(Exception):
    """The peer processed the request and replied with an application error
    (the worker itself is alive — distinct from a dead-connection OSError)."""


# ---------------------------------------------------------------------------
# tensor codec


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        # bfloat16 / float8 etc. live in ml_dtypes, not numpy proper
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def encode_tensor(x: Any) -> bytes:
    """Array (numpy / jax / scalar) or DEAD_TENSOR -> deterministic bytes.

    The producer's array *kind* travels with the bytes: a jax array
    rehydrates as a jax array, a numpy array as numpy.  Execution is
    kind-sensitive (``a @ b`` dispatches to XLA vs numpy with different
    accumulation orders), so preserving it is part of the bit-parity
    contract between wire and in-process runs.
    """
    if isinstance(x, _DeadTensor):
        return struct.pack(">B", _FLAG_DEAD)
    flags = 0
    try:
        import jax

        if isinstance(x, jax.Array):
            flags |= _FLAG_JAX
    except ImportError:  # pragma: no cover - jax is a hard dep elsewhere
        pass
    arr = np.asarray(x)
    if not arr.flags.c_contiguous:
        # 0-d arrays are always contiguous, so this can never flatten a
        # scalar (ascontiguousarray promotes 0-d to 1-d — a shape change)
        arr = np.ascontiguousarray(arr)
    dt = arr.dtype.name.encode("ascii")
    head = struct.pack(">BB", flags, len(dt)) + dt + struct.pack(">B", arr.ndim)
    head += b"".join(struct.pack(">Q", d) for d in arr.shape)
    return head + arr.tobytes()


def decode_tensor(data: bytes) -> Any:
    """Inverse of :func:`encode_tensor` — bit-identical, a buffer copy,
    never a cast.  Numpy-origin arrays stay numpy (jnp.asarray would
    silently downcast 64-bit dtypes with x64 disabled); jax-origin arrays
    come back as jax arrays so kernels see the kind the producer had."""
    (flags,) = struct.unpack_from(">B", data, 0)
    if flags & _FLAG_DEAD:
        return DEAD_TENSOR
    (dtlen,) = struct.unpack_from(">B", data, 1)
    off = 2
    dtype = _np_dtype(data[off:off + dtlen].decode("ascii"))
    off += dtlen
    (ndim,) = struct.unpack_from(">B", data, off)
    off += 1
    shape = struct.unpack_from(f">{ndim}Q", data, off) if ndim else ()
    off += 8 * ndim
    # .copy(): writable, and decoupled from the (much larger) frame buffer
    arr = np.frombuffer(data, dtype=dtype, offset=off).reshape(shape).copy()
    if flags & _FLAG_JAX:
        import jax.numpy as jnp

        return jnp.asarray(arr)
    return arr


class _WirePickler(pickle.Pickler):
    """Pickler that routes every tensor through the explicit codec."""

    def reducer_override(self, obj):  # noqa: D102 — pickle hook
        if isinstance(obj, _DeadTensor):
            return (_load_dead, ())
        if isinstance(obj, (np.ndarray, np.generic)):
            return (decode_tensor, (encode_tensor(obj),))
        try:
            import jax

            if isinstance(obj, jax.Array):
                return (decode_tensor, (encode_tensor(obj),))
        except ImportError:  # pragma: no cover - jax is a hard dep elsewhere
            pass
        return NotImplemented


def _load_dead() -> _DeadTensor:
    return DEAD_TENSOR


def pack_msg(msg: Dict[str, Any]) -> bytes:
    buf = io.BytesIO()
    try:
        _WirePickler(buf, protocol=pickle.HIGHEST_PROTOCOL).dump(msg)
    except Exception as e:  # noqa: BLE001 — rewrap with actionable context
        raise ProtocolError(
            f"message {msg.get('kind')!r} contains a non-wire-serializable "
            f"object ({e}); distributed graphs must be built from registered "
            f"primitive ops, importable callables, or Call factories "
            f"(GraphBuilder.call_factory — closures cannot ship; "
            f"DESIGN.md §15)"
        ) from e
    return buf.getvalue()


def unpack_msg(data: bytes) -> Dict[str, Any]:
    return pickle.loads(data)


# ---------------------------------------------------------------------------
# framing


def write_frame(sock: socket.socket, data: bytes) -> None:
    if len(data) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(data)} bytes exceeds MAX_FRAME")
    sock.sendall(struct.pack(">I", len(data)) + data)


def _read_exact(sock: socket.socket, n: int, *, eof_ok: bool) -> Optional[bytes]:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if eof_ok and got == 0:
                return None
            raise ProtocolError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(sock: socket.socket) -> Optional[bytes]:
    """One frame, or None on a clean EOF at a frame boundary."""
    head = _read_exact(sock, 4, eof_ok=True)
    if head is None:
        return None
    (n,) = struct.unpack(">I", head)
    if n > MAX_FRAME:
        raise ProtocolError(f"peer announced {n}-byte frame (> MAX_FRAME)")
    return _read_exact(sock, n, eof_ok=False)


def send_msg(sock: socket.socket, msg: Dict[str, Any]) -> None:
    write_frame(sock, pack_msg(msg))


def recv_msg(sock: socket.socket) -> Optional[Dict[str, Any]]:
    data = read_frame(sock)
    return None if data is None else unpack_msg(data)


# ---------------------------------------------------------------------------
# client channel

# §13 idempotency contract (DESIGN.md): RPCs whose effect is identical if
# re-executed, so a transport failure mid-call may be retried without
# risking a double effect.  heartbeat/get_variables/debug_state are pure
# reads; set_variables/update_cluster force-write the values they carry;
# register_graph SEEDs only (re-registering an already-registered handle
# replaces it with identical content); cleanup/purge_execution purge an
# already-purged namespace to the same empty state; recv_tensor is
# at-most-once — a retry after the peer popped the mailbox entry but
# before the reply landed cannot return the wrong tensor, it blocks and
# surfaces an execution failure that §3.3 recovery handles anyway.
# run_graph and shutdown are deliberately absent: run_graph mutates
# Variables per execution (a blind re-run could double-apply a training
# step) and keeps its fail-fast contract.  metrics_snapshot is a pure
# read; collect_trace drains the worker's span buffer, so a retry whose
# first attempt reached the peer can lose those events — acceptable for
# best-effort diagnostics, and retrying keeps trace collection alive
# across transient transport hiccups.
IDEMPOTENT_RPCS = frozenset({
    "heartbeat", "recv_tensor", "get_variables", "set_variables",
    "register_graph", "cleanup", "purge_execution", "update_cluster",
    "debug_state", "collect_trace", "metrics_snapshot",
})

RETRY_ATTEMPTS = 4          # total tries for an idempotent RPC
RETRY_BASE_S = 0.05         # first backoff; doubles per retry
RETRY_JITTER = 0.25         # +/- fraction of the backoff
CONNECT_ATTEMPTS = 4        # refused-connection retries while dialing


def _backoff(attempt: int, deadline: float) -> bool:
    """Sleep the exponential-backoff-with-jitter delay for ``attempt``
    (0-based), bounded by ``deadline``.  False if the deadline would pass
    before the retry could start (caller should give up instead)."""
    delay = RETRY_BASE_S * (2 ** attempt)
    delay *= 1.0 + RETRY_JITTER * (2.0 * faults.jitter_rng().random() - 1.0)
    if time.monotonic() + delay >= deadline:
        return False
    time.sleep(delay)
    return True


class Channel:
    """Pooled request/reply client to one worker endpoint.

    Each in-flight RPC owns a whole TCP connection (no multiplexing):
    concurrent calls draw distinct connections from the idle pool or dial
    new ones.  This is what makes concurrent ``recv_tensor`` fetches
    deadlock-free — a blocked fetch for a late tensor can never head-of-
    line-block the fetch whose arrival would unblock the producer.

    Failure handling (§13): dialing retries refused connections with
    exponential backoff (a standby worker still binding its port must not
    fail a whole rebind), and idempotent RPCs (:data:`IDEMPOTENT_RPCS`)
    additionally retry transport failures mid-call — bounded attempts,
    jittered backoff, all under the ``_timeout`` deadline.  Non-idempotent
    RPCs (``run_graph``) stay fail-fast once the request may have reached
    the peer.
    """

    def __init__(self, host: str, port: int, *, connect_timeout: float = 5.0,
                 connect_attempts: int = CONNECT_ATTEMPTS) -> None:
        self.host, self.port = host, port
        self.connect_timeout = connect_timeout
        self.connect_attempts = max(1, connect_attempts)
        self._idle: deque = deque()
        self._lock = threading.Lock()
        self._closed = False

    def _connect(self, deadline: float) -> socket.socket:
        """Dial with bounded retry on refused/unreachable connections.
        Always safe regardless of the RPC's idempotency: a connection
        that never opened never delivered a request."""
        last: Optional[Exception] = None
        for attempt in range(self.connect_attempts):
            budget = min(self.connect_timeout, deadline - time.monotonic())
            if budget <= 0:
                break
            try:
                faults.on_connect(self.host, self.port)
                sock = socket.create_connection((self.host, self.port),
                                                timeout=budget)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                return sock
            except OSError as e:
                last = e
                if attempt + 1 >= self.connect_attempts:
                    break
                if not _backoff(attempt, deadline):
                    break
        raise last if last is not None else OSError(
            f"connect deadline passed for {self.host}:{self.port}")

    def _acquire(self, deadline: float) -> socket.socket:
        while True:
            with self._lock:
                if self._closed:
                    raise OSError(f"channel to {self.host}:{self.port} is closed")
                sock = self._idle.popleft() if self._idle else None
            if sock is None:
                break
            # liveness probe: a socket closed while parked (peer restarted
            # on the same endpoint) is readable with EOF — reusing it
            # would surface a transport error and falsely condemn the
            # healthy restarted worker.  select(timeout=0) is cheap and,
            # unlike retry-on-failure, can never double-execute an RPC.
            readable, _, _ = select.select([sock], [], [], 0)
            if not readable:
                return sock
            sock.close()
        return self._connect(deadline)

    def _release(self, sock: socket.socket) -> None:
        with self._lock:
            if not self._closed and len(self._idle) < 8:
                self._idle.append(sock)
                return
        sock.close()

    def _call_once(self, kind: str, fields: Dict[str, Any],
                   deadline: float) -> Dict[str, Any]:
        # §16 client-side RPC span: one process-global recorder check —
        # the whole cost of the path when tracing is off
        rec = obs_spans.get()
        t_rpc = time.time() if rec is not None else None
        sock = self._acquire(deadline)
        try:
            faults.on_call(kind, fields, self.host, self.port)
            sock.settimeout(max(0.05, deadline - time.monotonic()))
            send_msg(sock, {"kind": kind, **fields})
            reply = recv_msg(sock)
        except Exception:
            sock.close()  # transport/encode failure: connection state unknown
            raise
        if reply is None:
            sock.close()
            raise ProtocolError(
                f"{self.host}:{self.port} closed the connection mid-call ({kind})")
        self._release(sock)
        if not reply.get("ok", False):
            raise WorkerError(reply.get("error", f"unknown {kind} failure"))
        if rec is not None:
            rec.record(kind, obs_spans.CAT_RPC, f"{self.host}:{self.port}",
                       t_rpc, time.time(), args={"kind": kind})
        return reply

    def call(self, kind: str, *, _timeout: float = 60.0,
             _attempts: Optional[int] = None, **fields: Any) -> Dict[str, Any]:
        """One RPC.  Raises :class:`WorkerError` on application errors
        (peer alive) and ``OSError``/:class:`ProtocolError` on transport
        failures (peer presumed lost).

        ``_timeout`` is the total deadline across every attempt.
        ``_attempts`` overrides the retry budget — idempotent RPCs
        (:data:`IDEMPOTENT_RPCS`) default to :data:`RETRY_ATTEMPTS`,
        everything else to 1 (the heartbeat monitor also passes 1: its
        own loop is the retry, and it must see raw per-probe failures to
        count misses honestly).
        """
        attempts = (_attempts if _attempts is not None
                    else (RETRY_ATTEMPTS if kind in IDEMPOTENT_RPCS else 1))
        deadline = time.monotonic() + _timeout
        for attempt in range(max(1, attempts)):
            try:
                return self._call_once(kind, fields, deadline)
            except WorkerError:
                raise  # application error: the peer is alive, never retry
            except (OSError, ProtocolError):
                if attempt + 1 >= attempts or not _backoff(attempt, deadline):
                    raise
                obs_metrics.counter("distrib.rpc_retries").inc()
        raise AssertionError("unreachable")  # pragma: no cover

    def close(self) -> None:
        with self._lock:
            self._closed = True
            while self._idle:
                self._idle.popleft().close()
