"""Deterministic fault injection for the wire runtime (DESIGN.md §13).

Recovery code that is only ever exercised by ad-hoc ``kill -9`` in tests
is recovery code that silently rots: the failure *point* drifts with
scheduler noise, so a red run cannot be replayed and a green run proves
little.  A :class:`FaultPlan` makes faults first-class, seeded inputs —
the same plan produces the same failure at the same protocol event every
run, which is what lets the §3.3 recovery tests assert bit-exact
recovered state.

A plan is a seed plus an ordered list of rules::

    REPRO_FAULTS="seed=7;kill:task=1,step=3;refuse:times=2,port=7077"

Rule grammar (``action:key=val,key=val,...``):

``kill``      ``step=N [task=T]`` — the matching *worker* process exits
              hard (``os._exit``, no flush — indistinguishable from
              ``kill -9``) upon receiving its N-th ``run_graph`` RPC,
              before executing it.
``drop``      ``[rpc=KIND] [key=SUBSTR] [times=N] [after=K]`` — the
              matching client-side RPC raises :class:`InjectedFault`
              (an ``OSError``: callers classify it as a transport
              failure) instead of touching the socket.  ``key`` matches
              a substring of the call's ``key`` field, so individual
              wire tensors (a predicate broadcast, one loop iteration)
              can be targeted.
``delay``     ``ms=M [rpc=KIND] [key=SUBSTR] [times=N] [after=K]`` —
              sleep M milliseconds before issuing the matching RPC.
``stall_hb``  ``times=N [task=T]`` — the matching *worker* drops the
              connection of its next N ``heartbeat`` RPCs without
              replying, so the master's monitor counts misses against a
              perfectly healthy process.
``refuse``    ``times=N [port=P]`` — the next N client connection
              attempts (optionally only to ``port``) fail with
              ``ConnectionRefusedError`` before dialing, simulating a
              standby worker that has not finished binding its port.

``times`` defaults to 1; ``after`` skips the first K matches.  Counters
live per rule per process, so a plan shipped to every process of a pool
via the ``REPRO_FAULTS`` environment variable (``start_worker_processes``
inherits it) fires at the same protocol events on every replay.  The
``seed`` additionally fixes the retry-backoff jitter stream
(:func:`jitter_rng`), so even timing-adjacent behaviour replays.

Workers call :func:`set_context` with their task id at startup; rules
carrying ``task=`` only fire in that process.  The master/client side
has no task context (``task=None``) and only client-side rules
(``drop``/``delay``/``refuse``) apply there.
"""
from __future__ import annotations

import os
import random
import threading
import time
from typing import Any, Dict, List, Optional

_CLIENT_ACTIONS = ("drop", "delay", "refuse")
_SERVER_ACTIONS = ("kill", "stall_hb")
_ACTIONS = _CLIENT_ACTIONS + _SERVER_ACTIONS

_INT_PARAMS = {"task", "step", "times", "after", "port", "ms"}


class InjectedFault(ConnectionError):
    """A fault-plan-injected transport failure.

    Subclasses ``ConnectionError`` (hence ``OSError``) deliberately: the
    runtime must classify an injected drop exactly as it classifies a
    real dead connection — same retry policy, same §3.3 condemnation.
    """


class _DropConnection(Exception):
    """Server-side signal: close the connection without replying."""


class FaultRule:
    """One match-counted fault. Thread-safe: concurrent RPCs may probe."""

    def __init__(self, action: str, **params: Any) -> None:
        if action not in _ACTIONS:
            raise ValueError(f"unknown fault action {action!r} "
                             f"(want one of {_ACTIONS})")
        self.action = action
        self.params: Dict[str, Any] = params
        self.times = int(params.get("times", 1))
        self.after = int(params.get("after", 0))
        self.fired = 0      # matches that actually injected
        self.seen = 0       # matches including the skipped `after` window
        self._lock = threading.Lock()
        if action == "kill" and "step" not in params:
            raise ValueError("kill rule requires step=N")
        if action == "delay" and "ms" not in params:
            raise ValueError("delay rule requires ms=M")

    def _consume(self) -> bool:
        """One matching event occurred: does the rule fire on it?"""
        with self._lock:
            self.seen += 1
            if self.seen <= self.after:
                return False
            if self.fired >= self.times:
                return False
            self.fired += 1
            return True

    def _field_match(self, name: str, value: Any) -> bool:
        want = self.params.get(name)
        return want is None or want == value

    def _key_match(self, fields: Dict[str, Any]) -> bool:
        want = self.params.get("key")
        return want is None or want in str(fields.get("key", ""))

    def spec(self) -> str:
        kv = ",".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return f"{self.action}:{kv}" if kv else self.action

    def __repr__(self) -> str:
        return (f"<FaultRule {self.spec()} fired={self.fired}/{self.times} "
                f"seen={self.seen}>")


class FaultPlan:
    """A seeded, replayable list of :class:`FaultRule`\\ s."""

    def __init__(self, rules: Optional[List[FaultRule]] = None, *,
                 seed: int = 0) -> None:
        self.seed = int(seed)
        self.rules: List[FaultRule] = list(rules or [])
        self.rng = random.Random(self.seed)

    @staticmethod
    def parse(spec: "FaultPlan | str") -> "FaultPlan":
        """``"seed=7;kill:task=1,step=3;..."`` -> FaultPlan."""
        if isinstance(spec, FaultPlan):
            return spec
        seed = 0
        rules: List[FaultRule] = []
        for part in (p.strip() for p in spec.split(";")):
            if not part:
                continue
            if part.startswith("seed="):
                seed = int(part.split("=", 1)[1])
                continue
            action, _, rest = part.partition(":")
            params: Dict[str, Any] = {}
            for kv in (s for s in rest.split(",") if s):
                k, _, v = kv.partition("=")
                k = k.strip()
                params[k] = int(v) if k in _INT_PARAMS else v.strip()
            rules.append(FaultRule(action.strip(), **params))
        return FaultPlan(rules, seed=seed)

    def describe(self) -> str:
        """Canonical replayable spec string (put this in failure reports:
        exporting it as ``REPRO_FAULTS`` reproduces the run)."""
        return ";".join([f"seed={self.seed}"] + [r.spec() for r in self.rules])

    def _matching(self, action: str) -> List[FaultRule]:
        return [r for r in self.rules if r.action == action]

    def __repr__(self) -> str:
        return f"<FaultPlan {self.describe()!r}>"


# ---------------------------------------------------------------------------
# process-wide installation + context

_UNSET = object()
_plan: Any = _UNSET          # _UNSET -> lazily load from env on first use
_context: Dict[str, Any] = {"task": None}
_install_lock = threading.Lock()


def install(plan: "FaultPlan | str | None") -> Optional[FaultPlan]:
    """Install (or clear, with None) the process-wide plan; returns it."""
    global _plan
    with _install_lock:
        _plan = FaultPlan.parse(plan) if plan is not None else None
    return _plan


def set_context(task: Optional[int]) -> None:
    """Declare this process's cluster task id (workers, at startup)."""
    _context["task"] = task


def active() -> Optional[FaultPlan]:
    """The installed plan, lazily parsed from ``REPRO_FAULTS`` once."""
    global _plan
    if _plan is _UNSET:
        with _install_lock:
            if _plan is _UNSET:
                spec = os.environ.get("REPRO_FAULTS")
                _plan = FaultPlan.parse(spec) if spec else None
    return _plan


def jitter_rng() -> random.Random:
    """RNG for retry-backoff jitter: plan-seeded when a plan is installed
    (deterministic replay), a module default otherwise."""
    plan = active()
    return plan.rng if plan is not None else _default_rng


_default_rng = random.Random()


# ---------------------------------------------------------------------------
# hooks — all no-ops (one None check) when no plan is installed

def on_connect(host: str, port: int) -> None:
    """Client side, before dialing. May raise ``ConnectionRefusedError``."""
    plan = active()
    if plan is None:
        return
    for rule in plan._matching("refuse"):
        if rule._field_match("port", port) and rule._consume():
            raise ConnectionRefusedError(
                f"[fault-injected] connection to {host}:{port} refused "
                f"({rule.spec()})")


def on_call(kind: str, fields: Dict[str, Any], host: str, port: int) -> None:
    """Client side, per attempt, before the request frame is written.
    May sleep (delay) or raise :class:`InjectedFault` (drop)."""
    plan = active()
    if plan is None:
        return
    for rule in plan._matching("delay"):
        if (rule._field_match("rpc", kind) and rule._key_match(fields)
                and rule._consume()):
            time.sleep(int(rule.params["ms"]) / 1000.0)
    for rule in plan._matching("drop"):
        if (rule._field_match("rpc", kind) and rule._key_match(fields)
                and rule._consume()):
            raise InjectedFault(
                f"[fault-injected] {kind} RPC to {host}:{port} dropped "
                f"({rule.spec()})")


def on_serve(kind: str, task: Optional[int]) -> None:
    """Worker serve loop, before dispatching a received RPC.  May raise
    :class:`_DropConnection` (the loop closes the socket, no reply)."""
    plan = active()
    if plan is None:
        return
    if kind == "heartbeat":
        for rule in plan._matching("stall_hb"):
            if rule._field_match("task", task) and rule._consume():
                raise _DropConnection(rule.spec())


def on_run_graph(task: Optional[int]) -> None:
    """Worker, upon receiving ``run_graph`` and before executing it.
    A matching ``kill`` rule hard-exits the process (``kill -9`` twin)."""
    plan = active()
    if plan is None:
        return
    for rule in plan._matching("kill"):
        if not rule._field_match("task", task):
            continue
        with rule._lock:
            rule.seen += 1
            due = rule.seen == int(rule.params["step"]) and not rule.fired
            if due:
                rule.fired += 1
        if due:
            # mirror SIGKILL: no atexit, no flushing, no socket shutdown
            os._exit(137)
