"""§4.3–§4.4 data-parallel replication over the worker pool (DESIGN.md §15).

A :class:`ReplicaPlan` stamps N copies of a train-step subgraph across the
tasks of a cluster (or an in-process multi-device DeviceSet) and wires the
paper's two aggregation disciplines:

* **sync** — one combined graph: shared Variables homed on task 0, N
  device-tagged replica forward/backward subgraphs, and a per-Variable
  binary-tree gradient reduce whose cross-task edges become ordinary
  Send/Recv pairs at partition time (the allreduce shape of *Distributed
  TensorFlow with MPI*).  The averaged gradient feeds a single apply on
  the Variable's home task, so one ``Session.run`` per step is a full
  synchronous barrier: every replica's gradient is in the average, and
  every replica reads the updated Variables next step.
* **async** — parameter-server Variables live *master-side* (in this
  plan, guarded by a lock): each replica is a disjoint gradient-only
  subgraph on its own task whose parameters arrive as *feeds* (the
  parameter fetch) and whose fetches are the gradients (the push).  A
  driver thread per replica loops fetch → compute → apply with NO
  barrier between replicas — applies interleave, exactly the Downpour
  shape of *Large Scale Distributed Deep Networks*.

The graphs contain no frames and no dead branches, so the §14 verifier's
C-pass accepts the reduce edges; the Variable-race pass is satisfied
because every replica read is ordered before the apply by the data path
loss → grads → reduce → apply.

Model-specific step shapes (the primitive-op MLP, the factory-Call LM)
are declared as :class:`ReplicaSpec` callbacks in ``repro.launch.steps``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.graph import Node, TensorRef
from ..core.options import SessionOptions

# module-level reduce kernels: pickle by reference, work on arrays AND
# pytrees (the LM's params-gradient is a nested dict)


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


class _TreeScale:
    """Picklable ``x * scale`` over a pytree (closure-free, §15)."""

    def __init__(self, scale: float) -> None:
        self.scale = float(scale)

    def __call__(self, x):
        return jax.tree.map(
            lambda v: (v * jnp.asarray(self.scale, dtype=v.dtype)
                       if jnp.issubdtype(jnp.asarray(v).dtype, jnp.floating)
                       else v * self.scale), x)


@dataclasses.dataclass
class ReplicaStep:
    """What one stamped replica exposes to the plan."""

    loss: TensorRef
    grads: Dict[str, TensorRef]      # grad-var name -> gradient ref
    feeds: Dict[str, TensorRef]      # feed name -> this replica's placeholder


@dataclasses.dataclass
class ReplicaSpec:
    """A train step described abstractly enough to stamp N times.

    ``build_replica(b, r, device, var_inputs)`` adds replica ``r``'s
    forward+backward subgraph reading parameters from ``var_inputs``
    (shared Variable nodes in sync mode, per-replica placeholders in
    async mode — the callback must not care which) and returns a
    :class:`ReplicaStep`.  ``build_apply(b, var_nodes, mean_grads,
    device)`` adds the single averaged apply (sync mode) and returns the
    train op.  ``apply_fn(values, grads) -> new values`` is the
    master-side parameter-server update (async mode); it must be
    picklable-by-reference-or-construction but runs only in the master
    process.
    """

    var_names: Tuple[str, ...]       # all stateful Variables (params, opt, ...)
    read_vars: Tuple[str, ...]       # subset the replica step actually reads
    grad_vars: Tuple[str, ...]       # subset receiving gradients
    feed_names: Tuple[str, ...]
    init_values: Dict[str, Any]
    build_replica: Callable[..., ReplicaStep]
    build_apply: Callable[..., Node]
    apply_fn: Optional[Callable[[Dict[str, Any], Dict[str, Any]],
                                Dict[str, Any]]] = None


def _pin_new_nodes(graph, before: set, device: str) -> None:
    """Device-tag every node added since ``before`` that carries no
    explicit constraint — replica subgraphs (including their §4.1
    backward extension, which ``gradients()`` adds un-tagged) must stay
    on their replica's task or the placer could colocate all N backward
    passes and erase the scaling."""
    for name, node in graph.nodes.items():
        if name not in before and node.device is None:
            node.device = device


def reduce_tree(b, parts: List[TensorRef], devices: List[str], *,
                base: str, home: str, n: int) -> TensorRef:
    """Binary-tree mean-reduce of ``parts`` (one per replica): pair (0,1)
    adds on 0's task, (2,3) on 2's, then (0,2) on 0's ... so each level
    halves the participants and every cross-task edge partitions into one
    Send/Recv pair.  The final 1/n scale lands on ``home`` (the owning
    Variable's task) so the apply is local."""
    level = 0
    parts, devices = list(parts), list(devices)
    while len(parts) > 1:
        nxt, nxtd = [], []
        for i in range(0, len(parts) - 1, 2):
            node = b.call(_tree_add, [parts[i], parts[i + 1]],
                          name=f"{base}/reduce{level}_{i // 2}",
                          device=devices[i])
            nxt.append(node.ref)
            nxtd.append(devices[i])
        if len(parts) % 2:
            nxt.append(parts[-1])
            nxtd.append(devices[-1])
        parts, devices = nxt, nxtd
        level += 1
    mean = b.call(_TreeScale(1.0 / n), [parts[0]], name=f"{base}/mean",
                  device=home)
    return mean.ref


class ReplicaPlan:
    """N replicas of a :class:`ReplicaSpec` across a task pool.

    ``mode="sync"``: :meth:`step` runs one barrier step over per-replica
    shards and returns the mean replica loss.  ``mode="async"``:
    :meth:`run_async` drives per-replica threads with interleaved
    master-side applies.  ``cluster=`` makes execution multi-process;
    without it the plan runs on an in-process multi-device DeviceSet of
    the same shape (the bit-parity oracle for the sync tests).
    """

    def __init__(self, spec: ReplicaSpec, n_replicas: int, *,
                 mode: str = "sync", cluster: Any = None,
                 devices: Any = None, tasks: Optional[Sequence[str]] = None,
                 options: Optional[SessionOptions] = None) -> None:
        from ..core.ops import GraphBuilder
        from ..core.session import Session

        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {mode!r}")
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.spec = spec
        self.n_replicas = n_replicas
        self.mode = mode
        if tasks is None:
            if cluster is not None:
                from .wire import ClusterSpec

                cl = ClusterSpec.parse(cluster)
                tasks = [f"/job:worker/task:{t}"
                         for t in range(len(cl.workers))]
            else:
                tasks = [f"/job:worker/task:{t}" for t in range(n_replicas)]
        self.tasks = list(tasks)
        if devices is None and cluster is None:
            from ..runtime.devices import DeviceSet

            devices = DeviceSet.make_cluster(len(self.tasks), 1, kind="cpu")

        b = GraphBuilder()
        self.home = self.tasks[0]
        self.replicas: List[ReplicaStep] = []
        self._lock = threading.Lock()
        self._values: Dict[str, Any] = {}

        if mode == "sync":
            var_nodes = {name: b.variable(name, spec.init_values[name],
                                          device=self.home)
                         for name in spec.var_names}
            for r in range(n_replicas):
                dev = self.tasks[r % len(self.tasks)]
                before = set(b.graph.nodes)
                step = spec.build_replica(
                    b, r, dev, {n: var_nodes[n] for n in spec.read_vars})
                _pin_new_nodes(b.graph, before, dev)
                self.replicas.append(step)
            # per-Variable gradient reduce trees + one averaged apply
            mean_grads: Dict[str, TensorRef] = {}
            for name in spec.grad_vars:
                parts = [rep.grads[name] for rep in self.replicas]
                devs = [self.tasks[r % len(self.tasks)]
                        for r in range(n_replicas)]
                mean_grads[name] = reduce_tree(
                    b, parts, devs, base=f"grad_reduce/{name}",
                    home=self.home, n=n_replicas)
            before = set(b.graph.nodes)
            self.train_op = spec.build_apply(b, var_nodes, mean_grads,
                                             self.home)
            _pin_new_nodes(b.graph, before, self.home)
            # mean replica loss (scalar binary tree, same edge discipline)
            loss_refs = [rep.loss for rep in self.replicas]
            devs = [self.tasks[r % len(self.tasks)]
                    for r in range(n_replicas)]
            self.mean_loss = reduce_tree(
                b, loss_refs, devs, base="loss_reduce", home=self.home,
                n=n_replicas)
        else:
            if spec.apply_fn is None:
                raise ValueError("async mode needs spec.apply_fn "
                                 "(the master-side parameter-server update)")
            self._values = {k: v for k, v in spec.init_values.items()}
            for r in range(n_replicas):
                dev = self.tasks[r % len(self.tasks)]
                before = set(b.graph.nodes)
                var_inputs = {n: b.placeholder(f"rep{r}/{n}")
                              for n in spec.read_vars}
                step = spec.build_replica(b, r, dev, var_inputs)
                _pin_new_nodes(b.graph, before, dev)
                step.feeds = dict(step.feeds)
                step.feeds.update(
                    {f"__var__{n}": var_inputs[n].ref
                     for n in spec.read_vars})
                self.replicas.append(step)
            self.train_op = None
            self.mean_loss = None

        self.builder = b
        self.session = Session(b.graph, options=dataclasses.replace(
            options or SessionOptions(), cluster=cluster, devices=devices))
        self._async_runs: List[Callable] = []

    # ------------------------------------------------------------------
    # sync mode
    def step(self, shards: Sequence[Dict[str, Any]], *,
             timeout: float = 60.0) -> float:
        """One synchronous barrier step: ``shards[r]`` feeds replica ``r``
        (missing shards reuse ``shards[r % len(shards)]``).  Returns the
        mean replica loss."""
        if self.mode != "sync":
            raise RuntimeError("step() is sync-mode only; use run_async()")
        feeds: Dict[TensorRef, Any] = {}
        for r, rep in enumerate(self.replicas):
            shard = shards[r % len(shards)]
            for fname in self.spec.feed_names:
                feeds[rep.feeds[fname]] = shard[fname]
        loss, _ = self.session.run(
            [self.mean_loss, self.train_op.ref], feeds)
        return loss

    # ------------------------------------------------------------------
    # async mode
    def _replica_callable(self, r: int) -> Callable[..., List[Any]]:
        rep = self.replicas[r]
        fetch = [rep.loss] + [rep.grads[n] for n in self.spec.grad_vars]
        feed_refs = ([rep.feeds[f"__var__{n}"] for n in self.spec.read_vars]
                     + [rep.feeds[f] for f in self.spec.feed_names])
        return self.session.make_callable(fetch, feed_refs)

    def run_async(self, batch_fn: Callable[[int, int], Dict[str, Any]],
                  steps: int, *, on_step: Optional[Callable] = None
                  ) -> List[Tuple[int, int, float]]:
        """Drive ``steps`` total interleaved applies across the replicas.

        Each replica thread loops: snapshot the master-side parameter
        values (the fetch), run its gradient subgraph on
        ``batch_fn(step_index, replica)``, then apply under the lock —
        no barrier, replicas overlap freely and late gradients apply to
        newer parameters (bounded staleness ~ n_replicas).  Returns
        ``(step_index, replica, loss)`` triples in apply order.
        """
        if self.mode != "async":
            raise RuntimeError("run_async() is async-mode only; use step()")
        counter = iter(range(steps))
        losses: List[Tuple[int, int, float]] = []
        errors: List[BaseException] = []
        runs = [self._replica_callable(r) for r in range(self.n_replicas)]

        def drive(r: int) -> None:
            while not errors:
                with self._lock:
                    i = next(counter, None)
                    if i is None:
                        return
                    vals = {n: self._values[n] for n in self.spec.read_vars}
                batch = batch_fn(i, r)
                try:
                    outs = runs[r](
                        *[vals[n] for n in self.spec.read_vars],
                        *[batch[f] for f in self.spec.feed_names])
                except BaseException as e:  # noqa: BLE001 — surface below
                    errors.append(e)
                    return
                loss = outs[0]
                grads = dict(zip(self.spec.grad_vars, outs[1:]))
                with self._lock:
                    self._values.update(
                        self.spec.apply_fn(dict(self._values), grads))
                    losses.append((i, r, float(loss)))
                    if on_step is not None:
                        on_step(i, r, float(loss))

        threads = [threading.Thread(target=drive, args=(r,), daemon=True,
                                    name=f"replica-{r}")
                   for r in range(self.n_replicas)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return losses

    # ------------------------------------------------------------------
    def variable_values(self) -> Dict[str, Any]:
        """Current parameter state: the master-side store in async mode,
        pulled from the pool (or the local store) in sync mode."""
        if self.mode == "async":
            with self._lock:
                return dict(self._values)
        if self.session.cluster is not None and self.session._master is not None:
            return self.session.pull_cluster_variables()
        return {n: self.session.variable_value(n)
                for n in self.spec.var_names}

    def set_variable_values(self, values: Dict[str, Any]) -> None:
        """Restore parameter state (e.g. from a checkpoint)."""
        if self.mode == "async":
            with self._lock:
                self._values.update(values)
            return
        for n, v in values.items():
            self.session.set_variable(n, v)

    def close(self) -> None:
        self.session.close()
