"""§3.2.2 rendezvous over TCP — the distributed half of Send/Recv.

``runtime/rendezvous.py`` promised that "a distributed implementation
would swap TCP/RDMA underneath the same interface"; :class:`WireRendezvous`
is that implementation.  It exposes the exact executor-facing surface
(``send`` / ``ready`` / ``wait_any`` / ``recv``) so ``core/executor.py``
— including the §4.4 frame-tagged keys and the DEAD_TENSOR wire marker
of the distributed-control-flow machinery — runs unchanged whether the
peer device is a thread or a process.

Transport model (the paper's §3.2.2 and the TF RecvTensor RPC): ``send``
is always local — the producing worker deposits into its own mailbox.
The *consuming* worker pulls: the first ``ready``/``recv``/``wait_any``
probe for a remote key starts an async fetcher thread that issues a
``recv_tensor`` RPC to the producing worker and deposits the reply into
the local mailbox, so the executor's Recv-deferral logic (defer while
other work is runnable, then ``wait_any``) behaves identically to the
in-process case.  Keys are namespaced by execution id so concurrent runs
of the same registered graph never mix.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from ..runtime.devices import Device, DeviceName, DeviceSet
from ..runtime.rendezvous import Rendezvous
from .protocol import Channel


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Topology of a worker pool: one ``host:port`` endpoint per task.

    Task ``t`` serves the virtual devices
    ``/job:worker/task:t/device:<kind>:<i>`` for ``i < devices_per_task``,
    so the §3.2.1 placer's device names map 1:1 onto owning processes.
    """

    workers: Tuple[str, ...]
    devices_per_task: int = 1
    kind: str = "cpu"

    @staticmethod
    def parse(spec: "ClusterSpec | str | Sequence[str]",
              devices_per_task: int = 1, kind: str = "cpu") -> "ClusterSpec":
        if isinstance(spec, ClusterSpec):
            return spec
        if isinstance(spec, str):
            workers = tuple(s.strip() for s in spec.split(",") if s.strip())
        else:
            workers = tuple(spec)
        if not workers:
            raise ValueError(f"empty cluster spec {spec!r}")
        for w in workers:
            host, _, port = w.rpartition(":")
            if not host or not port.isdigit():
                raise ValueError(f"bad cluster endpoint {w!r} (want host:port)")
        return ClusterSpec(workers, devices_per_task, kind)

    def device_set(self) -> DeviceSet:
        return DeviceSet([
            Device(DeviceName(job="worker", task=t, kind=self.kind, index=i))
            for t in range(len(self.workers))
            for i in range(self.devices_per_task)
        ])

    def task_of_device(self, device_name: str) -> int:
        task = DeviceName.parse(device_name).task
        if task >= len(self.workers):
            raise ValueError(
                f"device {device_name!r} names task {task} but the cluster "
                f"has only {len(self.workers)} workers")
        return task

    def host_port(self, task: int) -> Tuple[str, int]:
        host, _, port = self.workers[task].rpartition(":")
        return host, int(port)

    def fingerprint(self) -> Tuple[str, ...]:
        """Part of the RunSignature device fingerprint — the pool's
        *shape* only, never its endpoints.  Placement and partitioning
        depend solely on the virtual device names (task count, devices
        per task, kind), so an Executable stays valid when a task moves
        to a different endpoint: §13 partial re-placement patches the
        live WirePlan (re-registering just the moved task) and a §3.3
        whole-pool rebind re-registers lazily via the master's
        ``generation`` counter.  Endpoints in the fingerprint would force
        a full re-place/partition/re-register of every cached Executable
        on any recovery — exactly the cost partial re-placement exists to
        avoid."""
        return ("cluster", str(len(self.workers)),
                str(self.devices_per_task), self.kind)

    def with_replacement(self, task: int, endpoint: str) -> "ClusterSpec":
        """The same pool shape with ``task`` served from ``endpoint``
        (§13 partial re-placement).  The endpoint may already serve
        another task — a survivor hosting the dead task's devices."""
        host, _, port = endpoint.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad replacement endpoint {endpoint!r}")
        workers = list(self.workers)
        workers[task] = endpoint
        return ClusterSpec(tuple(workers), self.devices_per_task, self.kind)

    def to_wire(self) -> Dict[str, Any]:
        return {"workers": list(self.workers),
                "devices_per_task": self.devices_per_task, "kind": self.kind}

    @staticmethod
    def from_wire(d: Dict[str, Any]) -> "ClusterSpec":
        return ClusterSpec(tuple(d["workers"]), d["devices_per_task"], d["kind"])


class _FetchError:
    """Poison value a failed remote fetch deposits under the awaited key so
    the blocked executor raises instead of timing out blind."""

    __slots__ = ("error",)

    def __init__(self, error: str) -> None:
        self.error = error


class WireRendezvous:
    """The ``runtime/rendezvous.py`` interface over sockets (DESIGN.md §11).

    Wraps the worker's process-wide mailbox (a plain :class:`Rendezvous`)
    with (a) per-execution key namespacing and (b) pull-based remote
    fetches.  One instance exists per (worker, execution); the underlying
    mailbox is shared so the worker's ``recv_tensor`` server can serve
    peers directly from it.
    """

    _POLL = 0.25  # abort-check granularity while blocked

    def __init__(self, mailbox: Rendezvous, cluster: ClusterSpec,
                 local_task: int, execution_id: str, *,
                 timeout: float = 30.0,
                 channel_of: Optional[Callable[[int], Channel]] = None) -> None:
        self._mb = mailbox
        self._cluster = cluster
        self._task = local_task
        self._eid = execution_id
        self.timeout = timeout
        self._channel_of = channel_of
        self._fetching: set = set()
        self._lock = threading.Lock()
        self._abort: Optional[BaseException] = None
        self._closed = False
        self.sends = 0  # instrumentation (mirrors Rendezvous)
        self.bytes_sent = 0
        self.remote_fetches = 0

    # -- key plumbing ---------------------------------------------------
    def _ns(self, key: str) -> str:
        return f"{self._eid}|{key}"

    def _owner(self, key: str) -> int:
        # rendezvous keys are "src_device;dst_device;tensor;execution" and
        # the executor's frame tag only ever appends "#...", so the source
        # device is always the first ';' field
        return self._cluster.task_of_device(key.split(";", 1)[0])

    def _is_remote(self, key: str) -> bool:
        return self._owner(key) != self._task

    # -- interface ------------------------------------------------------
    def abort(self, exc: BaseException) -> None:
        """§3.3: poison this execution — blocked recv/wait_any raise."""
        self._abort = exc

    def close(self) -> None:
        """End-of-execution: straggler fetchers must drop their deposits
        (the mailbox outlives this view; see worker run_graph cleanup)."""
        self._closed = True

    def send(self, key: str, value: Any) -> None:
        # Send is always local: the §3.2.2 partitioner places a Send on
        # the producing device, so only this worker's executors call it.
        self._mb.send(self._ns(key), value)
        self.sends += 1
        try:
            self.bytes_sent += value.nbytes
        except AttributeError:
            pass

    def ready(self, key: str) -> bool:
        nk = self._ns(key)
        if self._mb.ready(nk):
            return True
        if self._is_remote(key):
            self._ensure_fetch(key)
            return self._mb.ready(nk)
        return False

    def wait_any(self, keys: Iterable[str], timeout: Optional[float] = None) -> str:
        keys = list(keys)
        for k in keys:
            # mailbox-first: when a survivor hosts two tasks (§13 partial
            # re-placement onto a survivor) both views share this process's
            # mailbox, so a "remote" key may already be deposited locally —
            # probing before fetching avoids a loopback RPC to ourselves
            if self._is_remote(k) and not self._mb.ready(self._ns(k)):
                self._ensure_fetch(k)
        ns_of = {self._ns(k): k for k in keys}
        deadline = time.monotonic() + (self.timeout if timeout is None else timeout)
        while True:
            if self._abort is not None:
                raise self._abort
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"recv timed out waiting for any of {keys!r} "
                    f"(task:{self._task}, execution {self._eid})")
            try:
                got = self._mb.wait_any(list(ns_of),
                                        timeout=min(self._POLL, remaining))
            except TimeoutError:
                continue
            return ns_of[got]

    def recv(self, key: str) -> Any:
        nk = self._ns(key)
        if self._is_remote(key) and not self._mb.ready(nk):
            self._ensure_fetch(key)
        deadline = time.monotonic() + self.timeout
        while True:
            if self._abort is not None:
                raise self._abort
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"recv timed out waiting for {key!r} "
                    f"(task:{self._task}, execution {self._eid})")
            try:
                v = self._mb.recv(nk, timeout=min(self._POLL, remaining))
            except TimeoutError:
                continue
            if isinstance(v, _FetchError):
                raise RuntimeError(v.error)
            return v

    # -- remote pull ----------------------------------------------------
    def _ensure_fetch(self, key: str) -> None:
        with self._lock:
            if key in self._fetching:
                return
            self._fetching.add(key)
        t = threading.Thread(target=self._fetch, args=(key,), daemon=True,
                             name=f"wire-fetch:{key[:40]}")
        t.start()

    _FETCH_CHUNK = 2.0  # per-RPC wait; close/abort responsiveness bound

    def _fetch(self, key: str) -> None:
        # Chunked pull: short recv_tensor polls instead of one blocking
        # RPC for the full timeout, so a closed/aborted view (§13 purge,
        # end of execution) releases this thread within a chunk instead
        # of pinning it — and a connection to the peer is never held
        # hostage to a tensor that will now never be produced.  A key
        # deposited locally between polls (a co-hosted producer view
        # after partial re-placement onto a survivor) also ends the
        # fetch without a loopback round-trip.
        owner = self._owner(key)
        nk = self._ns(key)
        deadline = time.monotonic() + self.timeout
        try:
            if self._channel_of is None:
                raise RuntimeError("no peer channels configured")
            while True:
                if self._closed or self._abort is not None:
                    return
                if self._mb.ready(nk):
                    return
                budget = deadline - time.monotonic()
                if budget <= 0:
                    raise TimeoutError(
                        f"remote fetch gave up after {self.timeout:.1f}s")
                chunk = min(self._FETCH_CHUNK, budget)
                rep = self._channel_of(owner).call(
                    "recv_tensor", key=nk, wait=chunk, poll=True,
                    _timeout=chunk + 10.0)
                if rep.get("timeout"):
                    continue
                value = rep["value"]
                self.remote_fetches += 1
                break
        except BaseException as e:  # noqa: BLE001 — poison, never hang
            value = _FetchError(
                f"fetching {key!r} from worker task:{owner} "
                f"({self._cluster.workers[owner]}): {type(e).__name__}: {e}")
        if self._closed:
            return  # execution already over; don't leak into the mailbox
        try:
            self._mb.send(nk, value)
        except RuntimeError:
            pass  # duplicate deposit after an abort/cleanup race — drop
