"""§3.2/§3.3 master: drives a worker pool from the Executable pipeline.

The master is the paper's client+master pair in one process: the
Session's Executable pipeline still runs place → partition → schedule
exactly once per run signature, and when the Session carries a
``cluster=`` spec the resulting per-device subgraphs are *shipped* to
their owning worker processes (``register_graph``) instead of executed
on local threads.  Each ``run`` then fans one ``run_graph`` RPC out per
task under a fresh execution id; workers coordinate tensor transfers
peer-to-peer through the :class:`~repro.distrib.wire.WireRendezvous`,
and the master only collects fetch values.

Fault tolerance (§3.3, §4.3 of the OSDI follow-up): a heartbeat monitor
pings every worker; on a timeout (or a transport error mid-run) the
worker is marked dead, in-flight executions abort with an
:class:`~repro.core.executor.ExecutorError` naming the lost process/host
(task, endpoint, pid), and training resumes by restarting the pool,
rebinding the session (``Session.rebind_cluster``) and restoring the
last checkpoint — re-registration ships the restored Variable state.
"""
from __future__ import annotations

import itertools
import threading
import time
import uuid
import weakref
from typing import Any, Dict, List, Optional, Tuple

from ..core.executor import ExecutorError
from ..core.graph import Graph, TensorRef
from .protocol import Channel, WorkerError
from .wire import ClusterSpec


class Master:
    """Connection + liveness manager for one worker pool."""

    def __init__(self, cluster: "ClusterSpec | str", *,
                 heartbeat_interval: float = 0.5,
                 heartbeat_misses: int = 3) -> None:
        self.cluster = ClusterSpec.parse(cluster)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_misses = heartbeat_misses
        self.generation = 0  # bumped on reset(); plans re-register lazily
        self.dead: Dict[int, str] = {}
        # weak refs: a plan lives exactly as long as its Executable — the
        # session's LRU eviction must actually free partitioned graphs
        # and shipped-payload copies, not pin them here forever
        self.plans: List["weakref.ref[WirePlan]"] = []
        self._info: Dict[int, Dict[str, Any]] = {}
        self._misses: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self.channels: Dict[int, Channel] = {
            t: Channel(*self.cluster.host_port(t))
            for t in range(len(self.cluster.workers))}

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._hb_thread is None and self.heartbeat_interval > 0:
            self._stop.clear()
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, daemon=True, name="master-hb")
            self._hb_thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._hb_thread = self._hb_thread, None
        if t is not None:
            t.join(timeout=2.0)
        for ch in self.channels.values():
            ch.close()

    def reset(self, cluster: "ClusterSpec | str | None" = None) -> None:
        """§3.3 recovery: point at a restarted pool (same task count).

        Bumps ``generation`` so every WirePlan re-registers on its next
        run.  Registration *seeds* missing worker Variables from the
        session store; live state is never clobbered — recovery pushes
        restored values explicitly (``Session.rebind_cluster`` /
        ``WirePlan.push_variables``)."""
        new = ClusterSpec.parse(cluster) if cluster is not None else self.cluster
        if len(new.workers) != len(self.cluster.workers):
            raise ValueError(
                f"recovery pool has {len(new.workers)} workers, expected "
                f"{len(self.cluster.workers)} (placement is per-task)")
        self.stop()
        self.cluster = new
        self.channels = {t: Channel(*new.host_port(t))
                         for t in range(len(new.workers))}
        self.dead.clear()
        self._info.clear()
        self._misses.clear()
        self.generation += 1
        self.start()

    # ------------------------------------------------------------------
    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            for task, ch in list(self.channels.items()):
                if self._stop.is_set() or task in self.dead:
                    continue
                try:
                    rep = ch.call("heartbeat",
                                  _timeout=max(1.0, self.heartbeat_interval * 4))
                    with self._lock:
                        self._info[task] = rep
                        self._misses[task] = 0
                except Exception as e:  # noqa: BLE001 — count, then condemn
                    with self._lock:
                        self._misses[task] = self._misses.get(task, 0) + 1
                        if self._misses[task] >= self.heartbeat_misses:
                            self.dead.setdefault(
                                task, f"{self._misses[task]} consecutive "
                                      f"heartbeats failed ({type(e).__name__}: {e})")

    def live_plans(self) -> List["WirePlan"]:
        out, refs = [], []
        for r in self.plans:
            plan = r()
            if plan is not None:
                out.append(plan)
                refs.append(r)
        self.plans = refs  # prune dead refs as a side effect
        return out

    def identity(self, task: int) -> str:
        """Human-readable process identity for §3.3 failure reports."""
        host, port = self.cluster.host_port(task)
        pid = self._info.get(task, {}).get("pid")
        pid_s = f", pid {pid}" if pid is not None else ""
        return f"worker task:{task} ({host}:{port}{pid_s})"

    def mark_dead(self, task: int, reason: str) -> None:
        self.dead.setdefault(task, reason)

    def check(self) -> None:
        if self.dead:
            lost = "; ".join(f"{self.identity(t)}: {r}"
                             for t, r in sorted(self.dead.items()))
            raise ExecutorError(
                f"§3.3: lost {lost} — in-flight executions aborted; restart "
                f"the worker pool, rebind the session "
                f"(Session.rebind_cluster) and resume from the last "
                f"checkpoint")


class WirePlan:
    """Distributed run state of one Executable: per-task payloads + RPCs.

    Built once per run signature from the Executable's partitioned graph;
    registration with the workers is lazy and generation-aware, so a
    restarted pool transparently re-receives the subgraphs and the
    session's current Variable values on the next run.
    """

    def __init__(self, exe: Any, device_nodes: Dict[str, set]) -> None:
        session = exe.session
        self.exe = exe
        self.session = session
        self.master: Master = session.master
        self.handle = uuid.uuid4().hex[:12]
        self._eid_prefix = uuid.uuid4().hex[:8]
        self._eid_counter = itertools.count()
        self._registered_gen: Optional[int] = None
        self._reg_lock = threading.Lock()

        parted = exe.partitioned
        graph: Graph = parted.graph
        cluster: ClusterSpec = session.cluster
        n_tasks = len(cluster.workers)

        # unshippable-graph check up front, with a better error than a
        # deep pickle traceback: Call kernels must pickle by reference
        # (module-level functions, autodiff's _GradFn) — closures cannot
        # cross a process boundary
        from .protocol import pack_msg

        for name, node in graph.nodes.items():
            if node.op == "Call":
                try:
                    pack_msg({"fn": node.attrs.get("fn")})
                except Exception as e:  # noqa: BLE001 — rewrap with the node name
                    raise ExecutorError(
                        f"Call node {name!r} holds a Python closure that "
                        f"cannot ship to a worker process ({e}); distributed "
                        f"graphs must use registered primitive ops or "
                        f"importable callables (DESIGN.md §11)") from e

        task_devices: Dict[int, List[str]] = {}
        for dev in device_nodes:
            task_devices.setdefault(cluster.task_of_device(dev), []).append(dev)

        # Variable state: force-init through the session store so every
        # worker receives concrete values; the shipped subgraph carries
        # init=None (workers never run initializers).
        self.var_owner: Dict[str, int] = {}
        self._var_containers: Dict[str, str] = {}
        # each session gets its own VariableStore on every worker (§4.7:
        # in-process sessions default to one ContainerManager each; two
        # sessions sharing a pool must not share state through colliding
        # Variable names)
        self.namespace = getattr(session, "wire_namespace", "s")
        for name, node in graph.nodes.items():
            if node.op != "Variable":
                continue
            session._ctx().read_variable(session.graph.nodes.get(name, node))
            self.var_owner[name] = cluster.task_of_device(parted.placement[name])
            self._var_containers[name] = node.attrs.get("container", "")

        self.payloads: Dict[int, Dict[str, Any]] = {}
        self.feed_routing: Dict[int, set] = {}  # task -> feed keys it consumes
        for task in range(n_tasks):
            devs = task_devices.get(task, [])
            local_names = set().union(*(device_nodes[d] for d in devs)) if devs else set()
            sub = graph.subgraph(local_names)
            # a fed tensor is read at input-gather time by every LOCAL
            # consumer of the fed edge (§4.2 feed semantics), so ship each
            # feed only to tasks that consume it (plus fully-fed fetches
            # routed to this task's devices)
            needed = {r for name in local_names
                      for r in graph.nodes[name].inputs if r in exe.feed_keys}
            for dev in devs:
                needed |= {exe.fetches[i] for i in exe.fetch_by_dev.get(dev, [])
                           if exe.fetches[i] in exe.feed_keys}
            self.feed_routing[task] = needed
            for name in sub.nodes:
                if sub.nodes[name].op == "Variable":
                    # workers never run initializers — state is seeded /
                    # pushed as concrete values
                    sub.nodes[name].attrs["init"] = None
            fetches: Dict[str, List[Tuple[int, str, int]]] = {}
            for dev in devs:
                idxs = exe.fetch_by_dev.get(dev, [])
                if idxs:
                    fetches[dev] = [(i, exe.fetches[i].node, exe.fetches[i].port)
                                    for i in idxs]
            self.payloads[task] = {
                "handle": self.handle,
                "namespace": self.namespace,
                "task": task,
                "graph": sub,
                "device_nodes": {d: sorted(device_nodes[d]) for d in devs},
                "placement": {n: parted.placement[n] for n in local_names},
                "fetches": fetches,
                "feed_keys": [(r.node, r.port) for r in exe.feed_keys],
                "fuse": exe.fuse_regions,
                "numerics": exe.numerics,
            }
        self.master.plans.append(weakref.ref(self))

    # ------------------------------------------------------------------
    def _variable_payload(self, task: int) -> Dict[str, Tuple[str, Any]]:
        """Current session-store values of the Variables this task owns —
        read at registration time so recovery ships restored state."""
        g = self.session.graph
        out: Dict[str, Tuple[str, Any]] = {}
        for name, owner in self.var_owner.items():
            if owner != task:
                continue
            node = g.nodes[name]
            value = self.session.variables.read(name, node.attrs)
            out[name] = (self._var_containers[name], value)
        return out

    def ensure_registered(self) -> None:
        self.master.check()
        with self._reg_lock:
            if self._registered_gen == self.master.generation:
                return
            cluster_wire = self.master.cluster.to_wire()
            for task, payload in self.payloads.items():
                try:
                    self.master.channels[task].call(
                        "register_graph", _timeout=60.0, cluster=cluster_wire,
                        variables=self._variable_payload(task), **payload)
                except WorkerError:
                    raise
                except Exception as e:  # noqa: BLE001 — transport = lost worker
                    self.master.mark_dead(task, f"register_graph failed: {e}")
                    self.master.check()
                    raise
            self._registered_gen = self.master.generation

    # ------------------------------------------------------------------
    def push_variables(self) -> None:
        """Force-write the session store's values for this plan's
        Variables into their owning workers (§3.3 recovery: registration
        itself only *seeds* missing state, never clobbers live weights)."""
        for task in sorted(set(self.var_owner.values())):
            values = self._variable_payload(task)
            if values:
                self.master.channels[task].call(
                    "set_variables", _timeout=30.0,
                    namespace=self.namespace, values=values)

    def run(self, feeds: Dict[TensorRef, Any], *, timeout: float = 60.0) -> List[Any]:
        try:
            return self._run_once(feeds, timeout=timeout)
        except ExecutorError as e:
            # a worker's bounded graph registry may have evicted (or a
            # worker restarted under an unchanged endpoint): one
            # transparent re-registration retry
            if "is not registered here" not in str(e) or self.master.dead:
                raise
            with self._reg_lock:
                self._registered_gen = None
            return self._run_once(feeds, timeout=timeout)

    def _run_once(self, feeds: Dict[TensorRef, Any], *,
                  timeout: float = 60.0) -> List[Any]:
        self.ensure_registered()
        eid = f"{self._eid_prefix}:{next(self._eid_counter)}"
        results: Dict[int, Any] = {}
        failures: Dict[int, BaseException] = {}
        stats: Dict[int, Dict[str, int]] = {}
        lock = threading.Lock()

        def call_one(task: int) -> None:
            try:
                local_feeds = {r: v for r, v in feeds.items()
                               if r in self.feed_routing.get(task, ())}
                rep = self.master.channels[task].call(
                    "run_graph", _timeout=timeout + 15.0, handle=self.handle,
                    execution_id=eid, feeds=local_feeds, timeout=timeout)
                with lock:
                    results.update(rep.get("results", {}))
                    stats[task] = {k: rep.get(k, 0) for k in
                                   ("sends", "bytes_sent", "remote_fetches")}
            except BaseException as e:  # noqa: BLE001 — classified below
                with lock:
                    failures[task] = e

        threads = {t: threading.Thread(target=call_one, args=(t,), daemon=True,
                                       name=f"master-run:{t}")
                   for t in self.payloads}
        for t in threads.values():
            t.start()
        deadline = time.monotonic() + timeout + 20.0
        try:
            while any(t.is_alive() for t in threads.values()):
                if self.master.dead:
                    self.master.check()  # raises, naming the lost process/host
                if failures:
                    break
                if time.monotonic() > deadline:
                    stuck = sorted(t for t, th in threads.items() if th.is_alive())
                    raise ExecutorError(
                        f"graph execution {eid} timed out after {timeout:.1f}s:"
                        f" {', '.join(self.master.identity(t) for t in stuck)} "
                        f"never replied (§3.3 failure reporting)")
                time.sleep(0.05)
            if failures:
                task, err = sorted(failures.items())[0]
                ident = self.master.identity(task)
                if isinstance(err, WorkerError):
                    # worker alive; the graph execution itself failed there
                    raise ExecutorError(
                        f"graph execution {eid} failed on {ident}: {err}") from err
                self.master.mark_dead(task, f"{type(err).__name__}: {err}")
                self.master.check()
        finally:
            threading.Thread(target=self._cleanup, args=(eid,),
                             daemon=True).start()

        self.last_run_stats = stats  # per-task wire instrumentation
        missing = [str(self.exe.fetches[i])
                   for i in range(len(self.exe.fetches)) if i not in results]
        if missing:
            raise ExecutorError(
                f"workers finished but fetches {missing} were never produced "
                f"(partition/fetch routing bug; §3.3 failure reporting)")
        return [results[i] for i in range(len(self.exe.fetches))]

    def _cleanup(self, eid: str) -> None:
        for task in self.payloads:
            if task in self.master.dead:
                continue
            try:
                self.master.channels[task].call("cleanup", _timeout=5.0,
                                                execution_id=eid)
            except Exception:  # noqa: BLE001 — best-effort
                pass

    # ------------------------------------------------------------------
    def pull_variables(self) -> Dict[str, Any]:
        """Fetch Variable state back from the pool into the session store
        (§3.3: the master-side CheckpointManager snapshots from here)."""
        self.master.check()
        out: Dict[str, Any] = {}
        by_task: Dict[int, List[str]] = {}
        for name, task in self.var_owner.items():
            by_task.setdefault(task, []).append(name)
        for task, names in sorted(by_task.items()):
            rep = self.master.channels[task].call(
                "get_variables", _timeout=30.0,
                namespace=self.namespace, names=names)
            for name, value in rep["values"].items():
                self.session.variables.write(name, value)
                out[name] = value
        return out
