"""§3.2/§3.3 master: drives a worker pool from the Executable pipeline.

The master is the paper's client+master pair in one process: the
Session's Executable pipeline still runs place → partition → schedule
exactly once per run signature, and when the Session carries a
``cluster=`` spec the resulting per-device subgraphs are *shipped* to
their owning worker processes (``register_graph``) instead of executed
on local threads.  Each ``run`` then fans one ``run_graph`` RPC out per
task under a fresh execution id; workers coordinate tensor transfers
peer-to-peer through the :class:`~repro.distrib.wire.WireRendezvous`,
and the master only collects fetch values.

Fault tolerance (§3.3 / DESIGN.md §13): a heartbeat monitor pings every
worker; on a timeout (or a transport error mid-run) the worker is marked
dead, in-flight executions are purged on the survivors
(``purge_execution``) and abort with an
:class:`~repro.core.executor.ExecutorError` naming the lost process/host
(task, endpoint, pid).  Recovery then prefers **partial re-placement**
(``Session.recover_dead_tasks``): only the dead task's subgraph is
re-registered — onto a standby worker or a survivor — and only its
Variables are pushed from the checkpoint, while survivors keep their
live state, registrations and Executables.  When no standby or survivor
can host (:class:`RecoveryError`), the whole-pool path remains: restart
the pool, ``Session.rebind_cluster``, restore the last checkpoint.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import uuid
import weakref
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from ..core.executor import ExecutorError
from ..core.graph import Graph, TensorRef
from ..obs import metrics as obs_metrics
from ..obs import spans as obs_spans
from .protocol import Channel, WorkerError
from .wire import ClusterSpec


class RecoveryError(ExecutorError):
    """Partial re-placement is impossible (no standby, no survivor able to
    host the dead task) — fall back to the §3.3 whole-pool path: restart
    the pool, ``Session.rebind_cluster``, restore the last checkpoint."""


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What recovery actually did — the §13 operator-facing account.

    ``mode`` is ``"partial"`` (re-placement; survivors kept live state)
    or ``"noop"`` (nothing was dead).  The whole-pool fallback raises
    :class:`RecoveryError` instead of returning a report, so a report in
    hand always means live state was preserved somewhere.
    """

    mode: str
    dead: Dict[int, str]               # task -> why it was condemned
    survivors: Tuple[int, ...]         # tasks whose live state was kept
    replacements: Dict[int, str]       # dead task -> host:port now serving it
    kept_live: Tuple[str, ...]         # Variables preserved on survivors
    restored: Tuple[str, ...]          # Variables restored from checkpoint

    def describe(self) -> str:
        lines = [f"recovery mode={self.mode}"]
        for t, why in sorted(self.dead.items()):
            lines.append(f"  lost   task:{t} ({why})")
        for t, ep in sorted(self.replacements.items()):
            lines.append(f"  placed task:{t} -> {ep}")
        lines.append(f"  survivors: {list(self.survivors)} "
                     f"(kept live: {list(self.kept_live) or 'none'})")
        lines.append(f"  restored from checkpoint: "
                     f"{list(self.restored) or 'none'}")
        return "\n".join(lines)


class Master:
    """Connection + liveness manager for one worker pool."""

    def __init__(self, cluster: "ClusterSpec | str", *,
                 heartbeat_interval: float = 0.5,
                 heartbeat_misses: int = 3,
                 standbys: Iterable[str] = ()) -> None:
        self.cluster = ClusterSpec.parse(cluster)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_misses = heartbeat_misses
        self.generation = 0  # bumped on reset(); plans re-register lazily
        # §13: endpoints of idle standby workers, consumed (FIFO) by
        # partial re-placement before falling back to survivor hosting
        self.standbys: List[str] = list(standbys)
        self.dead: Dict[int, str] = {}
        # weak refs: a plan lives exactly as long as its Executable — the
        # session's LRU eviction must actually free partitioned graphs
        # and shipped-payload copies, not pin them here forever
        self.plans: List["weakref.ref[WirePlan]"] = []
        self._info: Dict[int, Dict[str, Any]] = {}
        self._misses: Dict[int, int] = {}
        # §16.3 per-task clock estimate: task -> (rtt_s, offset_s) for the
        # minimum-RTT heartbeat seen so far.  offset = worker_clock -
        # master_clock; the tighter the RTT, the tighter the midpoint
        # assumption, so we keep the best sample rather than an average.
        self._clock: Dict[int, Tuple[float, float]] = {}
        # §16.2 spans shipped back on run_graph replies, keyed by task,
        # drained by collect_trace_streams() at export time
        self.worker_spans: Dict[int, List[Dict[str, Any]]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self.channels: Dict[int, Channel] = {
            t: Channel(*self.cluster.host_port(t))
            for t in range(len(self.cluster.workers))}

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._hb_thread is None and self.heartbeat_interval > 0:
            self._stop.clear()
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, daemon=True, name="master-hb")
            self._hb_thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._hb_thread = self._hb_thread, None
        if t is not None:
            t.join(timeout=2.0)
        for ch in self.channels.values():
            ch.close()

    def reset(self, cluster: "ClusterSpec | str | None" = None) -> None:
        """§3.3 recovery: point at a restarted pool (same task count).

        Bumps ``generation`` so every WirePlan re-registers on its next
        run.  Registration *seeds* missing worker Variables from the
        session store; live state is never clobbered — recovery pushes
        restored values explicitly (``Session.rebind_cluster`` /
        ``WirePlan.push_variables``)."""
        new = ClusterSpec.parse(cluster) if cluster is not None else self.cluster
        if len(new.workers) != len(self.cluster.workers):
            raise ValueError(
                f"recovery pool has {len(new.workers)} workers, expected "
                f"{len(self.cluster.workers)} (placement is per-task)")
        self.stop()
        self.cluster = new
        self.channels = {t: Channel(*new.host_port(t))
                         for t in range(len(new.workers))}
        self.dead.clear()
        self._info.clear()
        self._misses.clear()
        self.generation += 1
        self.start()

    # ------------------------------------------------------------------
    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            for task, ch in list(self.channels.items()):
                if self._stop.is_set() or task in self.dead:
                    continue
                try:
                    # _attempts=1: this loop IS the retry — the channel's
                    # idempotent-RPC backoff would mask individual probe
                    # failures and make miss counting dishonest
                    t_send = time.time()
                    rep = ch.call("heartbeat", _attempts=1,
                                  _timeout=max(1.0, self.heartbeat_interval * 4))
                    t_recv = time.time()
                    obs_metrics.counter("distrib.heartbeats").inc()
                    with self._lock:
                        self._info[task] = rep
                        self._misses[task] = 0
                        if "clock" in rep:
                            self._note_clock(task, rep["clock"], t_send, t_recv)
                except Exception as e:  # noqa: BLE001 — count, then condemn
                    obs_metrics.counter("distrib.heartbeat_misses").inc()
                    with self._lock:
                        self._misses[task] = self._misses.get(task, 0) + 1
                        if self._misses[task] >= self.heartbeat_misses:
                            if task not in self.dead:
                                obs_metrics.counter(
                                    "distrib.workers_condemned").inc()
                            self.dead.setdefault(
                                task, f"{self._misses[task]} consecutive "
                                      f"heartbeats failed ({type(e).__name__}: {e})")

    def _note_clock(self, task: int, worker_clock: float,
                    t_send: float, t_recv: float) -> None:
        """§16.3 NTP-style offset sample (caller holds ``_lock``): assume
        the worker read its clock at the RPC's midpoint, so ``offset =
        worker_clock - (t_send + t_recv) / 2`` with error bounded by
        RTT/2.  Keep the minimum-RTT sample — a GC pause or a loaded
        accept loop inflates RTT and with it the error bound, so the
        tightest bracket ever seen beats any smoothing of looser ones."""
        rtt = t_recv - t_send
        offset = worker_clock - (t_send + t_recv) / 2.0
        best = self._clock.get(task)
        if best is None or rtt < best[0]:
            self._clock[task] = (rtt, offset)

    def clock_offset(self, task: int) -> float:
        """Estimated ``worker_clock - master_clock`` seconds for ``task``
        (0.0 before any heartbeat completed — merge degrades to trusting
        raw timestamps rather than failing the export)."""
        with self._lock:
            est = self._clock.get(task)
        return est[1] if est else 0.0

    def stash_worker_spans(self, task: int,
                           events: List[Dict[str, Any]]) -> None:
        if events:
            with self._lock:
                self.worker_spans.setdefault(task, []).extend(events)

    def collect_trace_streams(self) -> List[Dict[str, Any]]:
        """§16.2 gather every worker's spans into export-ready streams:
        the run_graph-shipped buffers stashed here, plus a best-effort
        ``collect_trace`` drain of each live worker's process-level
        buffer (server-side RPC spans).  Dead workers contribute whatever
        their replies shipped before they died."""
        with self._lock:
            stashed = {t: evs for t, evs in self.worker_spans.items()}
            self.worker_spans = {}
        for task in range(len(self.cluster.workers)):
            if task in self.dead:
                continue
            try:
                rep = self.channels[task].call("collect_trace", _timeout=10.0)
                evs = rep.get("events") or []
                if evs:
                    stashed.setdefault(task, []).extend(evs)
            except Exception:  # noqa: BLE001 — diagnostics must not kill export
                pass
        return [{"process": f"worker-task{task}",
                 "offset_s": self.clock_offset(task),
                 "events": events}
                for task, events in sorted(stashed.items()) if events]

    def live_plans(self) -> List["WirePlan"]:
        out, refs = [], []
        for r in self.plans:
            plan = r()
            if plan is not None:
                out.append(plan)
                refs.append(r)
        self.plans = refs  # prune dead refs as a side effect
        return out

    def identity(self, task: int) -> str:
        """Human-readable process identity for §3.3 failure reports."""
        host, port = self.cluster.host_port(task)
        pid = self._info.get(task, {}).get("pid")
        pid_s = f", pid {pid}" if pid is not None else ""
        return f"worker task:{task} ({host}:{port}{pid_s})"

    def mark_dead(self, task: int, reason: str) -> None:
        self.dead.setdefault(task, reason)

    def add_standby(self, endpoint: str) -> None:
        """Offer an idle worker's ``host:port`` for future re-placement."""
        host, _, port = str(endpoint).rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad standby endpoint {endpoint!r}")
        if endpoint not in self.standbys:
            self.standbys.append(endpoint)

    def replace_task(self, task: int, endpoint: str) -> None:
        """§13 partial re-placement, connection half: ``task`` is now
        served from ``endpoint`` (a standby or a survivor's process).

        Deliberately does NOT bump ``generation`` — survivors' existing
        registrations stay valid; the caller re-registers only the
        replaced task (``WirePlan.reregister_task``) and patches
        survivors' specs (``WirePlan.update_survivors``)."""
        obs_metrics.counter("distrib.tasks_replaced").inc()
        old = self.channels.pop(task, None)
        if old is not None:
            old.close()
        self.cluster = self.cluster.with_replacement(task, endpoint)
        self.channels[task] = Channel(*self.cluster.host_port(task))
        with self._lock:
            self.dead.pop(task, None)
            self._misses.pop(task, None)
            self._info.pop(task, None)

    def check(self) -> None:
        if self.dead:
            lost = "; ".join(f"{self.identity(t)}: {r}"
                             for t, r in sorted(self.dead.items()))
            raise ExecutorError(
                f"§3.3: lost {lost} — in-flight executions aborted; recover "
                f"via partial re-placement (Session.recover_dead_tasks: "
                f"survivors keep live state) or restart the pool, rebind "
                f"the session (Session.rebind_cluster) and resume from the "
                f"last checkpoint")


class WirePlan:
    """Distributed run state of one Executable: per-task payloads + RPCs.

    Built once per run signature from the Executable's partitioned graph;
    registration with the workers is lazy and generation-aware, so a
    restarted pool transparently re-receives the subgraphs and the
    session's current Variable values on the next run.
    """

    def __init__(self, exe: Any, device_nodes: Dict[str, set], *,
                 numerics: Optional[str] = None,
                 backend: Optional[str] = None) -> None:
        session = exe.session
        self.exe = exe
        self.session = session
        self.master: Master = session.master
        # numerics/backend overrides: the §13 distributed parity guard
        # builds a companion plan with numerics="strict", backend="generic"
        # as its reference pipeline (strict fused == unfused bit-for-bit,
        # §7/§9; the generic backend is the kernel oracle, §12)
        self.numerics = numerics if numerics is not None else exe.numerics
        self.backend = (backend if backend is not None
                        else getattr(exe, "kernel_backend", "generic"))
        self.handle = uuid.uuid4().hex[:12]
        self._eid_prefix = uuid.uuid4().hex[:8]
        self._eid_counter = itertools.count()
        self._registered_gen: Optional[int] = None
        self._reg_lock = threading.Lock()

        parted = exe.partitioned
        graph: Graph = parted.graph
        cluster: ClusterSpec = session.cluster
        n_tasks = len(cluster.workers)

        # unshippable-graph check up front, with a better error than a
        # deep pickle traceback: a Call kernel must either pickle by
        # reference (module-level functions, autodiff's _GradFn) or be a
        # factory-form Call whose attrs carry an importable
        # ``module:qualname`` + picklable static args (DESIGN.md §15) —
        # closures cannot cross a process boundary
        from .protocol import pack_msg

        for name, node in graph.nodes.items():
            if node.op == "Call":
                try:
                    pack_msg({"attrs": node.attrs})
                except Exception as e:  # noqa: BLE001 — rewrap with the node name
                    raise ExecutorError(
                        f"Call node {name!r} holds a Python closure (or "
                        f"unpicklable static args) that cannot ship to a "
                        f"worker process ({e}); distributed graphs must use "
                        f"registered primitive ops, importable callables, or "
                        f"wire-shippable Call factories "
                        f"(GraphBuilder.call_factory, DESIGN.md §15)") from e

        # §14 pre-ship verification: each per-task slice must be
        # self-contained (P601) and the global Send/Recv pairing live —
        # shipping a slice that hangs wastes the whole pool, so the
        # check runs before any payload leaves the master
        from ..analysis import verifier as verifier_mod

        self.verify_report = verifier_mod.verify_wire_plan(exe, device_nodes)

        task_devices: Dict[int, List[str]] = {}
        for dev in device_nodes:
            task_devices.setdefault(cluster.task_of_device(dev), []).append(dev)

        # Variable state: force-init through the session store so every
        # worker receives concrete values; the shipped subgraph carries
        # init=None (workers never run initializers).
        self.var_owner: Dict[str, int] = {}
        self._var_containers: Dict[str, str] = {}
        # each session gets its own VariableStore on every worker (§4.7:
        # in-process sessions default to one ContainerManager each; two
        # sessions sharing a pool must not share state through colliding
        # Variable names)
        self.namespace = getattr(session, "wire_namespace", "s")
        for name, node in graph.nodes.items():
            if node.op != "Variable":
                continue
            session._ctx().read_variable(session.graph.nodes.get(name, node))
            self.var_owner[name] = cluster.task_of_device(parted.placement[name])
            self._var_containers[name] = node.attrs.get("container", "")

        self.payloads: Dict[int, Dict[str, Any]] = {}
        self.feed_routing: Dict[int, set] = {}  # task -> feed keys it consumes
        for task in range(n_tasks):
            devs = task_devices.get(task, [])
            local_names = set().union(*(device_nodes[d] for d in devs)) if devs else set()
            sub = graph.subgraph(local_names)
            # a fed tensor is read at input-gather time by every LOCAL
            # consumer of the fed edge (§4.2 feed semantics), so ship each
            # feed only to tasks that consume it (plus fully-fed fetches
            # routed to this task's devices)
            needed = {r for name in local_names
                      for r in graph.nodes[name].inputs if r in exe.feed_keys}
            for dev in devs:
                needed |= {exe.fetches[i] for i in exe.fetch_by_dev.get(dev, [])
                           if exe.fetches[i] in exe.feed_keys}
            self.feed_routing[task] = needed
            for name in sub.nodes:
                if sub.nodes[name].op == "Variable":
                    # workers never run initializers — state is seeded /
                    # pushed as concrete values
                    sub.nodes[name].attrs["init"] = None
            fetches: Dict[str, List[Tuple[int, str, int]]] = {}
            for dev in devs:
                idxs = exe.fetch_by_dev.get(dev, [])
                if idxs:
                    fetches[dev] = [(i, exe.fetches[i].node, exe.fetches[i].port)
                                    for i in idxs]
            self.payloads[task] = {
                "handle": self.handle,
                "namespace": self.namespace,
                "task": task,
                "graph": sub,
                "device_nodes": {d: sorted(device_nodes[d]) for d in devs},
                "placement": {n: parted.placement[n] for n in local_names},
                "fetches": fetches,
                "feed_keys": [(r.node, r.port) for r in exe.feed_keys],
                "fuse": exe.fuse_regions,
                "numerics": self.numerics,
                # §12/§15: the session's kernel-backend choice rides the
                # payload so the worker's re-fuse dispatches the same
                # kernels the master would have in-process
                "backend": self.backend,
            }
        self.master.plans.append(weakref.ref(self))

    # ------------------------------------------------------------------
    def _variable_payload(self, task: int) -> Dict[str, Tuple[str, Any]]:
        """Current session-store values of the Variables this task owns —
        read at registration time so recovery ships restored state."""
        g = self.session.graph
        out: Dict[str, Tuple[str, Any]] = {}
        for name, owner in self.var_owner.items():
            if owner != task:
                continue
            node = g.nodes[name]
            value = self.session.variables.read(name, node.attrs)
            out[name] = (self._var_containers[name], value)
        return out

    def _register_task(self, task: int) -> None:
        try:
            self.master.channels[task].call(
                "register_graph", _timeout=60.0,
                cluster=self.master.cluster.to_wire(),
                variables=self._variable_payload(task),
                **self.payloads[task])
        except WorkerError:
            raise
        except Exception as e:  # noqa: BLE001 — transport = lost worker
            self.master.mark_dead(task, f"register_graph failed: {e}")
            self.master.check()
            raise

    def ensure_registered(self) -> None:
        self.master.check()
        with self._reg_lock:
            if self._registered_gen == self.master.generation:
                return
            for task in self.payloads:
                self._register_task(task)
            self._registered_gen = self.master.generation

    # ------------------------------------------------------------------
    # §13 partial re-placement: patch one task, leave survivors alone
    def reregister_task(self, task: int) -> None:
        """Ship ONLY ``task``'s subgraph slice to its (replacement)
        endpoint — survivors keep their registrations, executors and live
        Variable state.  No-op for a plan that never registered: lazy
        registration will ship everything against the patched cluster."""
        with self._reg_lock:
            if self._registered_gen is None:
                return
            self._register_task(task)

    def update_survivors(self, replaced: "Set[int]") -> None:
        """Patch survivors' registered cluster specs to the
        post-replacement topology, so their future peer fetches dial the
        replacement endpoint instead of the dead one."""
        with self._reg_lock:
            if self._registered_gen is None:
                return
            cluster_wire = self.master.cluster.to_wire()
            for task in self.payloads:
                if task in replaced or task in self.master.dead:
                    continue
                self.master.channels[task].call(
                    "update_cluster", _timeout=30.0, cluster=cluster_wire,
                    handles=[self.handle])

    # ------------------------------------------------------------------
    def push_variables(self, tasks: Optional[Set[int]] = None) -> None:
        """Force-write the session store's values for this plan's
        Variables into their owning workers (§3.3 recovery: registration
        itself only *seeds* missing state, never clobbers live weights).
        ``tasks`` limits the push — partial recovery pushes ONLY the
        replaced task's Variables, preserving survivors' live state."""
        for task in sorted(set(self.var_owner.values())):
            if tasks is not None and task not in tasks:
                continue
            values = self._variable_payload(task)
            if values:
                self.master.channels[task].call(
                    "set_variables", _timeout=30.0,
                    namespace=self.namespace, values=values)

    def snapshot_variables(self, names: Optional[Iterable[str]] = None
                           ) -> Dict[str, Any]:
        """Read this plan's Variables from their owning workers WITHOUT
        touching the session store — the §13 distributed parity guard's
        snapshot (and the tests' bit-preservation probe)."""
        self.master.check()
        wanted = set(self.var_owner if names is None else names)
        by_task: Dict[int, List[str]] = {}
        for name, task in self.var_owner.items():
            if name in wanted:
                by_task.setdefault(task, []).append(name)
        out: Dict[str, Any] = {}
        for task, ns in sorted(by_task.items()):
            rep = self.master.channels[task].call(
                "get_variables", _timeout=30.0,
                namespace=self.namespace, names=ns)
            out.update(rep["values"])
        return out

    def restore_variables(self, values: Dict[str, Any]) -> None:
        """Force-write ``values`` back to their owning workers (inverse
        of :meth:`snapshot_variables`; bypasses the session store)."""
        by_task: Dict[int, Dict[str, Tuple[str, Any]]] = {}
        for name, value in values.items():
            by_task.setdefault(self.var_owner[name], {})[name] = (
                self._var_containers[name], value)
        for task, vals in sorted(by_task.items()):
            self.master.channels[task].call(
                "set_variables", _timeout=30.0,
                namespace=self.namespace, values=vals)

    def run(self, feeds: Dict[TensorRef, Any], *, timeout: float = 60.0,
            spans: Any = None) -> List[Any]:
        try:
            return self._run_once(feeds, timeout=timeout, spans=spans)
        except ExecutorError as e:
            # a worker's bounded graph registry may have evicted (or a
            # worker restarted under an unchanged endpoint): one
            # transparent re-registration retry
            if "is not registered here" not in str(e) or self.master.dead:
                raise
            with self._reg_lock:
                self._registered_gen = None
            return self._run_once(feeds, timeout=timeout, spans=spans)

    def _run_once(self, feeds: Dict[TensorRef, Any], *,
                  timeout: float = 60.0, spans: Any = None) -> List[Any]:
        self.ensure_registered()
        eid = f"{self._eid_prefix}:{next(self._eid_counter)}"
        # §16.2: tracing rides the run_graph payload ("trace": True) so
        # workers attach a per-execution recorder and ship its spans back
        # on the reply; the master-side step span brackets the whole
        # scatter/gather from this process's point of view
        trace = spans is not None
        t_step = time.time() if trace else 0.0
        results: Dict[int, Any] = {}
        failures: Dict[int, BaseException] = {}
        stats: Dict[int, Dict[str, int]] = {}
        lock = threading.Lock()
        done = threading.Event()  # set when all tasks replied (or one failed)
        pending = [len(self.payloads)]

        def call_one(task: int) -> None:
            try:
                local_feeds = {r: v for r, v in feeds.items()
                               if r in self.feed_routing.get(task, ())}
                rep = self.master.channels[task].call(
                    "run_graph", _timeout=timeout + 15.0, handle=self.handle,
                    task=task, execution_id=eid, feeds=local_feeds,
                    timeout=timeout, trace=trace)
                if trace:
                    self.master.stash_worker_spans(task, rep.get("spans") or [])
                with lock:
                    results.update(rep.get("results", {}))
                    stats[task] = {k: rep.get(k, 0) for k in
                                   ("sends", "bytes_sent", "remote_fetches")}
                    stats[task]["timings"] = rep.get("timings", {})
            except BaseException as e:  # noqa: BLE001 — classified below
                with lock:
                    failures[task] = e
                done.set()  # fail fast: wake the waiter before the tick
            finally:
                with lock:
                    pending[0] -= 1
                    if pending[0] == 0:
                        done.set()

        threads = {t: threading.Thread(target=call_one, args=(t,), daemon=True,
                                       name=f"master-run:{t}")
                   for t in self.payloads}
        for t in threads.values():
            t.start()
        deadline = time.monotonic() + timeout + 20.0
        try:
            while True:
                # event-driven completion (a polling sleep here puts a
                # floor under every step's latency); the 50ms timeout is
                # only the re-check cadence for dead workers
                with lock:
                    n_pending = pending[0]
                if n_pending == 0 or self.master.dead or failures:
                    break
                if time.monotonic() > deadline:
                    stuck = sorted(t for t, th in threads.items() if th.is_alive())
                    raise ExecutorError(
                        f"graph execution {eid} timed out after {timeout:.1f}s:"
                        f" {', '.join(self.master.identity(t) for t in stuck)} "
                        f"never replied (§3.3 failure reporting)")
                done.wait(0.05)
            if failures:
                task, err = sorted(failures.items())[0]
                ident = self.master.identity(task)
                if isinstance(err, WorkerError):
                    # worker alive; the graph execution itself failed
                    # there — still purge peers, whose executors may be
                    # blocked on tensors that will now never arrive
                    self.abort_execution(
                        eid, f"execution {eid} failed on {ident}")
                    raise ExecutorError(
                        f"graph execution {eid} failed on {ident}: {err}") from err
                self.master.mark_dead(task, f"{type(err).__name__}: {err}")
            if self.master.dead:
                # §13 detection -> abort: scrub this execution off every
                # SURVIVOR before condemning — their executors unwind now
                # (not after a full recv timeout) and their mailboxes hold
                # no orphaned tensors for the worker's lifetime
                lost = ", ".join(self.master.identity(t)
                                 for t in sorted(self.master.dead))
                self.abort_execution(eid, f"execution {eid} aborted: "
                                          f"lost {lost} (§3.3)")
                self.master.check()  # raises, naming the lost process/host
        finally:
            threading.Thread(target=self._cleanup, args=(eid,),
                             daemon=True).start()

        self.last_run_stats = stats  # per-task wire instrumentation
        if trace:
            spans.record(f"step:{eid}", obs_spans.CAT_STEP, "master",
                         t_step, time.time(),
                         args={"tasks": len(self.payloads)})
        missing = [str(self.exe.fetches[i])
                   for i in range(len(self.exe.fetches)) if i not in results]
        if missing:
            raise ExecutorError(
                f"workers finished but fetches {missing} were never produced "
                f"(partition/fetch routing bug; §3.3 failure reporting)")
        return [results[i] for i in range(len(self.exe.fetches))]

    def abort_execution(self, eid: str, reason: str) -> None:
        """§13 abort half of detection→abort→re-place→resume: purge one
        in-flight execution on every surviving worker (poison its
        rendezvous views, drop straggler fetchers, scrub the mailbox)."""
        for task in self.payloads:
            if task in self.master.dead:
                continue
            try:
                self.master.channels[task].call(
                    "purge_execution", _timeout=10.0, execution_id=eid,
                    reason=reason)
            except Exception:  # noqa: BLE001 — best-effort on a failing pool
                pass

    def _cleanup(self, eid: str) -> None:
        for task in self.payloads:
            if task in self.master.dead:
                continue
            try:
                self.master.channels[task].call("cleanup", _timeout=5.0,
                                                execution_id=eid)
            except Exception:  # noqa: BLE001 — best-effort
                pass

    # ------------------------------------------------------------------
    def pull_variables(self) -> Dict[str, Any]:
        """Fetch Variable state back from the pool into the session store
        (§3.3: the master-side CheckpointManager snapshots from here)."""
        self.master.check()
        out: Dict[str, Any] = {}
        by_task: Dict[int, List[str]] = {}
        for name, task in self.var_owner.items():
            by_task.setdefault(task, []).append(name)
        for task, names in sorted(by_task.items()):
            rep = self.master.channels[task].call(
                "get_variables", _timeout=30.0,
                namespace=self.namespace, names=names)
            for name, value in rep["values"].items():
                self.session.variables.write(name, value)
                out[name] = value
        return out
