"""§3.3 worker process: serves per-device subgraphs over the wire protocol.

A Worker owns the runtime state of its slice of the cluster — a
process-wide rendezvous mailbox, a VariableStore, queues and checkpoint
IO — and serves the DESIGN.md §11 RPCs:

* ``register_graph`` — receive a partitioned per-task subgraph from the
  master, seed Variable state, optionally run §7 region fusion on each
  local device subgraph (strict fusion is bit-identical, so wire runs
  keep the compiled-super-node speedups), and build one reusable
  :class:`~repro.core.executor.Executor` per local device.
* ``run_graph`` — execute one registered graph under an execution id:
  one thread per local device, all coordinating through a
  :class:`~repro.distrib.wire.WireRendezvous` view of the mailbox.
* ``recv_tensor`` — the pull half of a cross-process Send/Recv pair:
  block until the local mailbox holds the (execution-namespaced) key,
  pop it and reply.  DEAD_TENSOR replies carry §4.4 deadness across the
  process boundary.
* ``heartbeat`` / ``get_variables`` / ``set_variables`` / ``cleanup`` /
  ``shutdown`` — liveness, checkpoint sync and lifecycle.

CLI (one process per task)::

    python -m repro.distrib.worker --host 127.0.0.1 --port 7077 --task 0

``--port 0`` picks a free port; the worker announces
``WORKER_READY host:port task=N pid=P`` on stdout either way, which is
what :func:`start_worker_processes` parses.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import select
import socket
import subprocess
import sys
import threading
import time
import traceback
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.executor import ExecutionContext, Executor
from ..core.graph import Graph, TensorRef
from ..core import fusion as fusion_mod
from ..core import kernel_registry
from ..core import ops as ops_mod
from ..obs import metrics as obs_metrics
from ..obs import spans as obs_spans
from ..runtime.containers import ContainerManager, VariableStore
from ..runtime.rendezvous import Rendezvous
from . import faults
from .protocol import Channel, recv_msg, send_msg
from .wire import ClusterSpec, WireRendezvous

# RPCs excluded from server-side span recording even when tracing: the
# heartbeat fires continuously and the trace/metrics scrapes would trace
# themselves.
_UNTRACED_RPCS = frozenset({"heartbeat", "collect_trace", "metrics_snapshot"})


@dataclasses.dataclass
class _Registered:
    """One graph the master registered with this worker."""

    graph: Graph
    executors: Dict[str, Executor]                 # local device -> Executor
    fetch_specs: Dict[str, List[Tuple[int, TensorRef]]]  # dev -> (global idx, ref)
    fetch_remap: Dict[TensorRef, TensorRef]
    cluster: ClusterSpec
    task: int
    namespace: str  # owning session's store namespace (§4.7)


class Worker:
    """One OS process serving one cluster task's devices (DESIGN.md §11)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, task: int = 0, *,
                 rendezvous_timeout: float = 30.0,
                 checkpoint_root: Optional[str] = None) -> None:
        self.host, self.port, self.task = host, port, task
        self.mailbox = Rendezvous(timeout=rendezvous_timeout)
        # one VariableStore per *session* namespace, mirroring the
        # in-process default of one ContainerManager per Session (§4.7):
        # sessions sharing this pool never alias each other's Variables
        # (VariableStore.write resolves names across its containers)
        self._stores: Dict[str, VariableStore] = {}
        self._var_containers: Dict[str, Dict[str, str]] = {}
        self.queues: Dict[str, Any] = {}
        if checkpoint_root:
            from ..checkpoint import FileCheckpointIO

            self.checkpoint_io: Any = FileCheckpointIO(checkpoint_root)
        else:
            from ..core.session import _DictCheckpointIO

            self.checkpoint_io = _DictCheckpointIO()
        # keyed by (handle, cluster task): §13 partial re-placement may
        # land a dead task's subgraph on a SURVIVOR, which then serves two
        # tasks of the same plan — one registry slot each, never an
        # overwrite
        self._graphs: "OrderedDict[Tuple[str, int], _Registered]" = OrderedDict()
        self.max_graphs = 32  # LRU bound on registered graphs
        # eid -> rendezvous views; a dual-task survivor runs two per eid
        self._active: Dict[str, List[WireRendezvous]] = {}
        # keyed by ENDPOINT, not task id: after a partial pool restart
        # (dead task re-spawned on a new port) the re-registered cluster
        # spec must dial the new endpoint, never a stale cached channel
        self._peers: Dict[Tuple[str, int], Channel] = {}
        self._peers_lock = threading.Lock()
        self._stop = threading.Event()
        self._sock: Optional[socket.socket] = None
        self._started = time.monotonic()
        # §16 distributed EEG: the process-level span buffer (server-side
        # RPC spans + any events not yet shipped on a run_graph reply),
        # drained by the collect_trace RPC.  Recording stays off until the
        # first traced run_graph arrives — the flag makes every
        # instrumentation site a single bool check when the master never
        # asked for tracing.
        self.spans = obs_spans.SpanRecorder(process=f"worker-task{task}")
        self._trace = False

    # ------------------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, self.port))
        sock.listen(64)
        self.port = sock.getsockname()[1]
        self._sock = sock
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"worker{self.task}-accept").start()
        return self.host, self.port

    def stop(self) -> None:
        self._stop.set()
        self.mailbox.abort(RuntimeError(
            f"worker task:{self.task} (pid {os.getpid()}) shut down"))
        for views in list(self._active.values()):
            for rdv in views:
                rdv.abort(RuntimeError(f"worker task:{self.task} shutting down"))
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        with self._peers_lock:
            for ch in self._peers.values():
                ch.close()
            self._peers.clear()

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    # ------------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True,
                             name=f"worker{self.task}-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                msg = recv_msg(conn)
                if msg is None:
                    return
                kind = msg.pop("kind", "?")
                try:
                    # §13 fault injection: a stall_hb rule drops this
                    # connection without replying — the master's monitor
                    # counts a miss against a perfectly healthy process
                    faults.on_serve(kind, self.task)
                except faults._DropConnection:
                    return
                handler = getattr(self, f"_rpc_{kind}", None)
                if handler is None:
                    reply: Dict[str, Any] = {"ok": False,
                                             "error": f"unknown RPC {kind!r}"}
                else:
                    t_rpc = (time.time()
                             if self._trace and kind not in _UNTRACED_RPCS
                             else None)
                    try:
                        reply = handler(msg)
                        reply.setdefault("ok", True)
                    except Exception as e:  # noqa: BLE001 — report, don't die
                        reply = {"ok": False,
                                 "error": f"worker task:{self.task} "
                                          f"(pid {os.getpid()}) {kind} failed: "
                                          f"{type(e).__name__}: {e}\n"
                                          f"{traceback.format_exc(limit=8)}"}
                    if t_rpc is not None:
                        # §16 server-side RPC span, paired with the client
                        # span the caller's Channel recorded
                        self.spans.record(kind, obs_spans.CAT_RPC_SERVER,
                                          f"task:{self.task}", t_rpc,
                                          time.time(),
                                          args={"kind": kind,
                                                "ok": bool(reply.get("ok"))})
                send_msg(conn, reply)
                if kind == "shutdown":
                    self.stop()
                    return
        except Exception:  # noqa: BLE001 — connection-level failure
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def store(self, namespace: str) -> VariableStore:
        st = self._stores.get(namespace)
        if st is None:
            st = self._stores[namespace] = VariableStore(ContainerManager())
            self._var_containers[namespace] = {}
        return st

    def _peer_channel(self, cluster: ClusterSpec, task: int) -> Channel:
        endpoint = cluster.host_port(task)
        with self._peers_lock:
            ch = self._peers.get(endpoint)
            if ch is None:
                ch = Channel(*endpoint)
                self._peers[endpoint] = ch
            return ch

    # ------------------------------------------------------------------
    # RPC handlers
    def _rpc_register_graph(self, p: Dict[str, Any]) -> Dict[str, Any]:
        cluster = ClusterSpec.from_wire(p["cluster"])
        g: Graph = p["graph"]
        device_nodes = {d: set(ns) for d, ns in p["device_nodes"].items()}
        names = set().union(*device_nodes.values()) if device_nodes else set()
        placement = dict(p["placement"])
        feed_keys = frozenset(TensorRef(n, pt) for n, pt in p["feed_keys"])
        fetch_specs = {d: [(i, TensorRef(n, pt)) for i, n, pt in lst]
                       for d, lst in p["fetches"].items()}
        ns = p.get("namespace", "s")
        store = self.store(ns)
        for vname, (container, value) in p["variables"].items():
            cont = store.manager.get(container)
            if not cont.has(vname):
                # SEED-only: registration must never clobber live state —
                # a second Executable on the same session registers here
                # mid-training, when this store (not the master's) holds
                # the trained weights.  Recovery pushes explicitly via
                # set_variables (Session.rebind_cluster).
                cont.write(vname, value)
            self._var_containers[ns][vname] = container

        # §15 factory-form Calls rebuild *at registration*, not first run:
        # an unimportable factory (missing module, bad qualname) surfaces
        # as a register_graph error naming the node, and the built kernel
        # is memoised per (factory, args) so N replicas of one step share
        # a single model build in this process
        for name in sorted(names):
            node = g.nodes[name]
            if node.op == "Call" and "call_factory" in node.attrs:
                try:
                    ops_mod.resolve_call_fn(node)
                except Exception as e:  # noqa: BLE001 — rewrap with the node
                    raise RuntimeError(
                        f"Call node {name!r}: factory "
                        f"{node.attrs['call_factory']!r} failed to build on "
                        f"worker task:{self.task}: {e}") from e

        fetch_remap: Dict[TensorRef, TensorRef] = {}
        if p.get("fuse", True) and names:
            # §7 region fusion on the local slice: placement keeps regions
            # per-device, Send/Recv nodes are runtime ops and never join a
            # region, so the fused graph is safe to interleave with wire
            # transfers.  Strict numerics stays bit-identical (§9); the
            # master's kernel-backend choice rides the payload (§12/§15)
            # so wire runs dispatch e.g. Pallas kernels too.
            all_fetch_refs = [r for lst in fetch_specs.values() for _, r in lst]
            fus = fusion_mod.try_fuse(
                g, set(names), placement=placement, feeds=feed_keys,
                fetch_refs=all_fetch_refs,
                written_vars=fusion_mod.written_variables(g, names),
                numerics=p.get("numerics", "strict"),
                backend=p.get("backend", "generic"))
            if fus is not None and (fus.regions or fus.changed):
                g = fus.graph
                fetch_remap = fus.fetch_map
                device_nodes = {}
                for n in fus.names:
                    device_nodes.setdefault(fus.placement[n], set()).add(n)
        executors = {dev: Executor(g, node_filter=ns, device_label=dev)
                     for dev, ns in device_nodes.items()}
        key = (p["handle"], p["task"])
        self._graphs[key] = _Registered(
            graph=g, executors=executors, fetch_specs=fetch_specs,
            fetch_remap=fetch_remap, cluster=cluster, task=p["task"],
            namespace=ns)
        self._graphs.move_to_end(key)
        while len(self._graphs) > self.max_graphs:
            # bounded registry: masters whose signature churn outlives
            # this cap get a "not registered" reply and transparently
            # re-register (master.WirePlan.run)
            self._graphs.popitem(last=False)
        return {"devices": sorted(executors), "n_nodes": len(g.nodes)}

    def _find_registered(self, handle: str,
                         task: Optional[int]) -> Tuple[Any, _Registered]:
        if task is not None:
            key = (handle, task)
            reg = self._graphs.get(key)
        else:  # legacy master without task routing: any slot for the handle
            key = next((k for k in self._graphs if k[0] == handle), None)
            reg = self._graphs.get(key) if key is not None else None
        if reg is None:
            raise KeyError(f"graph {handle!r} (task {task}) is not registered "
                           f"here (worker restarted or registry evicted? "
                           f"re-register before running)")
        return key, reg

    def _rpc_run_graph(self, p: Dict[str, Any]) -> Dict[str, Any]:
        # §13 fault injection FIRST: a kill rule must fire on *receipt* of
        # the N-th run_graph, before any execution state exists — the
        # deterministic twin of `kill -9` mid-step
        faults.on_run_graph(self.task)
        key, reg = self._find_registered(p["handle"], p.get("task"))
        self._graphs.move_to_end(key)
        eid: str = p["execution_id"]
        timeout: float = float(p.get("timeout", 60.0))
        feeds: Dict[TensorRef, Any] = p.get("feeds") or {}
        # §16: the master flags traced executions; one recorder per
        # execution keeps concurrent run_graphs from draining each other,
        # and the flag arms server-side RPC spans for the process
        run_spans: Optional[obs_spans.SpanRecorder] = None
        if p.get("trace"):
            self._trace = True
            run_spans = obs_spans.SpanRecorder(
                process=f"worker-task{self.task}")
        wire = WireRendezvous(
            self.mailbox, reg.cluster, reg.task, eid, timeout=timeout,
            channel_of=lambda t: self._peer_channel(reg.cluster, t))
        self._active.setdefault(eid, []).append(wire)
        results: Dict[int, Any] = {}
        errors: List[BaseException] = []
        lock = threading.Lock()

        store = self.store(reg.namespace)

        timings: Dict[str, Dict[str, float]] = {}

        def run_device(dev: str, ex: Executor) -> None:
            # §16.4 last-progress gauge: hang reports below read this to
            # say how long each stuck device has been silent
            progress = obs_metrics.gauge(f"worker.device.{dev}.last_progress_ts")
            progress.set(time.time())
            ctx = ExecutionContext(
                variables=store, rendezvous=wire, queues=self.queues,
                checkpoint_io=self.checkpoint_io,
                device_kind=dev.split("device:")[-1].split(":")[0])
            specs = reg.fetch_specs.get(dev, [])
            local = [reg.fetch_remap.get(r, r) for _, r in specs]
            t_wall, t_cpu = time.monotonic(), time.thread_time()
            try:
                vals = ex.run(local, feeds, ctx=ctx, spans=run_spans)
                with lock:
                    for (i, _), v in zip(specs, vals):
                        results[i] = v
            except BaseException as e:  # noqa: BLE001 — §3.3 surface any failure
                with lock:
                    errors.append(e)
            finally:
                # wall vs thread-CPU split: the gap is time this device
                # spent blocked (Recv waits, scheduler) — §3.3 diagnostics
                # surfaced through run_graph replies into last_run_stats
                # AND the §16.4 metrics registry (worker.device_*)
                wall = time.monotonic() - t_wall
                cpu = time.thread_time() - t_cpu
                obs_metrics.histogram("worker.device_wall_s").observe(wall)
                obs_metrics.histogram("worker.device_cpu_s").observe(cpu)
                progress.set(time.time())
                with lock:
                    timings[dev] = {"wall_s": wall, "cpu_s": cpu}

        threads = {dev: threading.Thread(target=run_device, args=(dev, ex),
                                         daemon=True,
                                         name=f"worker{reg.task}:{dev}")
                   for dev, ex in reg.executors.items()}
        try:
            for t in threads.values():
                t.start()
            deadline = time.monotonic() + timeout
            for t in threads.values():
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            if errors:
                raise errors[0]
            stuck = sorted(dev for dev, t in threads.items() if t.is_alive())
            if stuck:
                wire.abort(RuntimeError(f"execution {eid} timed out"))
                now = time.time()

                def _age(dev: str) -> str:
                    ts = obs_metrics.gauge(
                        f"worker.device.{dev}.last_progress_ts").value
                    return f"{now - ts:.1f}s ago" if ts else "never"

                raise TimeoutError(
                    f"worker task:{reg.task} (pid {os.getpid()}): device(s) "
                    + ", ".join(f"{d} (last progress {_age(d)})"
                                for d in stuck)
                    + f" never finished within {timeout:.1f}s (stuck "
                    f"Send/Recv or hung kernel; §3.3 failure reporting)")
            out = {"results": results,
                   "sends": wire.sends, "bytes_sent": wire.bytes_sent,
                   "remote_fetches": wire.remote_fetches,
                   "timings": timings}
            if run_spans is not None:
                # ship this execution's spans on the reply; the clock
                # sample lets the master sanity-check its offset estimate
                out["spans"] = run_spans.drain()
                out["clock"] = time.time()
            return out
        finally:
            # stop straggler fetcher threads (blocked in recv_tensor RPCs
            # for up to their timeout) from depositing into the mailbox
            # after the master's cleanup purge has run — a late deposit
            # would leak for the worker's lifetime
            wire.close()
            views = self._active.get(eid)
            if views is not None:
                try:
                    views.remove(wire)
                except ValueError:
                    pass
                if not views:
                    self._active.pop(eid, None)

    def _rpc_recv_tensor(self, p: Dict[str, Any]) -> Dict[str, Any]:
        wait = float(p.get("wait", self.mailbox.timeout))
        try:
            value = self.mailbox.recv(p["key"], timeout=wait)
        except TimeoutError:
            if p.get("poll"):
                # chunked fetcher (wire.WireRendezvous._fetch): a clean
                # not-yet marker, so the client re-polls between its
                # closed/abort checks instead of burning one long blocking
                # RPC it cannot interrupt
                return {"timeout": True}
            raise
        return {"value": value}

    def _rpc_heartbeat(self, p: Dict[str, Any]) -> Dict[str, Any]:
        # "clock" piggybacks NTP-style offset estimation on the liveness
        # probe (§16.3): the master brackets the call with its own send /
        # receive times and assumes this sample was taken at the midpoint
        return {"task": self.task, "pid": os.getpid(),
                "active": len(self._active),
                "uptime_s": time.monotonic() - self._started,
                "registered": len(self._graphs),
                "clock": time.time()}

    def _rpc_collect_trace(self, p: Dict[str, Any]) -> Dict[str, Any]:
        """§16.2 drain the process-level span buffer (server-side RPC
        spans; run_graph spans ship on their own replies).  Draining is
        destructive, so a retried call can lose the events the first
        attempt drained — acceptable for diagnostics, and why this RPC
        is marked idempotent rather than given dedup bookkeeping."""
        return {"events": self.spans.drain(), "clock": time.time(),
                "task": self.task}

    def _rpc_metrics_snapshot(self, p: Dict[str, Any]) -> Dict[str, Any]:
        """§16.4 read-only dump of this process's metrics registry."""
        return {"metrics": obs_metrics.snapshot(), "task": self.task,
                "pid": os.getpid()}

    def _rpc_get_variables(self, p: Dict[str, Any]) -> Dict[str, Any]:
        ns = p.get("namespace", "s")
        store = self.store(ns)
        names = p.get("names")
        out: Dict[str, Any] = {}
        for vname, container in self._var_containers.get(ns, {}).items():
            if names is not None and vname not in names:
                continue
            cont = store.manager.get(container)
            if cont.has(vname):
                out[vname] = cont.read(vname)
        return {"values": out}

    def _rpc_set_variables(self, p: Dict[str, Any]) -> Dict[str, Any]:
        ns = p.get("namespace", "s")
        store = self.store(ns)
        for vname, (container, value) in p["values"].items():
            store.manager.get(container).write(vname, value)
            self._var_containers[ns].setdefault(vname, container)
        return {"n": len(p["values"])}

    def _rpc_cleanup(self, p: Dict[str, Any]) -> Dict[str, Any]:
        purged = self.mailbox.purge_prefix(f"{p['execution_id']}|")
        return {"purged": purged}

    def _rpc_purge_execution(self, p: Dict[str, Any]) -> Dict[str, Any]:
        """§13 abort path: poison an in-flight execution and scrub its
        rendezvous state.  The master calls this on every SURVIVOR when a
        peer dies mid-run, so executors blocked on tensors the dead task
        will never produce unwind promptly (instead of burning their full
        recv timeout) and nothing leaks into the process-wide mailbox."""
        eid = p["execution_id"]
        reason = p.get("reason", f"execution {eid} aborted by master (§3.3)")
        views = self._active.get(eid, [])
        for wire in list(views):
            wire.abort(RuntimeError(reason))
            wire.close()  # straggler fetcher deposits drop, not leak
        purged = self.mailbox.purge_prefix(f"{eid}|")
        return {"aborted": len(views), "purged": purged}

    def _rpc_update_cluster(self, p: Dict[str, Any]) -> Dict[str, Any]:
        """§13 partial re-placement: patch registered graphs' cluster spec
        in place — survivors keep their graphs, executors and Variable
        state, but future peer fetches must dial the replacement endpoint,
        never the dead one.  Idempotent: re-applying the same spec is a
        no-op.  ``handles`` limits the patch to specific plans."""
        new = ClusterSpec.from_wire(p["cluster"])
        handles = p.get("handles")
        updated = 0
        for key, reg in self._graphs.items():
            if handles is not None and key[0] not in handles:
                continue
            if len(reg.cluster.workers) == len(new.workers):
                reg.cluster = new
                updated += 1
        # drop pooled channels to endpoints no longer in any updated spec:
        # a parked connection to the dead endpoint would only resurface as
        # a spurious transport error on the next fetch
        keep = {reg.cluster.host_port(t)
                for reg in self._graphs.values()
                for t in range(len(reg.cluster.workers))}
        with self._peers_lock:
            for ep in list(self._peers):
                if ep not in keep:
                    self._peers.pop(ep).close()
        return {"updated": updated}

    def _rpc_debug_state(self, p: Dict[str, Any]) -> Dict[str, Any]:
        """Hygiene probe (§13 tests / operator debugging): what is still
        live in this process — pending mailbox keys, active executions,
        straggler fetcher threads, registered (handle, task) slots."""
        return {
            "task": self.task, "pid": os.getpid(),
            "pending_keys": self.mailbox.pending_keys(),
            "active_executions": sorted(self._active),
            "fetch_threads": sum(
                1 for t in threading.enumerate()
                if t.is_alive() and t.name.startswith("wire-fetch:")),
            "registered": sorted(f"{h}@task:{t}" for h, t in self._graphs),
            # §12/§15: per-backend kernel dispatch counts in THIS process —
            # the proof that a wire run routed fused idioms through the
            # registry (trace-time counts, once per compiled signature)
            "kernel_dispatch": {f"{b}:{k}": v for (b, k), v
                                in sorted(kernel_registry.DISPATCH.items())},
        }

    def _rpc_shutdown(self, p: Dict[str, Any]) -> Dict[str, Any]:
        return {"task": self.task}  # _serve_conn stops after replying


# ---------------------------------------------------------------------------
# process helpers (tests, examples, CI smoke)


def start_worker_processes(
    n: int, *, host: str = "127.0.0.1", timeout: float = 120.0,
    rendezvous_timeout: float = 30.0, first_task: int = 0,
    extra_env: Optional[Dict[str, str]] = None,
) -> Tuple[List[subprocess.Popen], ClusterSpec]:
    """Spawn ``n`` worker processes on free ports; returns (procs, spec).

    Blocks until every worker announced ``WORKER_READY`` (imports of
    jax dominate startup).  Callers own the processes — pair with
    :func:`stop_worker_processes`.

    ``first_task`` numbers the spawned tasks from an offset — a §13
    standby is a worker spawned with the next free task id, registered
    into the pool only when recovery re-places a dead task onto it.
    ``extra_env`` overlays the inherited environment (e.g. a seeded
    ``REPRO_FAULTS`` plan shipped to every process of the pool).
    """
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    if extra_env:
        env.update(extra_env)
    procs: List[subprocess.Popen] = []
    addrs: List[str] = []
    try:
        for t in range(first_task, first_task + n):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.distrib.worker",
                 "--host", host, "--port", "0", "--task", str(t),
                 "--rendezvous-timeout", str(rendezvous_timeout)],
                stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
                text=True, env=env))
        deadline = time.monotonic() + timeout
        for t, proc in enumerate(procs):
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"worker task:{t} never became ready")
                # select before readline: a worker that hangs silently
                # (wedged import, deadlock) must trip the deadline, not
                # block this call forever on an empty pipe
                rl, _, _ = select.select([proc.stdout], [], [],
                                         min(remaining, 1.0))
                if not rl:
                    continue
                line = proc.stdout.readline()
                if not line:
                    raise RuntimeError(
                        f"worker task:{t} exited (rc={proc.poll()}) before ready")
                if line.startswith("WORKER_READY "):
                    addrs.append(line.split()[1])
                    break
            # keep draining stdout so the pipe can never fill and block
            threading.Thread(target=lambda s=proc.stdout: s.read(),
                             daemon=True).start()
    except BaseException:
        stop_worker_processes(procs)
        raise
    return procs, ClusterSpec(tuple(addrs))


def stop_worker_processes(procs: Sequence[subprocess.Popen],
                          spec: Optional[ClusterSpec] = None) -> None:
    """Best-effort graceful shutdown, then terminate/kill."""
    if spec is not None:
        for t in range(len(spec.workers)):
            try:
                # connect_attempts=1: a pool being torn down is usually
                # already gone — retrying refused dials only slows tests
                ch = Channel(*spec.host_port(t), connect_timeout=1.0,
                             connect_attempts=1)
                ch.call("shutdown", _timeout=2.0)
                ch.close()
            except Exception:  # noqa: BLE001 — already gone is fine
                pass
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=5.0)
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait(timeout=5.0)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (announced on stdout)")
    ap.add_argument("--task", type=int, default=0)
    ap.add_argument("--rendezvous-timeout", type=float, default=30.0)
    ap.add_argument("--ckpt-root", default=None,
                    help="directory for worker-local Save/Restore nodes")
    args = ap.parse_args(argv)
    # §13: declare this process's task so task-scoped fault rules (kill,
    # stall_hb) shipped via REPRO_FAULTS fire only in the right process
    faults.set_context(args.task)
    w = Worker(args.host, args.port, args.task,
               rendezvous_timeout=args.rendezvous_timeout,
               checkpoint_root=args.ckpt_root)
    host, port = w.start()
    print(f"WORKER_READY {host}:{port} task={args.task} pid={os.getpid()}",
          flush=True)
    try:
        while not w._stop.wait(0.5):
            pass
    except KeyboardInterrupt:
        w.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
