"""§3.3 worker process: serves per-device subgraphs over the wire protocol.

A Worker owns the runtime state of its slice of the cluster — a
process-wide rendezvous mailbox, a VariableStore, queues and checkpoint
IO — and serves the DESIGN.md §11 RPCs:

* ``register_graph`` — receive a partitioned per-task subgraph from the
  master, seed Variable state, optionally run §7 region fusion on each
  local device subgraph (strict fusion is bit-identical, so wire runs
  keep the compiled-super-node speedups), and build one reusable
  :class:`~repro.core.executor.Executor` per local device.
* ``run_graph`` — execute one registered graph under an execution id:
  one thread per local device, all coordinating through a
  :class:`~repro.distrib.wire.WireRendezvous` view of the mailbox.
* ``recv_tensor`` — the pull half of a cross-process Send/Recv pair:
  block until the local mailbox holds the (execution-namespaced) key,
  pop it and reply.  DEAD_TENSOR replies carry §4.4 deadness across the
  process boundary.
* ``heartbeat`` / ``get_variables`` / ``set_variables`` / ``cleanup`` /
  ``shutdown`` — liveness, checkpoint sync and lifecycle.

CLI (one process per task)::

    python -m repro.distrib.worker --host 127.0.0.1 --port 7077 --task 0

``--port 0`` picks a free port; the worker announces
``WORKER_READY host:port task=N pid=P`` on stdout either way, which is
what :func:`start_worker_processes` parses.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import select
import socket
import subprocess
import sys
import threading
import time
import traceback
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.executor import ExecutionContext, Executor
from ..core.graph import Graph, TensorRef
from ..core import fusion as fusion_mod
from ..runtime.containers import ContainerManager, VariableStore
from ..runtime.rendezvous import Rendezvous
from .protocol import Channel, recv_msg, send_msg
from .wire import ClusterSpec, WireRendezvous


@dataclasses.dataclass
class _Registered:
    """One graph the master registered with this worker."""

    graph: Graph
    executors: Dict[str, Executor]                 # local device -> Executor
    fetch_specs: Dict[str, List[Tuple[int, TensorRef]]]  # dev -> (global idx, ref)
    fetch_remap: Dict[TensorRef, TensorRef]
    cluster: ClusterSpec
    task: int
    namespace: str  # owning session's store namespace (§4.7)


class Worker:
    """One OS process serving one cluster task's devices (DESIGN.md §11)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, task: int = 0, *,
                 rendezvous_timeout: float = 30.0,
                 checkpoint_root: Optional[str] = None) -> None:
        self.host, self.port, self.task = host, port, task
        self.mailbox = Rendezvous(timeout=rendezvous_timeout)
        # one VariableStore per *session* namespace, mirroring the
        # in-process default of one ContainerManager per Session (§4.7):
        # sessions sharing this pool never alias each other's Variables
        # (VariableStore.write resolves names across its containers)
        self._stores: Dict[str, VariableStore] = {}
        self._var_containers: Dict[str, Dict[str, str]] = {}
        self.queues: Dict[str, Any] = {}
        if checkpoint_root:
            from ..checkpoint import FileCheckpointIO

            self.checkpoint_io: Any = FileCheckpointIO(checkpoint_root)
        else:
            from ..core.session import _DictCheckpointIO

            self.checkpoint_io = _DictCheckpointIO()
        self._graphs: "OrderedDict[str, _Registered]" = OrderedDict()
        self.max_graphs = 32  # LRU bound on registered graphs
        self._active: Dict[str, WireRendezvous] = {}
        # keyed by ENDPOINT, not task id: after a partial pool restart
        # (dead task re-spawned on a new port) the re-registered cluster
        # spec must dial the new endpoint, never a stale cached channel
        self._peers: Dict[Tuple[str, int], Channel] = {}
        self._peers_lock = threading.Lock()
        self._stop = threading.Event()
        self._sock: Optional[socket.socket] = None
        self._started = time.monotonic()

    # ------------------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, self.port))
        sock.listen(64)
        self.port = sock.getsockname()[1]
        self._sock = sock
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"worker{self.task}-accept").start()
        return self.host, self.port

    def stop(self) -> None:
        self._stop.set()
        self.mailbox.abort(RuntimeError(
            f"worker task:{self.task} (pid {os.getpid()}) shut down"))
        for rdv in list(self._active.values()):
            rdv.abort(RuntimeError(f"worker task:{self.task} shutting down"))
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        with self._peers_lock:
            for ch in self._peers.values():
                ch.close()
            self._peers.clear()

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    # ------------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True,
                             name=f"worker{self.task}-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                msg = recv_msg(conn)
                if msg is None:
                    return
                kind = msg.pop("kind", "?")
                handler = getattr(self, f"_rpc_{kind}", None)
                if handler is None:
                    reply: Dict[str, Any] = {"ok": False,
                                             "error": f"unknown RPC {kind!r}"}
                else:
                    try:
                        reply = handler(msg)
                        reply.setdefault("ok", True)
                    except Exception as e:  # noqa: BLE001 — report, don't die
                        reply = {"ok": False,
                                 "error": f"worker task:{self.task} "
                                          f"(pid {os.getpid()}) {kind} failed: "
                                          f"{type(e).__name__}: {e}\n"
                                          f"{traceback.format_exc(limit=8)}"}
                send_msg(conn, reply)
                if kind == "shutdown":
                    self.stop()
                    return
        except Exception:  # noqa: BLE001 — connection-level failure
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def store(self, namespace: str) -> VariableStore:
        st = self._stores.get(namespace)
        if st is None:
            st = self._stores[namespace] = VariableStore(ContainerManager())
            self._var_containers[namespace] = {}
        return st

    def _peer_channel(self, cluster: ClusterSpec, task: int) -> Channel:
        endpoint = cluster.host_port(task)
        with self._peers_lock:
            ch = self._peers.get(endpoint)
            if ch is None:
                ch = Channel(*endpoint)
                self._peers[endpoint] = ch
            return ch

    # ------------------------------------------------------------------
    # RPC handlers
    def _rpc_register_graph(self, p: Dict[str, Any]) -> Dict[str, Any]:
        cluster = ClusterSpec.from_wire(p["cluster"])
        g: Graph = p["graph"]
        device_nodes = {d: set(ns) for d, ns in p["device_nodes"].items()}
        names = set().union(*device_nodes.values()) if device_nodes else set()
        placement = dict(p["placement"])
        feed_keys = frozenset(TensorRef(n, pt) for n, pt in p["feed_keys"])
        fetch_specs = {d: [(i, TensorRef(n, pt)) for i, n, pt in lst]
                       for d, lst in p["fetches"].items()}
        ns = p.get("namespace", "s")
        store = self.store(ns)
        for vname, (container, value) in p["variables"].items():
            cont = store.manager.get(container)
            if not cont.has(vname):
                # SEED-only: registration must never clobber live state —
                # a second Executable on the same session registers here
                # mid-training, when this store (not the master's) holds
                # the trained weights.  Recovery pushes explicitly via
                # set_variables (Session.rebind_cluster).
                cont.write(vname, value)
            self._var_containers[ns][vname] = container

        fetch_remap: Dict[TensorRef, TensorRef] = {}
        if p.get("fuse", True) and names:
            # §7 region fusion on the local slice: placement keeps regions
            # per-device, Send/Recv nodes are runtime ops and never join a
            # region, so the fused graph is safe to interleave with wire
            # transfers.  Strict numerics stays bit-identical (§9).
            all_fetch_refs = [r for lst in fetch_specs.values() for _, r in lst]
            fus = fusion_mod.try_fuse(
                g, set(names), placement=placement, feeds=feed_keys,
                fetch_refs=all_fetch_refs,
                written_vars=fusion_mod.written_variables(g, names),
                numerics=p.get("numerics", "strict"))
            if fus is not None and (fus.regions or fus.changed):
                g = fus.graph
                fetch_remap = fus.fetch_map
                device_nodes = {}
                for n in fus.names:
                    device_nodes.setdefault(fus.placement[n], set()).add(n)
        executors = {dev: Executor(g, node_filter=ns, device_label=dev)
                     for dev, ns in device_nodes.items()}
        self._graphs[p["handle"]] = _Registered(
            graph=g, executors=executors, fetch_specs=fetch_specs,
            fetch_remap=fetch_remap, cluster=cluster, task=p["task"],
            namespace=ns)
        self._graphs.move_to_end(p["handle"])
        while len(self._graphs) > self.max_graphs:
            # bounded registry: masters whose signature churn outlives
            # this cap get a "not registered" reply and transparently
            # re-register (master.WirePlan.run)
            self._graphs.popitem(last=False)
        return {"devices": sorted(executors), "n_nodes": len(g.nodes)}

    def _rpc_run_graph(self, p: Dict[str, Any]) -> Dict[str, Any]:
        reg = self._graphs.get(p["handle"])
        if reg is None:
            raise KeyError(f"graph {p['handle']!r} is not registered here "
                           f"(worker restarted or registry evicted? "
                           f"re-register before running)")
        self._graphs.move_to_end(p["handle"])
        eid: str = p["execution_id"]
        timeout: float = float(p.get("timeout", 60.0))
        feeds: Dict[TensorRef, Any] = p.get("feeds") or {}
        wire = WireRendezvous(
            self.mailbox, reg.cluster, reg.task, eid, timeout=timeout,
            channel_of=lambda t: self._peer_channel(reg.cluster, t))
        self._active[eid] = wire
        results: Dict[int, Any] = {}
        errors: List[BaseException] = []
        lock = threading.Lock()

        store = self.store(reg.namespace)

        def run_device(dev: str, ex: Executor) -> None:
            ctx = ExecutionContext(
                variables=store, rendezvous=wire, queues=self.queues,
                checkpoint_io=self.checkpoint_io,
                device_kind=dev.split("device:")[-1].split(":")[0])
            specs = reg.fetch_specs.get(dev, [])
            local = [reg.fetch_remap.get(r, r) for _, r in specs]
            try:
                vals = ex.run(local, feeds, ctx=ctx)
                with lock:
                    for (i, _), v in zip(specs, vals):
                        results[i] = v
            except BaseException as e:  # noqa: BLE001 — §3.3 surface any failure
                with lock:
                    errors.append(e)

        threads = {dev: threading.Thread(target=run_device, args=(dev, ex),
                                         daemon=True,
                                         name=f"worker{reg.task}:{dev}")
                   for dev, ex in reg.executors.items()}
        try:
            for t in threads.values():
                t.start()
            deadline = time.monotonic() + timeout
            for t in threads.values():
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            if errors:
                raise errors[0]
            stuck = sorted(dev for dev, t in threads.items() if t.is_alive())
            if stuck:
                wire.abort(RuntimeError(f"execution {eid} timed out"))
                raise TimeoutError(
                    f"worker task:{reg.task} (pid {os.getpid()}): device(s) "
                    f"{stuck} never finished within {timeout:.1f}s (stuck "
                    f"Send/Recv or hung kernel; §3.3 failure reporting)")
            return {"results": results, "sends": wire.sends,
                    "bytes_sent": wire.bytes_sent,
                    "remote_fetches": wire.remote_fetches}
        finally:
            # stop straggler fetcher threads (blocked in recv_tensor RPCs
            # for up to their timeout) from depositing into the mailbox
            # after the master's cleanup purge has run — a late deposit
            # would leak for the worker's lifetime
            wire.close()
            self._active.pop(eid, None)

    def _rpc_recv_tensor(self, p: Dict[str, Any]) -> Dict[str, Any]:
        wait = float(p.get("wait", self.mailbox.timeout))
        value = self.mailbox.recv(p["key"], timeout=wait)
        return {"value": value}

    def _rpc_heartbeat(self, p: Dict[str, Any]) -> Dict[str, Any]:
        return {"task": self.task, "pid": os.getpid(),
                "active": len(self._active),
                "uptime_s": time.monotonic() - self._started,
                "registered": len(self._graphs)}

    def _rpc_get_variables(self, p: Dict[str, Any]) -> Dict[str, Any]:
        ns = p.get("namespace", "s")
        store = self.store(ns)
        names = p.get("names")
        out: Dict[str, Any] = {}
        for vname, container in self._var_containers.get(ns, {}).items():
            if names is not None and vname not in names:
                continue
            cont = store.manager.get(container)
            if cont.has(vname):
                out[vname] = cont.read(vname)
        return {"values": out}

    def _rpc_set_variables(self, p: Dict[str, Any]) -> Dict[str, Any]:
        ns = p.get("namespace", "s")
        store = self.store(ns)
        for vname, (container, value) in p["values"].items():
            store.manager.get(container).write(vname, value)
            self._var_containers[ns].setdefault(vname, container)
        return {"n": len(p["values"])}

    def _rpc_cleanup(self, p: Dict[str, Any]) -> Dict[str, Any]:
        purged = self.mailbox.purge_prefix(f"{p['execution_id']}|")
        return {"purged": purged}

    def _rpc_shutdown(self, p: Dict[str, Any]) -> Dict[str, Any]:
        return {"task": self.task}  # _serve_conn stops after replying


# ---------------------------------------------------------------------------
# process helpers (tests, examples, CI smoke)


def start_worker_processes(
    n: int, *, host: str = "127.0.0.1", timeout: float = 120.0,
    rendezvous_timeout: float = 30.0,
) -> Tuple[List[subprocess.Popen], ClusterSpec]:
    """Spawn ``n`` worker processes on free ports; returns (procs, spec).

    Blocks until every worker announced ``WORKER_READY`` (imports of
    jax dominate startup).  Callers own the processes — pair with
    :func:`stop_worker_processes`.
    """
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    procs: List[subprocess.Popen] = []
    addrs: List[str] = []
    try:
        for t in range(n):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.distrib.worker",
                 "--host", host, "--port", "0", "--task", str(t),
                 "--rendezvous-timeout", str(rendezvous_timeout)],
                stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
                text=True, env=env))
        deadline = time.monotonic() + timeout
        for t, proc in enumerate(procs):
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"worker task:{t} never became ready")
                # select before readline: a worker that hangs silently
                # (wedged import, deadlock) must trip the deadline, not
                # block this call forever on an empty pipe
                rl, _, _ = select.select([proc.stdout], [], [],
                                         min(remaining, 1.0))
                if not rl:
                    continue
                line = proc.stdout.readline()
                if not line:
                    raise RuntimeError(
                        f"worker task:{t} exited (rc={proc.poll()}) before ready")
                if line.startswith("WORKER_READY "):
                    addrs.append(line.split()[1])
                    break
            # keep draining stdout so the pipe can never fill and block
            threading.Thread(target=lambda s=proc.stdout: s.read(),
                             daemon=True).start()
    except BaseException:
        stop_worker_processes(procs)
        raise
    return procs, ClusterSpec(tuple(addrs))


def stop_worker_processes(procs: Sequence[subprocess.Popen],
                          spec: Optional[ClusterSpec] = None) -> None:
    """Best-effort graceful shutdown, then terminate/kill."""
    if spec is not None:
        for t in range(len(spec.workers)):
            try:
                ch = Channel(*spec.host_port(t), connect_timeout=1.0)
                ch.call("shutdown", _timeout=2.0)
                ch.close()
            except Exception:  # noqa: BLE001 — already gone is fine
                pass
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=5.0)
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait(timeout=5.0)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (announced on stdout)")
    ap.add_argument("--task", type=int, default=0)
    ap.add_argument("--rendezvous-timeout", type=float, default=30.0)
    ap.add_argument("--ckpt-root", default=None,
                    help="directory for worker-local Save/Restore nodes")
    args = ap.parse_args(argv)
    w = Worker(args.host, args.port, args.task,
               rendezvous_timeout=args.rendezvous_timeout,
               checkpoint_root=args.ckpt_root)
    host, port = w.start()
    print(f"WORKER_READY {host}:{port} task={args.task} pid={os.getpid()}",
          flush=True)
    try:
        while not w._stop.wait(0.5):
            pass
    except KeyboardInterrupt:
        w.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
