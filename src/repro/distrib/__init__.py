"""repro.distrib — the §3.2/§3.3 multi-process runtime (DESIGN.md §11).

Public surface:
  ClusterSpec                 worker-pool topology ("host:port,..." per task)
  WireRendezvous              runtime/rendezvous.py interface over TCP
  Worker                      one task's server process (also a CLI:
                              ``python -m repro.distrib.worker``)
  Master / WirePlan           heartbeat monitor + per-Executable shipping
  RecoveryError /             §13 partial re-placement: raised when nothing
  RecoveryReport              can host a dead task / what was kept vs restored
  FaultPlan / faults          §13 deterministic fault injection (REPRO_FAULTS)
  start_worker_processes /    local pool helpers for tests, examples and
  stop_worker_processes       the CI 2-process smoke job
"""
from . import faults
from .faults import FaultPlan
from .wire import ClusterSpec, WireRendezvous
from .worker import Worker, start_worker_processes, stop_worker_processes
from .master import Master, WirePlan, RecoveryError, RecoveryReport
from .protocol import Channel, ProtocolError, WorkerError, encode_tensor, decode_tensor

__all__ = [
    "ClusterSpec", "WireRendezvous", "Worker", "Master", "WirePlan",
    "RecoveryError", "RecoveryReport", "FaultPlan", "faults",
    "Channel", "ProtocolError", "WorkerError",
    "encode_tensor", "decode_tensor",
    "start_worker_processes", "stop_worker_processes",
]
