"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads, 1 B/C group.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=256, ssm_groups=1,
    source="arXiv:2405.21060 (Mamba-2), 2.7B config",
)

SMOKE = ModelConfig(
    arch_id="mamba2-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_conv=4,
    ssm_chunk=16, ssm_groups=1,
    source="reduced mamba2 family",
)
