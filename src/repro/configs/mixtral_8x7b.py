"""mixtral-8x7b — extra pool architecture (beyond the assigned 10)
[hf:mistralai/Mixtral-8x7B-v0.1].

32L d_model=4096, 32 heads (GQA kv=8), 8 experts top-2 with per-expert
d_ff=14336, vocab=32000.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, rope_theta=1e6,
    n_experts=8, top_k=2, moe_d_ff=14336, capacity_factor=1.25,
    source="hf:mistralai/Mixtral-8x7B-v0.1 (extra, beyond assignment)",
)

SMOKE = ModelConfig(
    arch_id="mixtral-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, n_experts=4, top_k=2, moe_d_ff=96, capacity_factor=2.0,
    source="reduced mixtral family",
)
