"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048, 32 q heads (GQA kv=4) head_dim=128, per-expert
d_ff=768, vocab=151936, qk-norm.  No shared experts.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, moe_d_ff=768, capacity_factor=1.25,
    source="hf:Qwen/Qwen3-30B-A3B",
)

SMOKE = ModelConfig(
    arch_id="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=96, vocab_size=512, qk_norm=True,
    n_experts=4, top_k=2, moe_d_ff=96, capacity_factor=2.0,
    source="reduced qwen3-moe family",
)
