"""qwen2-0.5b — GQA + QKV bias [arXiv:2407.10671].

24L d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151936,
tied embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    source="arXiv:2407.10671 (Qwen2), 0.5B config",
)

SMOKE = ModelConfig(
    arch_id="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=512, qkv_bias=True, tie_embeddings=True,
    source="reduced qwen2 family",
)
