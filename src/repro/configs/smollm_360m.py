"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM-360M].

32L d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152, tied.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)

SMOKE = ModelConfig(
    arch_id="smollm-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_ff=256,
    vocab_size=512, tie_embeddings=True,
    source="reduced smollm family",
)
