"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676].

32L d_model=1600, 25 attn heads (GQA kv=5, head_dim 64) in parallel with
SSD heads (d_inner=3200, head_dim 64 -> 50 SSD heads, state 16); sliding
window 1024 everywhere except 3 global full-attention layers
(first/middle/last), per the paper.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=256, ssm_groups=1,
    swa_window=1024, global_layers=(0, 15, 31),
    source="arXiv:2411.13676 (Hymba), 1.5B config",
)

SMOKE = ModelConfig(
    arch_id="hymba-smoke", family="hybrid",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_conv=4,
    ssm_chunk=8, ssm_groups=1,
    swa_window=8, global_layers=(1,),
    source="reduced hymba family",
)
