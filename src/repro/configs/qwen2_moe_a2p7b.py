"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048, 16 heads (kv=16, MHA), per-expert d_ff=1408,
shared-expert width 4*1408=5632, vocab=151936, QKV bias.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, shared_d_ff=5632, capacity_factor=1.25,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE = ModelConfig(
    arch_id="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab_size=512, qkv_bias=True,
    n_experts=4, top_k=2, moe_d_ff=96,
    n_shared_experts=1, shared_d_ff=192, capacity_factor=2.0,
    source="reduced qwen2-moe family",
)
