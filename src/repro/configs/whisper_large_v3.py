"""whisper-large-v3 — enc-dec audio backbone [arXiv:2212.04356].

32L decoder (+32L encoder), d_model=1280, 20 heads (MHA), d_ff=5120,
vocab=51866.  Mel+conv frontend is a STUB: input_specs feeds precomputed
frame embeddings (B, 1500, 1280).  GELU (non-gated) MLPs, whisper-style.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, act="gelu",
    n_enc_layers=32, enc_seq=1500,
    source="arXiv:2212.04356 (Whisper), large-v3 card",
)

SMOKE = ModelConfig(
    arch_id="whisper-smoke", family="encdec",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, act="gelu",
    n_enc_layers=2, enc_seq=24,
    source="reduced whisper family",
)
