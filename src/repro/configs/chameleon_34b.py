"""chameleon-34b — early-fusion VLM [arXiv:2405.09818].

48L d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=65536 with VQ
image codes interleaved in the token stream (the VQ tokenizer is the
frontend STUB: input_specs feeds token ids only), qk-norm per the paper.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, qk_norm=True,
    source="arXiv:2405.09818 (Chameleon), 34B config",
)

SMOKE = ModelConfig(
    arch_id="chameleon-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512, qk_norm=True,
    source="reduced chameleon family",
)
