"""Assigned architecture configs (``--arch <id>``).

Each module defines ``CONFIG`` (the exact assigned full-scale config, with
source citation) and ``SMOKE`` (a reduced same-family variant: <=2 layers,
d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "mamba2_2p7b",
    "whisper_large_v3",
    "qwen3_moe_30b_a3b",
    "qwen2_moe_a2p7b",
    "chameleon_34b",
    "qwen2_0p5b",
    "qwen2p5_14b",
    "smollm_360m",
    "hymba_1p5b",
    "mistral_large_123b",
]

# extra pool architectures (beyond the 10 assigned; see README)
EXTRA_ARCH_IDS: List[str] = [
    "llama3_8b",
    "mixtral_8x7b",
]

# the task-assignment names -> module names
ALIASES: Dict[str, str] = {
    "mamba2-2.7b": "mamba2_2p7b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "chameleon-34b": "chameleon_34b",
    "qwen2-0.5b": "qwen2_0p5b",
    "qwen2.5-14b": "qwen2p5_14b",
    "smollm-360m": "smollm_360m",
    "hymba-1.5b": "hymba_1p5b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3-8b": "llama3_8b",
    "mixtral-8x7b": "mixtral_8x7b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
