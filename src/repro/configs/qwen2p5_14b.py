"""qwen2.5-14b — GQA + QKV bias [hf:Qwen/Qwen2.5-14B].

48L d_model=5120, 40 heads (GQA kv=8), d_ff=13824, vocab=152064.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-14B (assignment cites Qwen2.5 card)",
)

SMOKE = ModelConfig(
    arch_id="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=160, n_heads=5, n_kv_heads=1, d_ff=384,
    vocab_size=512, qkv_bias=True,
    source="reduced qwen2.5 family",
)
