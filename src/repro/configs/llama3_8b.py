"""llama-3.1-8b — extra pool architecture (beyond the assigned 10)
[hf:meta-llama/Llama-3.1-8B].

32L d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, rope_theta=5e5,
    source="hf:meta-llama/Llama-3.1-8B (extra, beyond assignment)",
)

SMOKE = ModelConfig(
    arch_id="llama3-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
    vocab_size=512,
    source="reduced llama3 family",
)
