"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288, 96 heads (GQA kv=8, head_dim 128), d_ff=28672,
vocab=32768.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768, rope_theta=1e6,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = ModelConfig(
    arch_id="mistral-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    source="reduced mistral family",
)
