"""Unified model API: one facade over all families.

``Model.for_config(cfg)`` dispatches to the right assembly (lm / encdec)
and exposes: describe_params, loss_fn, forward, serve_step,
init_cache_desc, and input description for each workload shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, PadPlan, plan_padding
from . import lm, encdec
from .params import LeafSpec, abstract_params, init_params, param_axes


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


class Model:
    def __init__(self, cfg: ModelConfig, plan: PadPlan):
        self.cfg = cfg
        self.plan = plan
        self.is_encdec = cfg.family == "encdec"
        self._mod = encdec if self.is_encdec else lm

    @staticmethod
    def for_config(cfg: ModelConfig, shard: int = 1) -> "Model":
        return Model(cfg, plan_padding(cfg, shard))

    # ------------------------------------------------------------------
    def describe_params(self, *, serve_longctx: bool = False):
        if self.is_encdec:
            return encdec.describe_encdec(self.cfg, self.plan,
                                          serve_longctx=serve_longctx)
        return lm.describe_lm(self.cfg, self.plan, serve_longctx=serve_longctx)

    def init(self, key, *, serve_longctx: bool = False):
        return init_params(self.describe_params(serve_longctx=serve_longctx), key)

    def abstract_params(self, **kw):
        return abstract_params(self.describe_params(**kw))

    def param_axes(self, **kw):
        return param_axes(self.describe_params(**kw))

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, **kw) -> jax.Array:
        return self._mod.loss_fn(self.cfg, self.plan, params, batch, **kw)

    def forward_logits(self, params, batch, **kw) -> jax.Array:
        if self.is_encdec:
            x, _ = encdec.forward(self.cfg, self.plan, params,
                                  batch["tokens"], batch["frames"], **kw)
        else:
            x, _ = lm.forward(self.cfg, self.plan, params, batch["tokens"], **kw)
        return lm.logits_from_hidden(self.cfg, self.plan, params, x)

    def serve_step(self, params, cache, tokens, pos, **kw):
        return self._mod.serve_step(self.cfg, self.plan, params, cache,
                                    tokens, pos, **kw)

    def init_cache_desc(self, *, batch: int, max_seq: int,
                        serve_longctx: bool = False, dtype=jnp.float32):
        return self._mod.init_cache_desc(self.cfg, self.plan, batch=batch,
                                         max_seq=max_seq,
                                         serve_longctx=serve_longctx,
                                         dtype=dtype)

    # ------------------------------------------------------------------
    def batch_desc(self, shape: Shape) -> Dict[str, LeafSpec]:
        """Feed tensors for a workload shape (dry-run stand-ins)."""
        B, S = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            d = {
                "tokens": LeafSpec((B, S), ("batch", "seq"), dtype=jnp.int32),
                "labels": LeafSpec((B, S), ("batch", "seq"), dtype=jnp.int32),
            }
            if self.is_encdec:
                d["frames"] = LeafSpec((B, self.cfg.enc_seq, self.cfg.d_model),
                                       ("batch", None, None), dtype=jnp.bfloat16)
            return d
        # decode: one token against a seq_len cache
        return {
            "tokens": LeafSpec((B, 1), ("batch", None), dtype=jnp.int32),
            "pos": LeafSpec((), (), dtype=jnp.int32),
        }

    def supports_shape(self, shape: Shape) -> Tuple[bool, str]:
        if shape.name == "long_500k":
            if self.cfg.family == "ssm":
                return True, "native O(1)-state decode"
            if self.cfg.family == "hybrid":
                return True, "SWA + SSM decode (global layers run SWA in the serving variant)"
            return True, f"sliding-window serving variant (window={self.cfg.longctx_window})"
        return True, ""


def make_model(cfg: ModelConfig, shard: int = 1) -> Model:
    return Model.for_config(cfg, shard)
