"""Encoder-decoder transformer (whisper-large-v3 backbone).

Per the assignment carve-out, the audio frontend (mel spectrogram + conv
feature extractor) is a STUB: the model consumes precomputed frame
embeddings (B, enc_seq, d_model) supplied by ``input_specs``.  Everything
downstream is real: a bidirectional encoder (sinusoidal positions, plain
GELU MLP — whisper-style) and a causal decoder with cross-attention.

Decode caches: self-attention KV ring/linear cache + cross-attention KV
computed once from the encoder output (stored in the cache pytree).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import logical_constraint as lc
from . import layers as L
from .config import ModelConfig, PadPlan
from .lm import (NEG_INF, _attn_desc, _mlp_desc, _stack, _attn_out,
                 _project_qkv, mlp_block, logits_from_hidden)
from .params import LeafSpec


def describe_encdec(cfg: ModelConfig, plan: PadPlan, *,
                    serve_longctx: bool = False) -> Dict[str, Any]:
    D = cfg.d_model
    enc_block = {**_attn_desc(cfg, plan), **_mlp_desc(cfg)}
    dec_block = {
        **_attn_desc(cfg, plan),
        "cross": {**{k: v for k, v in _attn_desc(cfg, plan).items() if k != "ln1"},
                  "ln": LeafSpec((D,), ("d_model",), "ones")},
        **_mlp_desc(cfg),
    }
    return {
        "enc_pos": LeafSpec((cfg.enc_seq, D), (None, "d_model"), "normal:0.01"),
        "enc": _stack(enc_block, cfg.n_enc_layers),
        "enc_norm": LeafSpec((D,), ("d_model",), "ones"),
        "embed": LeafSpec((plan.vocab_pad, D), ("vocab", "d_model")),
        "dec": _stack(dec_block, cfg.n_layers),
        "final_norm": LeafSpec((D,), ("d_model",), "ones"),
        "unembed": LeafSpec((D, plan.vocab_pad), ("d_model", "vocab")),
    }


def _self_attn(cfg, plan, p, x, positions, *, causal, window=0, q_chunk=0,
               kv_override=None, pos_kv=None):
    B, S, D = x.shape
    h = L.rmsnorm(x, p["ln1" if "ln1" in p else "ln"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, plan, p, h, positions)
    if kv_override is not None:
        k, v = kv_override
        pos_kv = pos_kv if pos_kv is not None else jnp.arange(k.shape[1])
    else:
        k = L.duplicate_kv(k, plan)
        v = L.duplicate_kv(v, plan)
        pos_kv = positions
    q = q.reshape(B, S, plan.kv_pad, plan.group, cfg.hd)
    hm = jnp.asarray(plan.head_mask(), x.dtype).reshape(plan.kv_pad, plan.group, 1)
    attn = L.attention(q, k, v, pos_q=positions, pos_kv=pos_kv, causal=causal,
                       window=window, q_chunk=q_chunk, head_mask=hm)
    return x + _attn_out(cfg, plan, p, attn, B, S)


def _cross_kv(cfg, plan, p, enc_out):
    """Project encoder output to (duplicated, padded) K/V once."""
    k = jnp.einsum("btd,dkh->btkh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dkh->btkh", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return L.duplicate_kv(k, plan), L.duplicate_kv(v, plan)


def _cross_attn(cfg, plan, p, x, enc_kv):
    B, S, D = x.shape
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dqh->bsqh", h, p["wq"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
    q = q.reshape(B, S, plan.kv_pad, plan.group, cfg.hd)
    k, v = enc_kv
    hm = jnp.asarray(plan.head_mask(), x.dtype).reshape(plan.kv_pad, plan.group, 1)
    attn = L.attention(q, k, v,
                       pos_q=jnp.zeros((S,), jnp.int32),
                       pos_kv=jnp.zeros((k.shape[1],), jnp.int32),
                       causal=False, head_mask=hm)
    return x + _attn_out(cfg, plan, p, attn, B, S)


def encode(cfg: ModelConfig, plan: PadPlan, params, frames: jax.Array,
           *, q_chunk: int = 0, remat: bool = True,
           scan_unroll: int = 1) -> jax.Array:
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    x = frames + params["enc_pos"].astype(frames.dtype)[None]
    x = lc(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def layer(x, pl):
        x = _self_attn(cfg, plan, pl, x, positions, causal=False,
                       q_chunk=q_chunk)
        return mlp_block(cfg, pl, x), None

    fn = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(lambda c, pl: fn(c, pl), x, params["enc"],
                        unroll=scan_unroll)
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, plan: PadPlan, params,
            tokens: jax.Array, frames: jax.Array, *,
            q_chunk: int = 0, compute_dtype: Any = jnp.float32,
            serve_longctx: bool = False, remat: bool = True,
            scan_unroll: int = 1) -> Tuple[jax.Array, jax.Array]:
    frames = frames.astype(compute_dtype)
    enc_out = encode(cfg, plan, params, frames, q_chunk=q_chunk, remat=remat,
                     scan_unroll=scan_unroll)
    B, S = tokens.shape
    x = jnp.take(params["embed"].astype(compute_dtype), tokens, axis=0)
    x = lc(x, "batch", "seq", None)
    positions = jnp.arange(S, dtype=jnp.int32)
    window = cfg.longctx_window if serve_longctx else 0

    def layer(x, pl):
        x = _self_attn(cfg, plan, pl, x, positions, causal=True,
                       window=window, q_chunk=q_chunk)
        x = _cross_attn(cfg, plan, pl["cross"], x,
                        _cross_kv(cfg, plan, pl["cross"], enc_out))
        return mlp_block(cfg, pl, x), None

    fn = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(lambda c, pl: fn(c, pl), x, params["dec"],
                        unroll=scan_unroll)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, plan: PadPlan, params, batch, *,
            q_chunk: int = 0, compute_dtype: Any = jnp.float32,
            loss_chunk: int = 0, n_token_groups: int = 1,
            remat: bool = True, scan_unroll: int = 1) -> jax.Array:
    x, _ = forward(cfg, plan, params, batch["tokens"], batch["frames"],
                   q_chunk=q_chunk, compute_dtype=compute_dtype, remat=remat,
                   scan_unroll=scan_unroll)
    logits = logits_from_hidden(cfg, plan, params, x).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["labels"][..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# decode


def init_cache_desc(cfg: ModelConfig, plan: PadPlan, *, batch: int,
                    max_seq: int, serve_longctx: bool = False,
                    dtype: Any = jnp.float32) -> Dict[str, Any]:
    hd = cfg.hd
    span = min(max_seq, cfg.longctx_window) if serve_longctx else max_seq
    n = cfg.n_layers
    return {
        "self_k": LeafSpec((n, batch, span, plan.kv_pad, hd),
                           ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
        "self_v": LeafSpec((n, batch, span, plan.kv_pad, hd),
                           ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
        "cross_k": LeafSpec((n, batch, cfg.enc_seq, plan.kv_pad, hd),
                            ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
        "cross_v": LeafSpec((n, batch, cfg.enc_seq, plan.kv_pad, hd),
                            ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
    }


def build_cross_cache(cfg, plan, params, enc_out):
    """Fill the cross-attention K/V cache from encoder states (prefill)."""
    def per_layer(pl):
        k, v = _cross_kv(cfg, plan, pl["cross"], enc_out)
        return k, v
    ks, vs = jax.lax.map(per_layer, params["dec"])
    return ks, vs


def serve_step(cfg: ModelConfig, plan: PadPlan, params, cache,
               tokens: jax.Array, pos: jax.Array, *,
               compute_dtype: Any = jnp.float32,
               serve_longctx: bool = False, n_token_groups: int = 1,
               scan_unroll: int = 1) -> Tuple[jax.Array, Dict[str, Any]]:
    from .lm import _decode_attn

    B = tokens.shape[0]
    window = cfg.longctx_window if serve_longctx else 0
    x = jnp.take(params["embed"].astype(compute_dtype), tokens, axis=0)

    def layer(x, packed):
        pl, sk, sv, ck, cv = packed
        a_out, nk, nv = _decode_attn(cfg, plan, pl, x, sk, sv, pos, window)
        x = x + a_out
        x = _cross_attn(cfg, plan, pl["cross"], x, (ck, cv))
        return mlp_block(cfg, pl, x), (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        lambda c, packed: layer(c, packed), x,
        (params["dec"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]), unroll=scan_unroll)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, plan, params, x)
    new_cache = dict(cache)
    new_cache["self_k"], new_cache["self_v"] = nks, nvs
    return logits, new_cache
