"""Decoder-only LM assembly: dense / MoE / SSM / hybrid families.

A model is a sequence of *block groups* — contiguous runs of identical
layer kinds — so ``lax.scan`` over stacked per-group parameters keeps
compile time O(#groups), not O(#layers), with ``jax.checkpoint`` (remat)
around each layer.  Kinds:

  attn    — GQA attention + gated MLP            (dense, vlm)
  swa     — same, sliding-window attention       (hybrid/serving variant)
  moe     — GQA attention + routed-expert FFN (+ optional shared experts)
  ssm     — Mamba-2 SSD mixer                    (attention-free)
  hybrid  — parallel attention + SSD heads, then MLP (hymba)

Decode ("serve") uses per-group caches: KV ring buffers for attention,
(state, conv) tuples for SSD.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import logical_constraint as lc
from . import layers as L
from .config import ModelConfig, PadPlan
from .params import LeafSpec

NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    kind: str          # attn | swa | moe | ssm | hybrid
    count: int
    window: int = 0    # >0 for swa kind


def block_groups(cfg: ModelConfig, *, serve_longctx: bool = False) -> List[BlockGroup]:
    """Static layer grouping for a config (DESIGN.md §4)."""
    if cfg.family == "ssm":
        return [BlockGroup("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        groups: List[BlockGroup] = []
        kinds = ["hybrid_swa"] * cfg.n_layers
        for gi in cfg.global_layers:
            kinds[gi] = "hybrid"
        # long-context serving keeps SWA for the global layers too
        if serve_longctx:
            kinds = ["hybrid_swa"] * cfg.n_layers
        i = 0
        while i < cfg.n_layers:
            j = i
            while j < cfg.n_layers and kinds[j] == kinds[i]:
                j += 1
            groups.append(BlockGroup(
                kinds[i].replace("hybrid_swa", "hybrid_swa"), j - i,
                window=cfg.swa_window if kinds[i] == "hybrid_swa" else 0))
            i = j
        return groups
    kind = "moe" if cfg.n_experts else "attn"
    if serve_longctx:
        # dense/moe archs at 500k run the sliding-window serving variant
        return [BlockGroup(kind, cfg.n_layers, window=cfg.longctx_window)]
    if cfg.swa_window:
        return [BlockGroup(kind, cfg.n_layers, window=cfg.swa_window)]
    return [BlockGroup(kind, cfg.n_layers)]


# ---------------------------------------------------------------------------
# parameter descriptions


def _attn_desc(cfg: ModelConfig, plan: PadPlan) -> Dict[str, Any]:
    D, hd = cfg.d_model, cfg.hd
    d = {
        "ln1": LeafSpec((D,), ("d_model",), "ones"),
        "wq": LeafSpec((D, plan.q_pad, hd), ("d_model", "heads", None),
                       f"normal:{0.02}"),
        "wk": LeafSpec((D, plan.n_kv_orig, hd), ("d_model", "kv_orig", None)),
        "wv": LeafSpec((D, plan.n_kv_orig, hd), ("d_model", "kv_orig", None)),
        "wo": LeafSpec((plan.q_pad, hd, D), ("heads", None, "d_model"),
                       f"normal:{0.02 / math.sqrt(2 * cfg.n_layers)}"),
    }
    if cfg.qkv_bias:
        d["bq"] = LeafSpec((plan.q_pad, hd), ("heads", None), "zeros")
        d["bk"] = LeafSpec((plan.n_kv_orig, hd), ("kv_orig", None), "zeros")
        d["bv"] = LeafSpec((plan.n_kv_orig, hd), ("kv_orig", None), "zeros")
    if cfg.qk_norm:
        d["q_norm"] = LeafSpec((hd,), (None,), "ones")
        d["k_norm"] = LeafSpec((hd,), (None,), "ones")
    return d


def _mlp_desc(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    d = {
        "ln2": LeafSpec((D,), ("d_model",), "ones"),
        "w1": LeafSpec((D, F), ("d_model", "ff")),
        "w2": LeafSpec((F, D), ("ff", "d_model"),
                       f"normal:{0.02 / math.sqrt(2 * cfg.n_layers)}"),
    }
    if cfg.act == "silu":
        d["w3"] = LeafSpec((D, F), ("d_model", "ff"))
    return d


def _moe_desc(cfg: ModelConfig, plan: PadPlan) -> Dict[str, Any]:
    D, F, E = cfg.d_model, cfg.moe_d_ff, plan.experts_pad
    d = {
        "ln2": LeafSpec((D,), ("d_model",), "ones"),
        "router": LeafSpec((D, E), ("d_model", None), "normal:0.02"),
        "w1": LeafSpec((E, D, F), ("experts", "d_model", None)),
        "w3": LeafSpec((E, D, F), ("experts", "d_model", None)),
        "w2": LeafSpec((E, F, D), ("experts", None, "d_model"),
                       f"normal:{0.02 / math.sqrt(2 * cfg.n_layers)}"),
    }
    if cfg.n_shared_experts:
        Fs = cfg.shared_d_ff
        d["shared"] = {
            "w1": LeafSpec((D, Fs), ("d_model", "ff")),
            "w3": LeafSpec((D, Fs), ("d_model", "ff")),
            "w2": LeafSpec((Fs, D), ("ff", "d_model"),
                           f"normal:{0.02 / math.sqrt(2 * cfg.n_layers)}"),
            "gate": LeafSpec((D,), ("d_model",), "zeros"),
        }
    return d


def _ssm_desc(cfg: ModelConfig, plan: PadPlan) -> Dict[str, Any]:
    D = cfg.d_model
    Hp = plan.ssm_heads_pad
    P = cfg.ssm_head_dim
    GN = cfg.ssm_groups * cfg.ssm_state
    K = cfg.ssm_conv
    inner = Hp * P
    return {
        "ln": LeafSpec((D,), ("d_model",), "ones"),
        "wz": LeafSpec((D, inner), ("d_model", "inner")),
        "wx": LeafSpec((D, inner), ("d_model", "inner")),
        "wB": LeafSpec((D, GN), ("d_model", None)),
        "wC": LeafSpec((D, GN), ("d_model", None)),
        "wdt": LeafSpec((D, Hp), ("d_model", "ssm_heads")),
        "dt_bias": LeafSpec((Hp,), ("ssm_heads",), "dt_bias"),
        "A_log": LeafSpec((Hp,), ("ssm_heads",), "a_log"),
        "D_skip": LeafSpec((Hp,), ("ssm_heads",), "ones"),
        "conv_x": LeafSpec((inner, K), ("inner", None), "normal:0.5"),
        "conv_B": LeafSpec((GN, K), (None, None), "normal:0.5"),
        "conv_C": LeafSpec((GN, K), (None, None), "normal:0.5"),
        "norm": LeafSpec((inner,), ("inner",), "ones"),
        "wout": LeafSpec((inner, D), ("inner", "d_model"),
                         f"normal:{0.02 / math.sqrt(2 * cfg.n_layers)}"),
    }


def _block_desc(cfg: ModelConfig, plan: PadPlan, kind: str) -> Dict[str, Any]:
    base_kind = kind.replace("_swa", "").replace("hybrid_swa", "hybrid")
    if kind.startswith("hybrid"):
        return {
            **_attn_desc(cfg, plan),
            "ssm": _ssm_desc(cfg, plan),
            "attn_fuse_norm": LeafSpec((cfg.d_model,), ("d_model",), "ones"),
            "ssm_fuse_norm": LeafSpec((cfg.d_model,), ("d_model",), "ones"),
            **_mlp_desc(cfg),
        }
    if kind == "ssm":
        return _ssm_desc(cfg, plan)
    if kind == "moe":
        return {**_attn_desc(cfg, plan), **_moe_desc(cfg, plan)}
    return {**_attn_desc(cfg, plan), **_mlp_desc(cfg)}  # attn / swa


def _stack(desc: Any, n: int) -> Any:
    return jax.tree.map(
        lambda s: LeafSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype),
        desc, is_leaf=lambda x: isinstance(x, LeafSpec))


def describe_lm(cfg: ModelConfig, plan: PadPlan, *,
                serve_longctx: bool = False) -> Dict[str, Any]:
    groups = block_groups(cfg, serve_longctx=serve_longctx)
    desc: Dict[str, Any] = {
        "embed": LeafSpec((plan.vocab_pad, cfg.d_model), ("vocab", "d_model")),
        "final_norm": LeafSpec((cfg.d_model,), ("d_model",), "ones"),
    }
    if not cfg.tie_embeddings:
        desc["unembed"] = LeafSpec((cfg.d_model, plan.vocab_pad),
                                   ("d_model", "vocab"))
    for gi, g in enumerate(groups):
        desc[f"g{gi}"] = _stack(_block_desc(cfg, plan, g.kind), g.count)
    return desc


# ---------------------------------------------------------------------------
# forward blocks


def _project_qkv(cfg, plan, p, h, positions):
    B, S, D = h.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dqh->bsqh", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dkh->bskh", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dkh->bskh", h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = lc(q, "batch", "seq", "heads", None)
    return q, k, v


def _attn_out(cfg, plan, p, attn, B, S):
    out = jnp.einsum("bskgh,kghd->bsd",
                     attn,
                     p["wo"].astype(attn.dtype).reshape(
                         plan.kv_pad, plan.group, cfg.hd, cfg.d_model))
    return lc(out, "batch", "seq_res", None)


def _maybe_gather_seq(h: jax.Array) -> jax.Array:
    """Megatron-SP schedule: when the residual stream is seq-sharded
    (rules seq_res->model), gather h ONCE before the qkv projections so
    GSPMD doesn't re-gather q/k/v per head shard (EXPERIMENTS §Perf)."""
    from ..parallel import sharding as shd

    rules = shd.current_rules()
    if rules and rules.get("seq_res") == "model" and rules.get("sp_gather_h", True):
        return lc(h, "batch", None, None)
    return h


def attn_block(cfg: ModelConfig, plan: PadPlan, p: Dict[str, Any],
               x: jax.Array, positions: jax.Array, *,
               window: int, q_chunk: int) -> jax.Array:
    B, S, D = x.shape
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    h = _maybe_gather_seq(h)
    q, k, v = _project_qkv(cfg, plan, p, h, positions)
    q = q.reshape(B, S, plan.kv_pad, plan.group, cfg.hd)
    k = L.duplicate_kv(k, plan)
    v = L.duplicate_kv(v, plan)
    hm = jnp.asarray(plan.head_mask(), x.dtype).reshape(plan.kv_pad, plan.group, 1)
    attn = L.attention(q, k, v, pos_q=positions, pos_kv=positions,
                       causal=True, window=window, q_chunk=q_chunk,
                       head_mask=hm)
    return x + _attn_out(cfg, plan, p, attn, B, S)


def mlp_block(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array) -> jax.Array:
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.gated_mlp(h, p["w1"].astype(x.dtype),
                           p.get("w3") if p.get("w3") is None else p["w3"].astype(x.dtype),
                           p["w2"].astype(x.dtype), cfg.act)


def moe_block(cfg: ModelConfig, plan: PadPlan, p: Dict[str, Any],
              x: jax.Array, n_groups: int) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    hg = h.reshape(n_groups, (B * S) // n_groups, D)
    hg = lc(hg, "groups", None, None)
    out, stats = L.moe_ffn(
        hg, p["router"].astype(x.dtype),
        p["w1"].astype(x.dtype), p["w3"].astype(x.dtype), p["w2"].astype(x.dtype),
        n_experts=cfg.n_experts, top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor, act=cfg.act)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        sp = p["shared"]
        shared = L.gated_mlp(h, sp["w1"].astype(x.dtype), sp["w3"].astype(x.dtype),
                             sp["w2"].astype(x.dtype), cfg.act)
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,d->bs", h.astype(jnp.float32), sp["gate"]))[..., None]
        out = out + shared * gate.astype(x.dtype)
    return x + out, stats.aux_loss


def ssm_block(cfg: ModelConfig, plan: PadPlan, p: Dict[str, Any],
              x: jax.Array) -> jax.Array:
    y, _ = ssm_mixer(cfg, plan, p, L.rmsnorm(x, p["ln"], cfg.norm_eps))
    return x + y


def ssm_mixer(cfg: ModelConfig, plan: PadPlan, p: Dict[str, Any],
              h: jax.Array, cache: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full mamba-2 mixer on normed input h (B,S,D).  With ``cache``
    (decode) S must be 1 and the conv/state caches are advanced."""
    B, S, D = h.shape
    Hp, P = plan.ssm_heads_pad, cfg.ssm_head_dim
    GN = cfg.ssm_groups * cfg.ssm_state
    z = h @ p["wz"].astype(h.dtype)
    xs = h @ p["wx"].astype(h.dtype)
    Bs = h @ p["wB"].astype(h.dtype)
    Cs = h @ p["wC"].astype(h.dtype)
    dt_raw = h @ p["wdt"].astype(h.dtype)
    z = lc(z, "batch", "seq", "inner")
    xs = lc(xs, "batch", "seq", "inner")

    new_cache: Optional[Dict[str, jax.Array]] = None
    if cache is None:
        xs, _ = L.causal_conv1d(xs, p["conv_x"].astype(h.dtype))
        Bs, _ = L.causal_conv1d(Bs, p["conv_B"].astype(h.dtype))
        Cs, _ = L.causal_conv1d(Cs, p["conv_C"].astype(h.dtype))
    else:
        xs, cx = L.causal_conv1d(xs, p["conv_x"].astype(h.dtype), cache["conv_x"])
        Bs, cb = L.causal_conv1d(Bs, p["conv_B"].astype(h.dtype), cache["conv_B"])
        Cs, cc = L.causal_conv1d(Cs, p["conv_C"].astype(h.dtype), cache["conv_C"])
        new_cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc}
    xs, Bs, Cs = jax.nn.silu(xs), jax.nn.silu(Bs), jax.nn.silu(Cs)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    xh = xs.reshape(B, S, Hp, P)
    Bh = Bs.reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    Chh = Cs.reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    mask = jnp.asarray(_ssm_head_mask(cfg, plan), h.dtype)

    if cache is None:
        y, _ = L.ssd_chunked(xh, dt, p["A_log"], Bh, Chh, p["D_skip"],
                             chunk=min(cfg.ssm_chunk, S))
    else:
        y1, new_state = L.ssd_decode_step(
            xh[:, 0], dt[:, 0], p["A_log"], Bh[:, 0], Chh[:, 0],
            p["D_skip"], cache["state"])
        new_cache["state"] = new_state
        y = y1[:, None]
    y = y * mask[None, None, :, None]
    y = y.reshape(B, S, Hp * P)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wout"].astype(h.dtype)
    return lc(out, "batch", "seq", None), new_cache


def _ssm_head_mask(cfg: ModelConfig, plan: PadPlan) -> np.ndarray:
    m = np.zeros((plan.ssm_heads_pad,), np.float32)
    m[: cfg.ssm_heads] = 1.0
    return m


def hybrid_block(cfg: ModelConfig, plan: PadPlan, p: Dict[str, Any],
                 x: jax.Array, positions: jax.Array, *,
                 window: int, q_chunk: int) -> jax.Array:
    """Hymba: parallel attention + SSD heads, mean-fused, then MLP."""
    B, S, D = x.shape
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, plan, p, h, positions)
    q = q.reshape(B, S, plan.kv_pad, plan.group, cfg.hd)
    k = L.duplicate_kv(k, plan)
    v = L.duplicate_kv(v, plan)
    hm = jnp.asarray(plan.head_mask(), x.dtype).reshape(plan.kv_pad, plan.group, 1)
    attn = L.attention(q, k, v, pos_q=positions, pos_kv=positions,
                       causal=True, window=window, q_chunk=q_chunk, head_mask=hm)
    a_out = _attn_out(cfg, plan, p, attn, B, S)
    s_out, _ = ssm_mixer(cfg, plan, p["ssm"], h)
    fused = 0.5 * (L.rmsnorm(a_out, p["attn_fuse_norm"], cfg.norm_eps)
                   + L.rmsnorm(s_out, p["ssm_fuse_norm"], cfg.norm_eps))
    x = x + fused
    return mlp_block(cfg, p, x)


# ---------------------------------------------------------------------------
# full forward / loss


def forward(cfg: ModelConfig, plan: PadPlan, params: Dict[str, Any],
            tokens: jax.Array, *, q_chunk: int = 0,
            compute_dtype: Any = jnp.float32,
            n_token_groups: int = 1,
            serve_longctx: bool = False,
            remat: bool = True, scan_unroll: int = 1) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> (hidden (B,S,D), total_aux_loss)."""
    B, S = tokens.shape
    groups = block_groups(cfg, serve_longctx=serve_longctx)
    x = jnp.take(params["embed"].astype(compute_dtype), tokens, axis=0)
    x = lc(x, "batch", "seq_res", None)
    positions = jnp.arange(S, dtype=jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)

    for gi, g in enumerate(groups):
        gp = params[f"g{gi}"]

        def layer_fn(x, pl, g=g):
            if g.kind == "ssm":
                return ssm_block(cfg, plan, pl, x), jnp.zeros((), jnp.float32)
            if g.kind in ("hybrid", "hybrid_swa"):
                return (hybrid_block(cfg, plan, pl, x, positions,
                                     window=g.window, q_chunk=q_chunk),
                        jnp.zeros((), jnp.float32))
            x2 = attn_block(cfg, plan, pl, x, positions,
                            window=g.window, q_chunk=q_chunk)
            if g.kind == "moe":
                x3, aux = moe_block(cfg, plan, pl, x2, n_token_groups)
                return x3, aux
            return mlp_block(cfg, pl, x2), jnp.zeros((), jnp.float32)

        if remat:
            layer_fn = jax.checkpoint(layer_fn)

        def scan_fn(x, pl):
            x2, aux = layer_fn(x, pl)
            return x2, aux

        x, auxes = jax.lax.scan(scan_fn, x, gp, unroll=scan_unroll)
        aux_total = aux_total + jnp.sum(auxes)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def logits_from_hidden(cfg: ModelConfig, plan: PadPlan, params, x: jax.Array
                       ) -> jax.Array:
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(x.dtype))
    logits = lc(logits, "batch", "seq", "vocab")
    if plan.vocab_pad > cfg.vocab_size:
        pad_bias = jnp.where(jnp.arange(plan.vocab_pad) < cfg.vocab_size,
                             0.0, NEG_INF).astype(logits.dtype)
        logits = logits + pad_bias
    return logits


def loss_fn(cfg: ModelConfig, plan: PadPlan, params,
            batch: Dict[str, jax.Array], *, q_chunk: int = 0,
            compute_dtype: Any = jnp.float32, n_token_groups: int = 1,
            loss_chunk: int = 0, remat: bool = True,
            scan_unroll: int = 1) -> jax.Array:
    """Mean next-token cross-entropy + MoE aux, seq-chunked over the vocab
    projection so full (B,S,V) logits are never materialised."""
    tokens, labels = batch["tokens"], batch["labels"]
    x, aux = forward(cfg, plan, params, tokens, q_chunk=q_chunk,
                     compute_dtype=compute_dtype,
                     n_token_groups=n_token_groups, remat=remat,
                     scan_unroll=scan_unroll)
    B, S, D = x.shape
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    unembed = unembed.astype(x.dtype)
    pad_bias = (jnp.where(jnp.arange(plan.vocab_pad) < cfg.vocab_size,
                          0.0, NEG_INF).astype(jnp.float32)
                if plan.vocab_pad > cfg.vocab_size else None)

    def chunk_nll(xc, yc):
        lg = jnp.einsum("btd,dv->btv", xc, unembed,
                        preferred_element_type=jnp.float32)
        lg = lc(lg, "batch", "seq", "vocab")
        if pad_bias is not None:
            lg = lg + pad_bias
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, yc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    if loss_chunk and S > loss_chunk and S % loss_chunk == 0:
        nc = S // loss_chunk
        xr = x.reshape(B, nc, loss_chunk, D)
        yr = labels.reshape(B, nc, loss_chunk)
        chunk_nll_ckpt = jax.checkpoint(chunk_nll)  # logits recomputed in bwd

        def body(tot, i):
            return tot + chunk_nll_ckpt(xr[:, i], yr[:, i]), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                jnp.arange(nc))
    else:
        total = chunk_nll(x, labels)
    nll = total / (B * S)
    return nll + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# serving (decode) path


def init_cache_desc(cfg: ModelConfig, plan: PadPlan, *, batch: int,
                    max_seq: int, serve_longctx: bool = False,
                    dtype: Any = jnp.float32) -> Dict[str, Any]:
    """LeafSpec tree for the decode cache (window-bounded for SWA groups).
    KV/conv caches use ``dtype`` (bf16 in production); the SSD state stays
    float32 — it is a long-lived accumulator."""
    groups = block_groups(cfg, serve_longctx=serve_longctx)
    hd = cfg.hd
    desc: Dict[str, Any] = {}
    for gi, g in enumerate(groups):
        n = g.count
        gdesc: Dict[str, Any] = {}
        if g.kind in ("attn", "swa", "moe", "hybrid", "hybrid_swa"):
            span = min(max_seq, g.window) if g.window else max_seq
            gdesc["k"] = LeafSpec((n, batch, span, plan.kv_pad, hd),
                                  ("layers", "batch", None, "kv_heads", None),
                                  "zeros", dtype)
            gdesc["v"] = LeafSpec((n, batch, span, plan.kv_pad, hd),
                                  ("layers", "batch", None, "kv_heads", None),
                                  "zeros", dtype)
        if g.kind in ("ssm", "hybrid", "hybrid_swa"):
            Hp, P = plan.ssm_heads_pad, cfg.ssm_head_dim
            GN = cfg.ssm_groups * cfg.ssm_state
            K = cfg.ssm_conv
            gdesc["ssm"] = {
                "state": LeafSpec((n, batch, Hp, P, cfg.ssm_state),
                                  ("layers", "batch", "ssm_heads", None, None),
                                  "zeros", jnp.float32),
                "conv_x": LeafSpec((n, batch, K - 1, Hp * P),
                                   ("layers", "batch", None, "inner"), "zeros", dtype),
                "conv_B": LeafSpec((n, batch, K - 1, GN),
                                   ("layers", "batch", None, None), "zeros", dtype),
                "conv_C": LeafSpec((n, batch, K - 1, GN),
                                   ("layers", "batch", None, None), "zeros", dtype),
            }
        desc[f"g{gi}"] = gdesc
    return desc


def _decode_attn(cfg, plan, p, x, kcache, vcache, pos, window):
    """One-token attention against a (possibly ring-buffer) cache.
    kcache/vcache: (B, span, KVp, hd).  Returns (out, new_k, new_v)."""
    B = x.shape[0]
    span = kcache.shape[1]
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, plan, p, h, positions)
    q = q.reshape(B, 1, plan.kv_pad, plan.group, cfg.hd)
    k = L.duplicate_kv(k, plan)
    v = L.duplicate_kv(v, plan)
    write_at = jnp.mod(pos, span) if window else jnp.minimum(pos, span - 1)
    kcache = jax.lax.dynamic_update_slice_in_dim(kcache, k, write_at, axis=1)
    vcache = jax.lax.dynamic_update_slice_in_dim(vcache, v, write_at, axis=1)
    if window:
        # ring buffer: slot s holds absolute position p iff p % span == s
        base = (pos // span) * span
        idx = jnp.arange(span, dtype=jnp.int32)
        pos_kv = jnp.where(idx <= jnp.mod(pos, span), base + idx,
                           base - span + idx)
    else:
        pos_kv = jnp.arange(span, dtype=jnp.int32)
    hm = jnp.asarray(plan.head_mask(), x.dtype).reshape(plan.kv_pad, plan.group, 1)
    attn = L.attention(q, kcache, vcache, pos_q=positions, pos_kv=pos_kv,
                       causal=True, window=window, head_mask=hm,
                       kv_len_valid=None)
    out = _attn_out(cfg, plan, p, attn, B, 1)
    return out, kcache, vcache


def serve_step(cfg: ModelConfig, plan: PadPlan, params,
               cache: Dict[str, Any], tokens: jax.Array, pos: jax.Array,
               *, compute_dtype: Any = jnp.float32,
               serve_longctx: bool = False, n_token_groups: int = 1,
               scan_unroll: int = 1) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step: tokens (B,1) + cache @ pos -> (logits (B,1,V), cache)."""
    B = tokens.shape[0]
    groups = block_groups(cfg, serve_longctx=serve_longctx)
    x = jnp.take(params["embed"].astype(compute_dtype), tokens, axis=0)
    new_cache: Dict[str, Any] = {}

    for gi, g in enumerate(groups):
        gp = params[f"g{gi}"]
        gc = cache[f"g{gi}"]

        def layer_fn(x, packed, g=g):
            pl, cc = packed
            ncc = {}
            if g.kind == "ssm":
                h = L.rmsnorm(x, pl["ln"], cfg.norm_eps)
                y, ssm_cache = ssm_mixer(cfg, plan, pl, h, cache=cc["ssm"])
                ncc["ssm"] = ssm_cache
                return x + y, ncc
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            a_out, nk, nv = _decode_attn(cfg, plan, pl, x, cc["k"], cc["v"],
                                         pos, g.window)
            ncc["k"], ncc["v"] = nk, nv
            if g.kind in ("hybrid", "hybrid_swa"):
                s_out, ssm_cache = ssm_mixer(cfg, plan, pl["ssm"], h,
                                             cache=cc["ssm"])
                ncc["ssm"] = ssm_cache
                fused = 0.5 * (L.rmsnorm(a_out, pl["attn_fuse_norm"], cfg.norm_eps)
                               + L.rmsnorm(s_out, pl["ssm_fuse_norm"], cfg.norm_eps))
                x = x + fused
                return mlp_block(cfg, pl, x), ncc
            x = x + a_out
            if g.kind == "moe":
                x, _ = moe_block(cfg, plan, pl, x, n_token_groups)
                return x, ncc
            return mlp_block(cfg, pl, x), ncc

        def scan_fn(x, packed):
            return layer_fn(x, packed)

        x, ncache = jax.lax.scan(scan_fn, x, (gp, gc), unroll=scan_unroll)
        new_cache[f"g{gi}"] = ncache

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, plan, params, x)
    return logits, new_cache
