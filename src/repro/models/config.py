"""Model configuration + TP padding planner.

``ModelConfig`` captures every assigned architecture (see repro.configs).
``plan_padding`` maps a config onto a tensor-parallel shard count: head
counts, vocab and expert counts are padded to shardable multiples.  Pad
slots are masked to exact zero contribution (head_mask / logit mask /
router mask), so the padded model computes the *same function* as the
unpadded one — the padding waste is visible, by design, in the roofline
MODEL_FLOPS/HLO_FLOPs ratio (DESIGN.md §4).

Head plan: original GQA group size g0 = q0/kv0 must be an integer.  We
duplicate each original KV head ``spo`` times (in compute, not in params)
so kv_pad = shard-aligned, and arrange padded Q slots so that q slot
``s`` attends kv slot ``s // group`` — locality-preserving, so GSPMD
never needs a cross-shard gather inside attention.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"  # silu (gated) | gelu (plain MLP)
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden width
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # --- SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # --- hybrid / attention variants
    swa_window: int = 0          # 0 = full attention everywhere
    global_layers: Tuple[int, ...] = ()  # layer indices using full attn when swa_window>0
    # --- long-context serving variant (dense archs at 500k)
    longctx_window: int = 4096
    # --- encoder-decoder (audio)
    n_enc_layers: int = 0
    enc_seq: int = 0             # frontend-stub sequence length (e.g. 1500 frames)
    # --- provenance
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class PadPlan:
    shard: int                   # model-axis size this plan targets
    q_pad: int
    kv_pad: int
    group: int                   # q_pad == kv_pad * group
    spo: int                     # kv duplication factor (slots per original)
    n_kv_orig: int
    q_slot_of_orig: Tuple[int, ...]   # len q0: padded slot index per orig q head
    vocab_pad: int
    experts_pad: int
    ssm_heads_pad: int

    def head_mask(self) -> np.ndarray:
        """(q_pad,) 1.0 for live q slots, 0.0 for pad slots."""
        m = np.zeros((self.q_pad,), dtype=np.float32)
        for s in self.q_slot_of_orig:
            m[s] = 1.0
        return m

    def kv_dup_index(self) -> np.ndarray:
        """(kv_pad,) original kv head index per padded kv slot (clipped)."""
        idx = np.minimum(np.arange(self.kv_pad) // max(self.spo, 1),
                         self.n_kv_orig - 1)
        return idx.astype(np.int32)


def plan_padding(cfg: ModelConfig, shard: int) -> PadPlan:
    vocab_pad = _ceil_to(cfg.vocab_size, max(shard, 1))
    experts_pad = _ceil_to(cfg.n_experts, shard) if cfg.n_experts else 0
    ssm_heads_pad = _ceil_to(cfg.ssm_heads, shard) if cfg.ssm_state else 0

    if cfg.family == "ssm" or cfg.n_heads == 0:
        return PadPlan(shard=shard, q_pad=0, kv_pad=0, group=1, spo=1,
                       n_kv_orig=0, q_slot_of_orig=(),
                       vocab_pad=vocab_pad, experts_pad=experts_pad,
                       ssm_heads_pad=ssm_heads_pad)

    q0, kv0 = cfg.n_heads, cfg.n_kv_heads
    if q0 % kv0 != 0:
        raise ValueError(f"{cfg.arch_id}: n_heads {q0} not divisible by kv {kv0}")
    g0 = q0 // kv0
    kv_pad = _ceil_to(kv0, shard) if kv0 >= shard else shard
    spo = kv_pad // kv0  # duplication factor (floor; leftover slots are dead)
    group = max(1, math.ceil(g0 / max(spo, 1)))
    q_pad = kv_pad * group
    # place orig q head i (parent p=i//g0, rank r=i%g0) at slot p*spo*group + r
    slots = tuple(int((i // g0) * spo * group + (i % g0)) for i in range(q0))
    assert len(set(slots)) == q0 and max(slots) < q_pad, (cfg.arch_id, slots, q_pad)
    # consistency: slot s uses kv slot s//group which duplicates orig kv
    for i in range(q0):
        assert min(slots[i] // group // max(spo, 1), kv0 - 1) == i // g0, (
            cfg.arch_id, i, slots[i])
    return PadPlan(shard=shard, q_pad=q_pad, kv_pad=kv_pad, group=group, spo=spo,
                   n_kv_orig=kv0, q_slot_of_orig=slots, vocab_pad=vocab_pad,
                   experts_pad=experts_pad, ssm_heads_pad=ssm_heads_pad)
