"""Parameter description/materialisation machinery.

``describe_*`` functions build a pytree of :class:`LeafSpec` — shape,
logical sharding axes, and init recipe — for each architecture.  From one
description we derive (a) real initialised parameters (smoke tests,
examples), (b) ``ShapeDtypeStruct`` stand-ins (the multi-pod dry-run; no
allocation), and (c) ``PartitionSpec`` trees (via parallel.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | a_log | dt_bias | normal:<std>
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leafspec(x) -> bool:
    return isinstance(x, LeafSpec)


def _init_leaf(spec: LeafSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "a_log":  # mamba2: A ~ U[1,16], store log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if spec.init == "dt_bias":  # softplus^-1(U[1e-3, 1e-1])
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(spec.dtype)
    std = 0.02
    if spec.init.startswith("normal:"):
        std = float(spec.init.split(":", 1)[1])
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def init_params(desc: Any, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(desc, is_leaf=is_leafspec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(desc: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), desc, is_leaf=is_leafspec)


def param_axes(desc: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, desc, is_leaf=is_leafspec)


def count_params(desc: Any) -> int:
    return sum(int(np.prod(s.shape)) for s in
               jax.tree.leaves(desc, is_leaf=is_leafspec))
