"""Neural-net building blocks (the paper's Table-1 "NN building blocks"
row, grown to 2026): RMSNorm, RoPE, padded GQA attention (full / chunked
/ sliding-window / decode), gated MLP, sort-based dropped-token MoE, and
the Mamba-2 SSD mixer with chunked scan + O(1) decode.

All functions are pure jnp (the Pallas TPU kernels in repro.kernels are
drop-in replacements for the hot paths and are validated against these).
Softmax/normalization accumulate in float32 regardless of compute dtype.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import logical_constraint as lc
from .config import ModelConfig, PadPlan

# ---------------------------------------------------------------------------
# norms / rope / mlp


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, Dh); positions: (S,) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    # broadcast over any head-like dims between S and Dh
    while cos.ndim < x.ndim:
        cos = cos[..., None, :, :] if False else jnp.expand_dims(cos, -2)
        sin = jnp.expand_dims(sin, -2)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_mlp(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
              act: str = "silu") -> jax.Array:
    """SwiGLU: (x@w1)*silu_or_gelu(x@w3) @ w2; if w3 is None, plain MLP."""
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = x @ w1
    h = lc(h, "batch", "seq", "ff")
    if w3 is not None:
        g = x @ w3
        g = lc(g, "batch", "seq", "ff")
        h = a(g) * h
    else:
        h = a(h)
    out = h @ w2
    return lc(out, "batch", "seq_res", None)


# ---------------------------------------------------------------------------
# attention (padded-GQA layout: q (B,S,KVp,G,Dh), kv (B,T,KVp,Dh))


def _mask_bias(pos_q: jax.Array, pos_kv: jax.Array, causal: bool,
               window: int, kv_len_valid: Optional[jax.Array]) -> jax.Array:
    """(Sq, Skv) additive bias in f32: 0 allowed, -inf masked."""
    ok = pos_kv[None, :] >= 0  # ring-buffer slots not yet written sit at p<0
    ok = jnp.broadcast_to(ok, (pos_q.shape[0], pos_kv.shape[0]))
    if causal:
        ok &= pos_kv[None, :] <= pos_q[:, None]
    if window > 0:
        ok &= pos_kv[None, :] > (pos_q[:, None] - window)
    if kv_len_valid is not None:
        ok &= pos_kv[None, :] < kv_len_valid
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _attn_block(q: jax.Array, k: jax.Array, v: jax.Array,
                bias: jax.Array, head_mask: Optional[jax.Array]) -> jax.Array:
    """q (B,Sq,KV,G,D), k/v (B,Skv,KV,D), bias (Sq,Skv) -> (B,Sq,KV,G,D)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bskgd,btkd->bsktg", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias[None, :, None, :, None]
    p = jax.nn.softmax(s, axis=3)
    # rows that are fully masked (e.g. pre-fill positions in a decode cache)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bsktg,btkd->bskgd", p.astype(v.dtype), v)
    if head_mask is not None:
        o = o * head_mask  # (KV, G) broadcast: zero out pad q slots
    return o


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    pos_q: jax.Array, pos_kv: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 0,
    kv_len_valid: Optional[jax.Array] = None,
    head_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Padded-GQA attention.

    q: (B, Sq, KVp, G, Dh); k, v: (B, Skv, KVp, Dh).
    pos_q (Sq,), pos_kv (Skv,) absolute positions (mask arithmetic).
    window > 0 = sliding-window attention.
    q_chunk > 0 = memory-efficient chunked path (scan over query blocks);
    with a window it also *slices* the kv stream so FLOPs are O(S*window).
    head_mask: (KVp, G) zeros out padded q slots exactly.
    """
    B, Sq, KV, G, Dh = q.shape
    if q_chunk <= 0 or Sq <= q_chunk or Sq % q_chunk != 0:
        # indivisible sequences (e.g. whisper's 1500 encoder frames) take
        # the one-shot path; chunking is a memory optimisation only
        bias = _mask_bias(pos_q, pos_kv, causal, window, kv_len_valid)
        return _attn_block(q, k, v, bias, head_mask)
    n_chunks = Sq // q_chunk

    if window > 0 and window % q_chunk == 0 and k.shape[1] == Sq:
        # sliding-window: slice only the kv band each chunk needs
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        pos_kv_p = jnp.concatenate(
            [jnp.full((pad,), -10**9, dtype=pos_kv.dtype), pos_kv])

        @jax.checkpoint  # flash-attention semantics: recompute scores in bwd
        def chunk_body(i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
            ks = jax.lax.dynamic_slice_in_dim(kp, i * q_chunk, window + q_chunk, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, i * q_chunk, window + q_chunk, axis=1)
            pq = jax.lax.dynamic_slice_in_dim(pos_q, i * q_chunk, q_chunk)
            pk = jax.lax.dynamic_slice_in_dim(pos_kv_p, i * q_chunk, window + q_chunk)
            bias = _mask_bias(pq, pk, causal, window, kv_len_valid)
            return _attn_block(qs, ks, vs, bias, head_mask)

        _, outs = jax.lax.scan(lambda c, i: (c, chunk_body(i)), None,
                               jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, Dh)
        return out

    @jax.checkpoint  # scores never live past the chunk, fwd or bwd
    def chunk_body(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        pq = jax.lax.dynamic_slice_in_dim(pos_q, i * q_chunk, q_chunk)
        bias = _mask_bias(pq, pos_kv, causal, window, kv_len_valid)
        return _attn_block(qs, k, v, bias, head_mask)

    _, outs = jax.lax.scan(lambda c, i: (c, chunk_body(i)), None,
                           jnp.arange(n_chunks))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, Dh)


def duplicate_kv(kv: jax.Array, plan: PadPlan) -> jax.Array:
    """(B,S,kv0,Dh) -> (B,S,kv_pad,Dh) by slot-duplication (compute-side,
    so the parameter count stays faithful to the original architecture)."""
    if plan.kv_pad == plan.n_kv_orig:
        return kv
    idx = jnp.asarray(plan.kv_dup_index())
    out = jnp.take(kv, idx, axis=2)
    return lc(out, "batch", "seq", "kv_heads", None)


# ---------------------------------------------------------------------------
# MoE: sort-based dropped-token dispatch (GShard-style capacity, grouped)


class MoEStats(NamedTuple):
    aux_loss: jax.Array
    frac_dropped: jax.Array


def moe_ffn(
    x: jax.Array,                   # (Gr, T, D) token groups (data-sharded)
    router_w: jax.Array,            # (D, Epad)
    w1: jax.Array, w3: jax.Array, w2: jax.Array,  # (Epad, D, F), (Epad, D, F), (Epad, F, D)
    *,
    n_experts: int,                 # real expert count (<= Epad)
    top_k: int,
    capacity_factor: float,
    act: str = "silu",
) -> Tuple[jax.Array, MoEStats]:
    Gr, T, D = x.shape
    Epad, _, F = w1.shape
    K = top_k
    C = max(1, int(math.ceil(T * K / n_experts * capacity_factor)))

    logits = jnp.einsum("gtd,de->gte", x, router_w,
                        preferred_element_type=jnp.float32)
    if Epad > n_experts:
        pad_bias = jnp.where(jnp.arange(Epad) < n_experts, 0.0, -jnp.inf)
        logits = logits + pad_bias
    probs = jax.nn.softmax(logits, axis=-1)                    # (Gr,T,Epad)
    gate_vals, e_idx = jax.lax.top_k(probs, K)                 # (Gr,T,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- aux load-balance loss (Switch): E * sum_e f_e * P_e
    pe = jnp.mean(probs, axis=(0, 1))                          # (Epad,)
    onehot_top1 = jax.nn.one_hot(e_idx[..., 0], Epad, dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=(0, 1))
    aux = n_experts * jnp.sum(fe * pe)

    # --- per-group sort by expert; rank within expert; capacity drop
    flat_e = e_idx.reshape(Gr, T * K)
    flat_t = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K)).reshape(T * K)
    flat_w = gate_vals.reshape(Gr, T * K)

    order = jnp.argsort(flat_e, axis=1)                        # (Gr, T*K)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = flat_t[order]
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    first = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(Epad)))(se)
    rank = jnp.arange(T * K)[None, :] - jnp.take_along_axis(first, se, axis=1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, Epad * C)            # dropped -> overflow row

    # token index per (expert, capacity) slot; -1 = empty
    slot_to_tok = jnp.full((Gr, Epad * C + 1), -1, dtype=jnp.int32)
    slot_to_tok = jax.vmap(lambda s2t, sl, t: s2t.at[sl].set(t))(
        slot_to_tok, slot, jnp.broadcast_to(st, slot.shape).astype(jnp.int32))
    slot_to_tok = slot_to_tok[:, :-1]                          # (Gr, Epad*C)

    gathered = jnp.where(
        slot_to_tok[..., None] >= 0,
        jnp.take_along_axis(
            x, jnp.maximum(slot_to_tok, 0)[..., None], axis=1),
        0.0).reshape(Gr, Epad, C, D)
    gathered = lc(gathered, "groups", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", gathered, w1)
    if w3 is not None:
        g = jnp.einsum("gecd,edf->gecf", gathered, w3)
        afn = jax.nn.silu if act == "silu" else jax.nn.gelu
        h = afn(g) * h
    else:
        h = (jax.nn.silu if act == "silu" else jax.nn.gelu)(h)
    y_e = jnp.einsum("gecf,efd->gecd", h, w2)                  # (Gr,Epad,C,D)
    y_e = lc(y_e, "groups", "experts", None, None)

    # --- combine: scatter-add weighted expert outputs back to tokens
    y_flat = y_e.reshape(Gr, Epad * C, D)
    w_slot = jnp.zeros((Gr, Epad * C + 1), dtype=jnp.float32)
    w_slot = jax.vmap(lambda ws, sl, w: ws.at[sl].set(w))(
        w_slot, slot, jnp.where(keep, sw, 0.0))
    w_slot = w_slot[:, :-1]
    contrib = y_flat * w_slot[..., None].astype(y_flat.dtype)
    out = jax.vmap(
        lambda o, t, c: o.at[jnp.maximum(t, 0)].add(
            jnp.where(t[:, None] >= 0, c, 0.0)))(
        jnp.zeros((Gr, T, D), dtype=x.dtype), slot_to_tok, contrib)
    out = lc(out, "groups", None, None)

    dropped = 1.0 - jnp.sum(jnp.where(keep, 1.0, 0.0)) / (Gr * T * K)
    return out, MoEStats(aux_loss=aux, frac_dropped=dropped)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality): chunked train scan + O(1) decode


def causal_conv1d(x: jax.Array, w: jax.Array,
                  cache: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv.  x (B,S,Cch), w (Cch,K).
    cache (B,K-1,Cch) for decode; returns (y, new_cache)."""
    B, S, Cch = x.shape
    K = w.shape[1]
    if cache is not None:
        win = jnp.concatenate([cache, x], axis=1)      # (B, K-1+S, C)
        new_cache = win[:, -(K - 1):, :]
        xp = win
    else:
        new_cache = None
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jax.lax.conv_general_dilated(
        xp, w.T[:, None, :],                            # (K,1,C) WIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=Cch)
    return y, new_cache


def ssd_chunked(
    x: jax.Array,        # (B,S,H,P)
    dt: jax.Array,       # (B,S,H) post-softplus
    A_log: jax.Array,    # (H,)
    B_: jax.Array,       # (B,S,G,N)
    C_: jax.Array,       # (B,S,G,N)
    D: jax.Array,        # (H,)
    *,
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B,H,P,N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Dao & Gu 2024): intra-chunk quadratic attention-
    like term + inter-chunk recurrent state pass.  Returns (y, final_state).
    """
    Bb, S, H, Pp = x.shape
    G, N = B_.shape[2], B_.shape[3]
    if S % chunk != 0:  # shrink to the largest divisor (correctness first)
        chunk = next(d for d in range(min(chunk, S), 0, -1) if S % d == 0)
    NC, Q = S // chunk, chunk
    rep = H // G

    a = -jnp.exp(A_log.astype(jnp.float32))              # (H,)
    dA = dt.astype(jnp.float32) * a                       # (B,S,H)
    dAc = dA.reshape(Bb, NC, Q, H)
    xc = x.reshape(Bb, NC, Q, H, Pp)
    dtc = dt.reshape(Bb, NC, Q, H).astype(jnp.float32)
    Bh = jnp.repeat(B_, rep, axis=2).reshape(Bb, NC, Q, H, N).astype(jnp.float32)
    Ch = jnp.repeat(C_, rep, axis=2).reshape(Bb, NC, Q, H, N).astype(jnp.float32)

    cs = jnp.cumsum(dAc, axis=2)                          # (B,NC,Q,H)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (B,NC,Q,T,H)
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    att = jnp.einsum("bcqhn,bcthn->bcqth", Ch, Bh) * L * dtc[:, :, None, :, :]
    xf = xc.astype(jnp.float32)
    y_intra = jnp.einsum("bcqth,bcthp->bcqhp", att, xf)

    # chunk state contributions: S_c = sum_t exp(cs_end - cs_t) dt_t B_t x_t
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)         # (B,NC,Q,H)
    Sc = jnp.einsum("bcthn,bcth,bcthp->bchpn",
                    Bh, dtc * decay_to_end, xf)           # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                # (B,NC,H)

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bb, H, Pp, N), jnp.float32))

    def scan_fn(h, inputs):
        sc, cd = inputs                                   # (B,H,P,N), (B,H)
        h_new = h * cd[:, :, None, None] + sc
        return h_new, h                                   # emit state BEFORE chunk

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                  # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Ch * jnp.exp(cs)[..., None], h_prev)
    y = (y_intra + y_inter).reshape(Bb, S, H, Pp)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final.astype(x.dtype)


def ssd_decode_step(
    x: jax.Array,       # (B,H,P)
    dt: jax.Array,      # (B,H)
    A_log: jax.Array,   # (H,)
    B_: jax.Array,      # (B,G,N)
    C_: jax.Array,      # (B,G,N)
    D: jax.Array,       # (H,)
    state: jax.Array,   # (B,H,P,N)
) -> Tuple[jax.Array, jax.Array]:
    H = x.shape[1]
    rep = H // B_.shape[1]
    Bh = jnp.repeat(B_, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1).astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * a)               # (B,H)
    xf = x.astype(jnp.float32)
    new_state = (state.astype(jnp.float32) * dA[:, :, None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32), xf, Bh))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state) + D[None, :, None] * xf
    return y.astype(x.dtype), new_state.astype(state.dtype)
