from .config import ModelConfig, PadPlan, plan_padding

__all__ = ["ModelConfig", "PadPlan", "plan_padding"]
