"""Evaluation substrate: perplexity + accuracy over a data pipeline.

Evaluation is a Session.Run of the loss subgraph with learning turned
off — exactly the paper's §6 lesson 3 ("always ensure the objective
matches between systems when learning is turned off"), which is also how
tests compare the eager and compiled paths.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def perplexity_eval(model, params, batches: Iterator[Dict[str, Any]], *,
                    max_batches: int = 16, loss_kw: Optional[Dict] = None
                    ) -> Dict[str, float]:
    """Mean token NLL + perplexity over up to ``max_batches`` batches."""
    loss_kw = dict(loss_kw or {})
    loss_fn = jax.jit(lambda p, b: model.loss_fn(p, b, **loss_kw))
    total_nll, n = 0.0, 0
    for i, raw in enumerate(batches):
        if i >= max_batches:
            break
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        total_nll += float(loss_fn(params, batch))
        n += 1
    nll = total_nll / max(n, 1)
    return {"nll": nll, "perplexity": math.exp(min(nll, 30.0)), "batches": n}


def token_accuracy(model, params, batch: Dict[str, Any], *,
                   fwd_kw: Optional[Dict] = None) -> float:
    """Greedy next-token accuracy (teacher-forced)."""
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    logits = model.forward_logits(params, batch, **(fwd_kw or {}))
    pred = jnp.argmax(logits[..., : model.cfg.vocab_size], axis=-1)
    return float(jnp.mean((pred == batch["labels"]).astype(jnp.float32)))
