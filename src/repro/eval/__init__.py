from .metrics import perplexity_eval, token_accuracy

__all__ = ["perplexity_eval", "token_accuracy"]
