"""Optimizers, functional + as graph nodes.

The paper's design point: parameter updates are *just more nodes in the
graph* operating on Variables — no separate parameter-server subsystem
(§11, "a significant simplification").  ``attach_train_op`` realises that:
given a Session graph with a loss node and parameter Variables, it extends
the graph with §4.1 gradients, optimizer-state Variables, and Assign
update nodes, returning the train_op group node.

The functional forms (``*_init`` / ``*_update``) are pure pytree->pytree
and are what the compiled/pjit path fuses into the step function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.graph import Node, TensorRef
from ..core.ops import GraphBuilder
from ..core import autodiff


class OptState(NamedTuple):
    step: jax.Array
    m: Any  # first moment / momentum (pytree like params, or ())
    v: Any  # second moment (pytree like params, or ())


# --- SGD ---------------------------------------------------------------------

def sgd_init(params) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32), m=(), v=())


def sgd_update(params, grads, state: OptState, *, lr: float = 1e-2,
               **_) -> Tuple[Any, OptState]:
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    return new_params, OptState(step=state.step + 1, m=(), v=())


# --- SGD + momentum -----------------------------------------------------------

def momentum_init(params) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(jnp.zeros_like, params), v=())


def momentum_update(params, grads, state: OptState, *, lr: float = 1e-2,
                    momentum: float = 0.9, **_) -> Tuple[Any, OptState]:
    new_m = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state.m, grads)
    new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
    return new_params, OptState(step=state.step + 1, m=new_m, v=())


# --- AdamW --------------------------------------------------------------------

def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: OptState, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0) -> Tuple[Any, OptState]:
    step = state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        p2 = p.astype(jnp.float32) - lr * (update + weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, OptState(step=step, m=new_m, v=new_v)


_OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "sgd": (sgd_init, sgd_update),
    "momentum": (momentum_init, momentum_update),
    "adamw": (adamw_init, adamw_update),
}


def make_optimizer(name: str, **hparams):
    init, update = _OPTIMIZERS[name]

    def bound_update(params, grads, state):
        return update(params, grads, state, **hparams)

    return init, bound_update


# ---------------------------------------------------------------------------
# Graph integration: "updates are just nodes" (§2 Variables / §11).


def attach_train_op(
    b: GraphBuilder,
    loss: "Node | TensorRef",
    param_vars: Sequence[Node],
    optimizer: str = "sgd",
    name: str = "train",
    **hparams,
) -> Node:
    """Extend the graph with gradients + optimizer update nodes.

    Returns a NoOp group node; fetching it runs one optimization step.
    Optimizer state lives in per-parameter Variables in the same graph.
    """
    g = b.graph
    grad_refs = autodiff.gradients(g, [loss], list(param_vars))
    init_fn, update_fn = make_optimizer(optimizer, **hparams)

    step_var = b.variable(f"{name}/step", init_value=lambda: jnp.zeros((), jnp.int32))
    new_step = b.assign_add(step_var, b.constant(jnp.ones((), jnp.int32), name=f"{name}/one"))
    updates = [new_step]

    for pv, gref in zip(param_vars, grad_refs):
        if gref is None:
            raise ValueError(f"loss does not depend on variable {pv.name}")
        slots: Dict[str, Node] = {}

        def zeros_like_param(pv=pv):
            init = pv.attrs.get("init")
            if init is None:
                raise ValueError(f"variable {pv.name} needs an init for optimizer slots")
            val = init() if callable(init) else init
            return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), val)

        for slot in {"momentum": ("m",), "adamw": ("m", "v")}.get(optimizer, ()):
            svar = b.variable(f"{name}/{pv.name}/{slot}", init_value=zeros_like_param)
            svar.attrs["colocate_with"] = pv.name  # §4.3: state lives with its param
            slots[slot] = svar

        if optimizer == "sgd":
            def sgd_node(p, g, s, lr=hparams.get("lr", 1e-2)):
                return p - lr * g
            newp = b.call(sgd_node, [pv, gref, step_var], name=f"{name}/{pv.name}/newp")
            updates.append(b.assign(pv, newp))
        elif optimizer == "momentum":
            mu = hparams.get("momentum", 0.9)
            lr = hparams.get("lr", 1e-2)
            mvar = slots["m"]

            def mom_node(p, g, m, mu=mu, lr=lr):
                m2 = mu * m + g
                return p - lr * m2, m2
            res = b.call(mom_node, [pv, gref, mvar], name=f"{name}/{pv.name}/mom", n_out=2)
            updates.append(b.assign(pv, res.output(0)))
            updates.append(b.assign(mvar, res.output(1)))
        elif optimizer == "adamw":
            lr = hparams.get("lr", 3e-4)
            b1 = hparams.get("b1", 0.9)
            b2 = hparams.get("b2", 0.95)
            eps = hparams.get("eps", 1e-8)
            wd = hparams.get("weight_decay", 0.0)
            mvar, vvar = slots["m"], slots["v"]

            def adamw_node(p, g, m, v, t, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd):
                t = t.astype(jnp.float32)
                g = g.astype(jnp.float32)
                m2 = b1 * m + (1 - b1) * g
                v2 = b2 * v + (1 - b2) * g * g
                upd = (m2 / (1 - b1 ** t)) / (jnp.sqrt(v2 / (1 - b2 ** t)) + eps)
                p2 = p.astype(jnp.float32) - lr * (upd + wd * p.astype(jnp.float32))
                return p2.astype(p.dtype), m2, v2
            res = b.call(adamw_node, [pv, gref, mvar, vvar, new_step],
                         name=f"{name}/{pv.name}/adamw", n_out=3)
            updates.append(b.assign(pv, res.output(0)))
            updates.append(b.assign(mvar, res.output(1)))
            updates.append(b.assign(vvar, res.output(2)))
        else:
            raise ValueError(f"unknown optimizer {optimizer!r}")
    return b.group(updates, name=f"{name}/op")
