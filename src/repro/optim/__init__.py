from .optimizers import (OptState, sgd_init, sgd_update, momentum_init,
                         momentum_update, adamw_init, adamw_update,
                         make_optimizer, attach_train_op)

__all__ = ["OptState", "sgd_init", "sgd_update", "momentum_init",
           "momentum_update", "adamw_init", "adamw_update", "make_optimizer",
           "attach_train_op"]
