"""Mamba-2 SSD chunked scan as a Pallas kernel.

Grid (B*H, n_chunks) with the chunk dimension innermost/sequential; the
(N, P) recurrent state lives in f32 VMEM scratch across chunks.  Each
step computes the intra-chunk quadratic term (Q×Q attention-like matmul
on the MXU), the inter-chunk contribution from the carried state, and
the state update — one HBM pass over x/dt/B/C per layer, which is the
TPU-native shape of the SSD algorithm (DESIGN.md: recurrent-scan
blocking for VMEM instead of the paper's CUDA warp layout).

Layouts (heads folded): x (BH, S, P), dt (BH, S), Bc/Cc (BH, S, N),
A (BH,) negative decay rate per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q,)
    a = a_ref[0].astype(jnp.float32)        # scalar (negative)
    B = b_ref[0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0].astype(jnp.float32)        # (Q, N)

    dA = dt * a                             # (Q,)
    cs = jnp.cumsum(dA)                     # (Q,)
    # intra-chunk: att[q,t] = C_q·B_t * exp(cs_q - cs_t) * dt_t, t<=q
    seg = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    att = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    att = att * L * dt[None, :]
    y = jax.lax.dot(att, x, preferred_element_type=jnp.float32)

    # inter-chunk: y += (C * exp(cs)) @ state   (state: (N, P))
    y += jax.lax.dot(C * jnp.exp(cs)[:, None], state_scr[...],
                     preferred_element_type=jnp.float32)

    # state update: state = exp(cs_end) * state + sum_t w_t B_t^T x_t
    cs_end = cs[chunk - 1]
    w = dt * jnp.exp(cs_end - cs)           # (Q,)
    Bw = B * w[:, None]                     # (Q, N)
    state_scr[...] = (jnp.exp(cs_end) * state_scr[...]
                      + jax.lax.dot_general(
                          Bw, x, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                    Bc: jax.Array, Cc: jax.Array, *, chunk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """x (BH,S,P), dt (BH,S), a (BH,), Bc/Cc (BH,S,N) -> y (BH,S,P)."""
    BH, S, P = x.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1,), lambda bh, c: (bh,)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, Bc, Cc)
