"""Blocked MXU matmul: (M,K) @ (K,N) with (bm, bn, bk) VMEM tiles.

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) grid dimension,
so the f32 VMEM accumulator scratch persists across K steps and the
output tile is written once on the last step.  Tile defaults are MXU-
aligned (multiples of 128 on the contracting/lane dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = False) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
