"""Fused RMSNorm kernel: rows stay in VMEM through square/mean/scale.

x (R, D) is tiled (br, D) — the full feature dim lives in VMEM so the
reduction is one pass; weight w (D,) is broadcast to every row tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype) * w_ref[...].astype(o_ref.dtype))


@functools.partial(jax.jit, static_argnames=("br", "eps", "interpret"))
def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, br: int = 256,
                   eps: float = 1e-5, interpret: bool = False) -> jax.Array:
    R, D = x.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, w)
