"""§5.5 lossy 32->16-bit mantissa-truncation compression as a Pallas
elementwise bit-twiddling kernel (the Send-path compression op).

Tiles are (8, 128) — the TPU vreg shape — over a 2-D view of the input.
``compress`` emits the uint16 wire format; ``decompress`` zero-fills.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compress_kernel(x_ref, o_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    o_ref[...] = (bits >> 16).astype(jnp.uint16)


def _decompress_kernel(w_ref, o_ref):
    bits = w_ref[...].astype(jnp.uint32) << 16
    o_ref[...] = jax.lax.bitcast_convert_type(bits, jnp.float32)


def _tile2d(n: int, rows: int = 8, cols: int = 128):
    per = rows * cols
    assert n % per == 0, (n, per)
    return n // per, rows, cols


@functools.partial(jax.jit, static_argnames=("interpret",))
def compress16_pallas(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    shape = x.shape
    n = x.size
    blocks, r, c = _tile2d(n)
    x2 = x.astype(jnp.float32).reshape(blocks * r, c)
    out = pl.pallas_call(
        _compress_kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks * r, c), jnp.uint16),
        interpret=interpret,
    )(x2)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decompress16_pallas(w: jax.Array, *, interpret: bool = False) -> jax.Array:
    shape = w.shape
    n = w.size
    blocks, r, c = _tile2d(n)
    w2 = w.reshape(blocks * r, c)
    out = pl.pallas_call(
        _decompress_kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks * r, c), jnp.float32),
        interpret=interpret,
    )(w2)
    return out.reshape(shape)
