"""Public jit'd wrappers over the Pallas kernels.

These are the drop-in replacements for the model-layer hot paths; on a
real TPU they run compiled, in tests they run interpret=True against the
ref.py oracles.  ``flash_attention_gqa`` adapts the model's padded-GQA
layout (B,S,KVp,G,Dh) to the kernel's folded-head layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas
from .rmsnorm import rmsnorm_pallas
from .flash_attention import flash_attention_pallas
from .ssd_scan import ssd_scan_pallas
from .compress16 import compress16_pallas, decompress16_pallas


def matmul(a, b, *, interpret: bool = False):
    return matmul_pallas(a, b, interpret=interpret)


def rmsnorm(x, w, *, eps: float = 1e-5, interpret: bool = False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    return rmsnorm_pallas(x2, w, eps=eps, interpret=interpret).reshape(shape)


def attention(q, kT, v, *, scale=None, interpret: bool = False):
    """2-D single-head attention over the graph idiom:
    ``softmax(q @ kT * scale) @ v`` with q (S,D), kT (D,T), v (T,D).

    The flash kernel bakes 1/sqrt(D) and takes (BH, S, D) k/v — fold the
    graph's scale into q (cancelling the baked one) and adapt layouts.
    """
    S, D = q.shape
    T = kT.shape[1]
    sc = (1.0 if scale is None else scale) * (D ** 0.5)
    qf = (q * jnp.asarray(sc, q.dtype)).reshape(1, S, D)
    kf = kT.T.reshape(1, T, D)
    vf = v.reshape(1, T, v.shape[-1])
    return flash_attention_pallas(qf, kf, vf, causal=False, window=0,
                                  interpret=interpret)[0]


def flash_attention_gqa(q, k, v, *, causal=True, window=0,
                        head_mask=None, interpret: bool = False):
    """q (B,S,KVp,G,Dh), k/v (B,T,KVp,Dh) — the models.layers layout."""
    B, S, KV, G, Dh = q.shape
    T = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, S, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * KV, T, Dh), G, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * KV, T, Dh), G, axis=0)
    of = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                interpret=interpret)
    out = of.reshape(B, KV, G, S, Dh).transpose(0, 3, 1, 2, 4)
    if head_mask is not None:
        out = out * head_mask
    return out


def ssd_scan(x, dt, A_log, Bc, Cc, D_skip, *, chunk: int = 128,
             interpret: bool = False):
    """models.layers ssd layout: x (B,S,H,P), dt (B,S,H), A_log (H,),
    Bc/Cc (B,S,G,N), D_skip (H,) -> y (B,S,H,P)."""
    B, S, H, P = x.shape
    Gr, N = Bc.shape[2], Bc.shape[3]
    rep = H // Gr
    a = -jnp.exp(A_log.astype(jnp.float32))
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    af = jnp.tile(a, (B,))
    Bf = jnp.repeat(Bc, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Cf = jnp.repeat(Cc, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y = ssd_scan_pallas(xf, dtf, af, Bf, Cf, chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y + D_skip.astype(y.dtype)[None, None, :, None] * x


def compress16(x, *, interpret: bool = False):
    return compress16_pallas(x, interpret=interpret)


def decompress16(w, *, interpret: bool = False):
    return decompress16_pallas(w, interpret=interpret)


# ---------------------------------------------------------------------------
# §2 kernel registration: the Pallas kernels ARE the TPU kernels for the
# corresponding graph ops ("A kernel is a particular implementation of an
# operation that can be run on a particular type of device").


def register_tpu_kernels(interpret: bool = False) -> None:
    """Install Pallas implementations as the 'tpu' kernels of the core ops.

    With ``interpret=True`` the same registration works on CPU (tests) —
    the executor picks them whenever a node is placed on a tpu device.
    """
    from ..core.ops import register_kernel

    @register_kernel("MatMul", "tpu")
    def _matmul_tpu(ctx, node, a, b):
        return (matmul(a, b, interpret=interpret),)
