"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q (BH,Sq,D), k/v (BH,Skv,D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > (qpos - window)
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bqt,btd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                 Bc: jax.Array, Cc: jax.Array) -> jax.Array:
    """Sequential SSD recurrence.  x (BH,S,P), dt (BH,S), a (BH,),
    Bc/Cc (BH,S,N) -> y (BH,S,P)."""
    BH, S, P = x.shape
    N = Bc.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp               # (BH,P),(BH,),(BH,N),(BH,N)
        dA = jnp.exp(dtt * a)               # (BH,)
        state = (state * dA[:, None, None]
                 + jnp.einsum("b,bn,bp->bnp", dtt, Bt, xt))
        y = jnp.einsum("bn,bnp->bp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cc, 1, 0).astype(jnp.float32))
    state0 = jnp.zeros((BH, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def compress16_ref(x: jax.Array) -> jax.Array:
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return (bits >> 16).astype(jnp.uint16)


def decompress16_ref(w: jax.Array) -> jax.Array:
    bits = w.astype(jnp.uint32) << 16
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_valid: jax.Array) -> jax.Array:
    """q (BH,D), k/v (BH,T,D), kv_valid (BH,) -> (BH,D)."""
    T = k.shape[1]
    s = jnp.einsum("bd,btd->bt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    mask = jnp.arange(T)[None, :] < kv_valid[:, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bt,btd->bd", p, v.astype(jnp.float32)).astype(q.dtype)
