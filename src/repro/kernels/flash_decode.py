"""Flash-decode: one query token against a long KV cache.

The decode-path hot spot (decode_32k / long_500k): for each (batch, head)
a single query attends T cached keys.  K/V stream HBM->VMEM in bkv
blocks; the online-softmax state (m, l, acc) lives in VMEM scratch across
the KV sweep, and a per-row valid length masks unwritten cache slots —
matching the serve-path semantics of models.lm._decode_attn.

Layouts (heads folded): q (BH, D), k/v (BH, T, D), kv_valid (BH,) int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bkv: int, n_kv: int,
                   scale: float):
    i_kv = pl.program_id(1)

    @pl.when(i_kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (D,)
    k = k_ref[0].astype(jnp.float32)            # (bkv, D)
    v = v_ref[0].astype(jnp.float32)
    valid = valid_ref[0]

    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # (bkv,)
    k_pos = i_kv * bkv + jax.lax.iota(jnp.int32, bkv)
    s = jnp.where(k_pos < valid, s, NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                       # (bkv,)
    # fully-masked blocks: exp(NEG_INF - NEG_INF) = 1 must not count
    p = jnp.where(k_pos < valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[0, 0] = l_scr[0, 0] * corr + jnp.sum(p)
    acc_scr[0] = acc_scr[0] * corr + jnp.dot(p, v,
                                             preferred_element_type=jnp.float32)
    m_scr[0, 0] = m_new

    @pl.when(i_kv == n_kv - 1)
    def _done():
        l = l_scr[0, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[0] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        kv_valid: jax.Array, *, bkv: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q (BH, D); k, v (BH, T, D); kv_valid (BH,) -> (BH, D)."""
    BH, D = q.shape
    T = k.shape[1]
    bkv = min(bkv, T)
    assert T % bkv == 0, (T, bkv)
    n_kv = T // bkv
    scale = 1.0 / math.sqrt(D)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bkv=bkv, n_kv=n_kv, scale=scale),
        grid=(BH, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ik: (bh,)),
            pl.BlockSpec((1, D), lambda bh, ik: (bh, 0)),
            pl.BlockSpec((1, bkv, D), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bkv, D), lambda bh, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda bh, ik: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_valid, q, k, v)
