"""Flash attention (causal / sliding-window) — online-softmax Pallas kernel.

Layout: q, k, v are (BH, S, D) with heads folded into the leading dim
(the GQA expansion happens in the ops.py wrapper).  Grid is
(BH, S/bq, T/bkv) with the KV dimension innermost, so the running
(m, l, acc) state lives in VMEM scratch across the KV sweep — K/V stream
HBM->VMEM block by block and the (bq, bkv) score tile never leaves VMEM,
which is exactly the memory-term win the §Roofline baseline attributes
to attention score traffic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bkv: int, n_kv: int, causal: bool, window: int,
                  scale: float):
    i_q = pl.program_id(1)
    i_kv = pl.program_id(2)

    @pl.when(i_kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (bq, D)
    k = k_ref[0].astype(jnp.float32)           # (bkv, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = i_q * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = i_kv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), dtype=bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > (q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                        # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 must not count
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i_kv == n_kv - 1)
    def _done():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (BH, Sq, D); k, v (BH, Skv, D) -> (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, Skv, bq, bkv)
    n_kv = Skv // bkv
    scale = 1.0 / math.sqrt(D)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bkv=bkv, n_kv=n_kv,
                          causal=causal, window=window, scale=scale),
        grid=(BH, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bkv, D), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bkv, D), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
