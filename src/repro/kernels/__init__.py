"""Pallas TPU kernels for the compute hot spots (§5.4 "optimized
libraries for kernel implementations", done TPU-native).

Each kernel module exposes ``<name>_pallas(..., interpret=False)``;
``ops.py`` has the jit'd public wrappers and ``ref.py`` the pure-jnp
oracles the tests assert against (interpret=True on CPU).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
