"""Continuous-batching serving layer on the paper's substrate.

Requests arrive through a §4.6 FIFO queue; a fixed pool of batch *slots*
shares one jitted serve step (cache batch dim = n_slots).  Each decode
step every live slot advances one token; finished slots are immediately
refilled from the queue (continuous batching, the standard production
serving discipline).  Per-slot positions are tracked host-side and the
whole-batch step uses per-slot position masking, so slots at different
depths coexist in one cache.

This requires per-slot decode positions, which the single-``pos`` serve
step doesn't expose — so the batcher drives the model with a vmapped
single-sequence step over the slot axis.  Sampling: greedy or
temperature.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from ..models.params import init_params
from ..obs import metrics as obs_metrics
from ..runtime.queues import FIFOQueue, QueueClosed


def _slot_step_for(model: Model):
    """Prepared-step reuse (the serving-side analogue of the Session's
    Executable cache, DESIGN.md §5): restarting or multiplying batchers
    over one model reuses the traced/jitted vmapped slot step instead of
    re-tracing it.  The step is cached on the model instance itself so
    its lifetime tracks the model — nothing is pinned process-wide."""
    step = getattr(model, "_batcher_slot_step", None)
    if step is not None:
        return step

    def one_slot_step(params, cache, token, pos):
        logits, new_cache = model.serve_step(params, cache, token[None, :], pos)
        return logits[0], new_cache

    step = jax.jit(jax.vmap(one_slot_step, in_axes=(None, 0, 0, 0)))
    model._batcher_slot_step = step
    return step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    prompt_len: int
    steps: int
    latency_s: float


class ContinuousBatcher:
    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_seq: int = 256, seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.queue: FIFOQueue = FIFOQueue(capacity=64, name="requests")
        self.results: Dict[int, RequestResult] = {}
        self._key = jax.random.PRNGKey(seed)

        cdesc = model.init_cache_desc(batch=1, max_seq=max_seq)
        self._empty_cache = init_params(cdesc, jax.random.PRNGKey(1))
        # slot-stacked cache: add a leading slot axis via vmap-compatible stack
        self.cache = jax.tree.map(
            lambda x: jnp.stack([x] * n_slots), self._empty_cache)

        # params is an explicit argument (vmap in_axes=None), so the jitted
        # step is shared across batcher instances serving the same model
        self._step = _slot_step_for(model)

        # host-side slot state
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int64)
        self.slot_pending: List[List[int]] = [[] for _ in range(n_slots)]
        self.slot_out: List[List[int]] = [[] for _ in range(n_slots)]
        self.slot_t0 = np.zeros(n_slots)
        self.slot_steps = np.zeros(n_slots, dtype=np.int64)
        self.stats = {"steps": 0, "slot_tokens": 0, "idle_slot_tokens": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.enqueue(req)

    def _reset_slot_cache(self, s: int) -> None:
        self.cache = jax.tree.map(
            lambda full, empty: full.at[s].set(empty),
            self.cache, self._empty_cache)

    def _try_fill_slots(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None:
                continue
            if self.queue.size() == 0:
                continue
            try:
                req = self.queue.dequeue()
            except (TimeoutError, QueueClosed):
                return
            self.slot_req[s] = req
            self.slot_pos[s] = 0
            self.slot_pending[s] = list(req.prompt)
            self.slot_out[s] = []
            self.slot_t0[s] = time.time()
            self.slot_steps[s] = 0
            self._reset_slot_cache(s)

    def _live(self) -> List[int]:
        return [s for s in range(self.n_slots) if self.slot_req[s] is not None]

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Advance every live slot one token; returns #completed requests."""
        self._try_fill_slots()
        live = self._live()
        if not live:
            return 0

        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for s in live:
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s][0]
            elif self.slot_out[s]:
                tokens[s, 0] = self.slot_out[s][-1]
            else:
                tokens[s, 0] = 0
        positions = jnp.asarray(self.slot_pos.astype(np.int32))

        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens), positions)
        self.stats["steps"] += 1
        self.stats["slot_tokens"] += len(live)
        self.stats["idle_slot_tokens"] += self.n_slots - len(live)

        done = 0
        logits_np = np.asarray(logits[:, 0, :])
        for s in live:
            req = self.slot_req[s]
            self.slot_pos[s] += 1
            self.slot_steps[s] += 1
            if self.slot_pending[s]:
                self.slot_pending[s].pop(0)
                if self.slot_pending[s]:
                    continue  # still prefilling
            # sample the next token from this step's logits
            v = logits_np[s, : self.model.cfg.vocab_size]
            if req.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                tok = int(jax.random.categorical(
                    sub, jnp.asarray(v) / req.temperature))
            else:
                tok = int(np.argmax(v))
            self.slot_out[s].append(tok)
            finished = (len(self.slot_out[s]) >= req.max_new_tokens
                        or (req.eos_id is not None and tok == req.eos_id)
                        or self.slot_pos[s] >= self.max_seq - 1)
            if finished:
                latency = time.time() - self.slot_t0[s]
                self.results[req.rid] = RequestResult(
                    rid=req.rid, tokens=list(self.slot_out[s]),
                    prompt_len=len(req.prompt),
                    steps=int(self.slot_steps[s]),
                    latency_s=latency)
                # §16.4: request latency lands in the process registry so
                # serve.py (and the metrics_snapshot RPC) can report
                # p50/p99 without reaching into batcher internals
                obs_metrics.histogram("serving.request_latency_s").observe(
                    latency)
                obs_metrics.counter("serving.requests_completed").inc()
                self.slot_req[s] = None
                done += 1
        return done

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, RequestResult]:
        for _ in range(max_steps):
            self.step()
            if self.queue.size() == 0 and not self._live():
                break
        return self.results

    def occupancy(self) -> float:
        tot = self.stats["slot_tokens"] + self.stats["idle_slot_tokens"]
        return self.stats["slot_tokens"] / tot if tot else 0.0
