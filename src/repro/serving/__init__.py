from .batcher import Request, RequestResult, ContinuousBatcher

__all__ = ["Request", "RequestResult", "ContinuousBatcher"]
