"""Session configuration as one object: :class:`SessionOptions`.

Historically each knob was its own ``Session(...)`` kwarg resolving its own
``REPRO_*`` environment variable inline in ``Session.__init__``.  This
module consolidates them (DESIGN.md §15) with ONE documented resolution
order, applied field-by-field when :meth:`SessionOptions.resolve` runs:

  1. an explicit value on the ``SessionOptions`` (legacy ``Session``
     kwargs fold into the options object via a deprecation shim first),
  2. the field's ``REPRO_*`` environment variable,
  3. the built-in default.

Env-backed fields and their variables:

  ===============  =====================  ============
  field            env var                default
  ===============  =====================  ============
  ``verify``       ``REPRO_VERIFY``       ``"warn"``
  ``fuse_regions`` ``REPRO_FUSE_REGIONS`` ``True``
  ``numerics``     ``REPRO_FUSE_NUMERICS``  ``"strict"``
  ``parity_guard`` ``REPRO_NUMERICS_GUARD`` ``"1"``
  ``backend``      ``REPRO_KERNEL_BACKEND`` ``"generic"``
  ``trace_dir``    ``REPRO_TRACE``        ``None``
  ===============  =====================  ============

Two further ``REPRO_*`` variables stay *process*-scoped by design and
are therefore not Session options: ``REPRO_REGION_CACHE`` (fusion's
on-disk region cache, repro.core.fusion) and ``REPRO_FAULTS`` (worker
fault injection, repro.distrib.faults) configure a process, not a
session.

``RunSignature.for_session`` derives every options-dependent component of
the Executable cache key from the resolved options object in one place —
flipping any field above can never reuse a stale Executable.  The one
deliberate exception is ``trace_dir``: tracing observes the compiled
artifact rather than changing it (DESIGN.md §16), so it is NOT part of
the cache key — turning the EEG on never forces a rebuild.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Tuple

_TRUTHY_OFF = ("0", "false", "off")


def parse_guard(value) -> Tuple[bool, Optional[int]]:
    """Parity-guard policy -> (enabled, sample_every).

    ``True``/``"1"`` verify the first run only; ``"sample:N"`` (or an int
    N > 1) additionally re-verifies every Nth run — the opt-in sampling
    mode for long-lived serving processes where input distribution shift
    could expose drift the first batch didn't (DESIGN.md §9)."""
    if isinstance(value, bool):
        return value, None
    if isinstance(value, int):
        # 0 disables (falsy, like the old bool-only signature); N > 1
        # samples every Nth run
        return value > 0, (value if value > 1 else None)
    s = str(value).strip().lower()
    if s in _TRUTHY_OFF:
        return False, None
    if s.startswith("sample:"):
        n = int(s.split(":", 1)[1])
        if n < 1:
            raise ValueError(f"parity guard sample period must be >= 1, got {n}")
        return True, n  # sample:1 re-verifies every run
    return True, None


@dataclasses.dataclass(frozen=True)
class SessionOptions:
    """All Session configuration, one object.  ``None`` on an env-backed
    field means "resolve from the environment, else the default".

    Non-env fields: ``cluster`` (a ClusterSpec / spec string turns the
    session multi-process, DESIGN.md §11), ``standby`` (idle standby
    endpoints for §13 partial re-placement), ``devices`` (a DeviceSet for
    the in-process multi-device path), ``max_cached_executables`` (the
    Executable LRU size; 0 disables caching)."""

    verify: Optional[str] = None
    fuse_regions: Optional[bool] = None
    numerics: Optional[str] = None
    parity_guard: Any = None
    backend: Optional[str] = None
    trace_dir: Optional[str] = None
    cluster: Any = None
    standby: Any = ()
    devices: Any = None
    max_cached_executables: int = 16

    def resolve(self) -> "SessionOptions":
        """Apply the documented resolution order and validate; returns a
        new ``SessionOptions`` with every env-backed field concrete."""
        verify = self.verify
        if verify is None:
            verify = os.environ.get("REPRO_VERIFY", "warn")
        if verify not in ("off", "warn", "error"):
            raise ValueError(
                f"verify must be 'off', 'warn' or 'error', got {verify!r}")

        fuse_regions = self.fuse_regions
        if fuse_regions is None:
            fuse_regions = os.environ.get(
                "REPRO_FUSE_REGIONS", "1").lower() not in _TRUTHY_OFF
        fuse_regions = bool(fuse_regions)

        numerics = self.numerics
        if numerics is None:
            numerics = os.environ.get("REPRO_FUSE_NUMERICS", "strict")
        if numerics not in ("strict", "fast"):
            raise ValueError(
                f"numerics must be 'strict' or 'fast', got {numerics!r}")

        parity_guard = self.parity_guard
        if parity_guard is None:
            parity_guard = os.environ.get("REPRO_NUMERICS_GUARD", "1")
        parse_guard(parity_guard)  # validate eagerly

        backend = self.backend
        if backend is None:
            backend = os.environ.get("REPRO_KERNEL_BACKEND", "generic")
        from . import kernel_registry

        kernel_registry.get_backend(backend)  # raises ValueError if unknown

        trace_dir = self.trace_dir
        if trace_dir is None:
            trace_dir = os.environ.get("REPRO_TRACE") or None
        if trace_dir is not None:
            trace_dir = str(trace_dir)

        standby = self.standby
        if isinstance(standby, str):
            standby = tuple(s.strip() for s in standby.split(",") if s.strip())
        else:
            standby = tuple(standby)

        return dataclasses.replace(
            self, verify=verify, fuse_regions=fuse_regions, numerics=numerics,
            parity_guard=parity_guard, backend=backend, trace_dir=trace_dir,
            standby=standby)

    @property
    def parity_guard_policy(self) -> Tuple[bool, Optional[int]]:
        return parse_guard(self.parity_guard if self.parity_guard is not None
                           else os.environ.get("REPRO_NUMERICS_GUARD", "1"))
