"""§2 Sessions: Extend + Run, with §4.2 partial execution (feed/fetch).

``Session.run(fetches, feed_dict)`` rewrites the graph with feed/fetch
semantics: fed tensors shadow their producing nodes, the executed node set
is the transitive closure working backwards from the fetches through the
rewritten graph, and everything else is pruned (Figure 6).

The prune -> place -> partition -> schedule -> executor-static-analysis
pipeline runs once per :class:`~repro.core.executable.RunSignature`, not
once per call: the Session keeps an LRU of prepared
:class:`~repro.core.executable.Executable`\\ s keyed by (fetches, fed
keys, device set, graph version), so steady-state ``run`` loops only pay
per-run executor state (§3.2 "caches these graphs"; DESIGN.md §5).
``Session.extend`` bumps the graph version, invalidating stale entries
automatically.  The same Session can also *compile* a (feeds, fetches)
signature through the JIT lowering (§10 / DESIGN.md §2) into a pure JAX
function.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .graph import Graph, Node, TensorRef, as_ref
from .executor import ExecutionContext, Executor
from .executable import Executable, ExecutableCache, RunSignature
from .options import SessionOptions, parse_guard
from . import ops as ops_mod
from . import kernel_registry
from ..runtime.containers import VariableStore, ContainerManager
from ..runtime.rendezvous import Rendezvous


class _DictCheckpointIO:
    """In-memory checkpoint table (file-backed IO lives in repro.checkpoint)."""

    def __init__(self) -> None:
        self.table: Dict[str, Dict[str, Any]] = {}

    def save(self, path: str, values: Dict[str, Any]) -> None:
        self.table[path] = dict(values)

    def load(self, path: str) -> Dict[str, Any]:
        return self.table[path]


# Legacy config kwargs (pre-SessionOptions): sentinel distinguishes
# "not passed" from an explicit None/()/16.
_UNSET = object()
_LEGACY_OPTION_KWARGS = ("devices", "cluster", "standby",
                         "max_cached_executables", "fuse_regions",
                         "numerics", "parity_guard", "backend", "verify")
_warned_legacy_kwargs = False


def _parse_guard(value) -> Tuple[bool, Optional[int]]:
    # retained alias; the implementation moved to repro.core.options
    return parse_guard(value)


class Session:
    _ids = itertools.count()

    def __init__(self, graph: Optional[Graph] = None, *,
                 options: Optional[SessionOptions] = None,
                 containers: Optional[ContainerManager] = None,
                 checkpoint_io: Any = None,
                 devices: Any = _UNSET,
                 cluster: Any = _UNSET,
                 standby: Any = _UNSET,
                 max_cached_executables: Any = _UNSET,
                 fuse_regions: Any = _UNSET,
                 numerics: Any = _UNSET,
                 parity_guard: Any = _UNSET,
                 backend: Any = _UNSET,
                 verify: Any = _UNSET) -> None:
        self.graph = graph or Graph()
        # All configuration lives on one SessionOptions (repro.core.options;
        # DESIGN.md §15) with a single documented resolution order:
        # explicit value > REPRO_* env var > default.  The per-field kwargs
        # are a deprecation shim — they fold into the options object, with
        # an explicit kwarg overriding the corresponding options= field.
        #
        # Field notes (details in repro.core.options):
        #   verify        §14 pre-execution verifier: off|warn|error; part
        #                 of the RunSignature (flipping warn->error
        #                 re-verifies, never reuses a stale Executable).
        #   fuse_regions  §10 region fusion (DESIGN.md §7), default-on;
        #                 in the RunSignature.
        #   numerics      DESIGN.md §9 strict|fast policy; in the
        #                 RunSignature so the modes never share a cache
        #                 entry.
        #   parity_guard  fast-mode safety net: first-run (and sample:N)
        #                 verification against unfused-strict, with
        #                 permanent strict fallback on a breach.
        #   backend       DESIGN.md §12 kernel-backend registry choice;
        #                 in the RunSignature.
        legacy = {k: v for k, v in (
            ("devices", devices), ("cluster", cluster), ("standby", standby),
            ("max_cached_executables", max_cached_executables),
            ("fuse_regions", fuse_regions), ("numerics", numerics),
            ("parity_guard", parity_guard), ("backend", backend),
            ("verify", verify)) if v is not _UNSET}
        if legacy:
            global _warned_legacy_kwargs
            if not _warned_legacy_kwargs:
                warnings.warn(
                    "per-field Session(...) config kwargs are deprecated; "
                    "pass Session(options=SessionOptions(...)) instead "
                    "(repro.core.options)", DeprecationWarning, stacklevel=2)
                _warned_legacy_kwargs = True
        opts = dataclasses.replace(options or SessionOptions(), **legacy)
        self.options = opts = opts.resolve()
        # verify/fuse_regions/numerics/kernel_backend are write-through
        # properties over self.options (below): mid-session flips like
        # ``sess.numerics = "strict"`` fold back into the options object,
        # so RunSignature.for_session — which derives every key component
        # from the resolved options — re-keys and rebuilds, never reuses.
        self.parity_guard, self.parity_guard_every = parse_guard(opts.parity_guard)
        self.containers = containers or ContainerManager()
        self.variables = VariableStore(self.containers)
        self.rendezvous = Rendezvous()
        self.queues: Dict[str, Any] = {}
        self.checkpoint_io = checkpoint_io or _DictCheckpointIO()
        # §3.3/DESIGN.md §11: a cluster spec turns multi-device execution
        # into multi-*process* execution — the same place/partition/
        # schedule pipeline, with per-device subgraphs shipped to worker
        # processes and Send/Recv riding the wire rendezvous.
        self.cluster = None
        self._master: Any = None
        devices = opts.devices
        if opts.cluster is not None:
            import uuid

            from ..distrib.wire import ClusterSpec

            self.cluster = ClusterSpec.parse(opts.cluster)
            if devices is None:
                devices = self.cluster.device_set()
            # worker-side Variable containers are namespaced per session,
            # mirroring the in-process default of one ContainerManager
            # per Session (§4.7): two sessions sharing a worker pool must
            # not silently share state through colliding Variable names.
            # Stable across pool restarts (recovery keeps the session).
            self.wire_namespace = uuid.uuid4().hex[:8]
        # §13: endpoints of idle standby workers — partial re-placement
        # consumes them before falling back to survivor hosting
        self.standby = list(opts.standby)
        self.devices = devices  # DeviceSet for the multi-device eager path
        self.id = next(Session._ids)
        self._run_count = 0
        # compile-once/run-many: RunSignature -> Executable (DESIGN.md §5);
        # max_cached_executables=0 disables caching (benchmark baseline).
        self._executables = ExecutableCache(maxsize=opts.max_cached_executables)
        # §16 distributed EEG: trace_dir turns on the span stream for every
        # run of this session (including make_callable, which passes no
        # per-call kwargs — Executable.run consults self._spans).  The
        # recorder is installed process-globally too, so the RPC client
        # layer records wire calls.  trace_dir unset => self._spans is
        # None and every instrumentation site stays a single None check.
        self.trace_dir = opts.trace_dir
        self._spans = None
        self._trace_exported = False
        if self.trace_dir:
            from ..obs import spans as spans_mod

            self._spans = spans_mod.install(
                spans_mod.SpanRecorder(process="master"))

    # ------------------------------------------------------------------
    # -- mirrored option attrs --------------------------------------------
    # One source of truth: reads come from self.options, writes fold back
    # into it (validated through resolve()), so a mid-session flip reaches
    # RunSignature.for_session through the same options-derived path as a
    # constructor value.

    @property
    def verify(self) -> str:
        return self.options.verify

    @verify.setter
    def verify(self, v: str) -> None:
        self.options = dataclasses.replace(self.options, verify=v).resolve()

    @property
    def fuse_regions(self) -> bool:
        return self.options.fuse_regions

    @fuse_regions.setter
    def fuse_regions(self, v: bool) -> None:
        self.options = dataclasses.replace(
            self.options, fuse_regions=v).resolve()

    @property
    def numerics(self) -> str:
        return self.options.numerics

    @numerics.setter
    def numerics(self, v: str) -> None:
        self.options = dataclasses.replace(self.options, numerics=v).resolve()

    @property
    def kernel_backend(self) -> str:
        return self.options.backend

    @kernel_backend.setter
    def kernel_backend(self, v: str) -> None:
        self.options = dataclasses.replace(self.options, backend=v).resolve()

    @property
    def master(self):
        """Lazily-started :class:`repro.distrib.master.Master` for cluster
        sessions (heartbeats begin on first touch; DESIGN.md §11)."""
        if self.cluster is None:
            raise RuntimeError("Session has no cluster= spec")
        if self._master is None:
            from ..distrib.master import Master

            self._master = Master(self.cluster, standbys=self.standby)
            self._master.start()
        return self._master

    def rebind_cluster(self, cluster: Any = None) -> None:
        """§3.3 recovery: point this session at a restarted worker pool.

        The pool must have the same shape (task count / devices per task
        — placement is per-task).  The session store's *current* Variable
        values are pushed to the pool here and cached Executables
        re-register lazily, so the recovery recipe is: restore the last
        checkpoint into the session (``set_variable``), restart the
        workers, call this, keep running.
        """
        from ..distrib.wire import ClusterSpec

        spec = ClusterSpec.parse(cluster) if cluster is not None else self.cluster
        if spec is None:
            raise RuntimeError("Session has no cluster= spec")
        self.cluster = spec
        self.master.reset(spec)
        # registration only *seeds* worker Variables (it must not clobber
        # live mid-training state); recovery state is pushed explicitly —
        # restore the checkpoint into the session store BEFORE calling
        for plan in self.master.live_plans():
            plan.push_variables()

    def recover_dead_tasks(self, checkpoint: Optional[Dict[str, Any]] = None,
                           *, standby: Any = None):
        """§13 partial re-placement: recover from dead workers WITHOUT
        restarting the pool or discarding survivors' live Variable state.

        Each dead task's subgraph slice is re-placed onto a standby
        worker (``standby=`` here, ``Session(standby=...)``, or
        ``master.add_standby``) or, failing that, onto a survivor's
        process; only the dead task's Variables are pushed from
        ``checkpoint`` (``{name: value}`` — typically the last
        checkpoint's values), survivors keep live state, and only the
        replaced task re-registers — cached Executables stay valid.

        Returns a :class:`~repro.distrib.master.RecoveryReport` saying
        what was kept vs restored.  Raises
        :class:`~repro.distrib.master.RecoveryError` when nothing can
        host the dead tasks — the whole-pool path (restart workers,
        ``set_variable`` the checkpoint, ``rebind_cluster``) remains the
        fallback.
        """
        from ..distrib.master import RecoveryError, RecoveryReport

        m = self.master
        if isinstance(standby, str):
            standby = [s.strip() for s in standby.split(",") if s.strip()]
        for ep in (standby or ()):
            m.add_standby(ep)
        dead = dict(m.dead)
        if not dead:
            return RecoveryReport(
                mode="noop", dead={}, replacements={},
                survivors=tuple(range(len(m.cluster.workers))),
                kept_live=(), restored=())
        survivors = tuple(t for t in range(len(m.cluster.workers))
                          if t not in dead)
        plans = m.live_plans()
        replacements: Dict[str, Any] = {}
        for i, t in enumerate(sorted(dead)):
            if m.standbys:
                replacements[t] = m.standbys.pop(0)
            elif survivors:
                # round-robin over survivors: the replacement process then
                # hosts two tasks' devices of the same plan (worker
                # registry is keyed by (handle, task))
                replacements[t] = m.cluster.workers[survivors[i % len(survivors)]]
            else:
                raise RecoveryError(
                    f"§13: no standby or survivor can host dead task(s) "
                    f"{sorted(dead)} ("
                    + "; ".join(f"task:{k}: {v}" for k, v in sorted(dead.items()))
                    + ") — fall back to whole-pool recovery: restart the "
                    f"pool, restore the last checkpoint (set_variable) and "
                    f"rebind_cluster")
        # restore ONLY the dead tasks' Variables into the session store;
        # survivors' names in the checkpoint are ignored — their live
        # (newer) worker-side state is the whole point of this path
        dead_owned = {name for plan in plans
                      for name, owner in plan.var_owner.items()
                      if owner in dead}
        if checkpoint:
            for name in sorted(dead_owned & set(checkpoint)):
                self.set_variable(name, checkpoint[name])
        for t, ep in sorted(replacements.items()):
            m.replace_task(t, ep)
        self.cluster = m.cluster  # same shape: fingerprint (and cache) hold
        kept: set = set()
        for plan in plans:
            for t in sorted(replacements):
                plan.reregister_task(t)
            plan.update_survivors(set(replacements))
            # registration only SEEDs: force-push the restored values — a
            # survivor hosting the dead task may hold stale state for it
            plan.push_variables(tasks=set(replacements))
            kept |= {name for name, owner in plan.var_owner.items()
                     if owner not in dead}
        return RecoveryReport(
            mode="partial", dead=dead, survivors=survivors,
            replacements=replacements, kept_live=tuple(sorted(kept)),
            restored=tuple(sorted(dead_owned)))

    def pull_cluster_variables(self) -> Dict[str, Any]:
        """Fetch Variable state back from the worker pool into the local
        store; returns the pulled values (checkpoint them with
        CheckpointManager for §3.3 recovery)."""
        if self._master is None:
            return {}
        out: Dict[str, Any] = {}
        seen = set()
        for plan in self._master.live_plans():
            names = set(plan.var_owner) - seen
            if names:
                out.update(plan.pull_variables())
                seen |= set(plan.var_owner)
        return out

    def export_trace(self, path: Optional[str] = None) -> Optional[str]:
        """Write the merged Chrome-trace JSON (§16.3): the local span
        stream plus, for cluster sessions, every worker's buffered events
        (shipped on ``run_graph`` replies and drained via the
        ``collect_trace`` RPC), aligned by the master's per-task
        clock-offset estimates.  Returns the path written, or None when
        the session was not constructed with ``trace_dir=``."""
        if self._spans is None:
            return None
        import os

        from ..obs import export as export_mod

        streams = [{"process": "master", "offset_s": 0.0,
                    "events": self._spans.snapshot()}]
        if self.cluster is not None and self._master is not None:
            streams.extend(self._master.collect_trace_streams())
        path = path or os.path.join(self.trace_dir, "trace.json")
        export_mod.write_trace(path, streams)
        self._trace_exported = True
        return path

    def close(self) -> None:
        """Stop heartbeat threads / close worker channels (cluster sessions).
        A pending ``trace_dir=`` trace is flushed first (best-effort: an
        export failure must never mask shutdown)."""
        if self._spans is not None and not self._trace_exported:
            try:
                self.export_trace()
            except Exception:
                pass
        if self._master is not None:
            self._master.stop()
            self._master = None

    # ------------------------------------------------------------------
    def extend(self, graph: Graph) -> None:
        """Session.Extend (§2): augment the current graph."""
        self.graph.extend(graph)

    def register_queue(self, name: str, q: Any) -> None:
        self.queues[name] = q

    def _ctx(self) -> ExecutionContext:
        return ExecutionContext(
            variables=self.variables,
            rendezvous=self.rendezvous,
            queues=self.queues,
            checkpoint_io=self.checkpoint_io,
        )

    # ------------------------------------------------------------------
    def _normalize(self, fetches, feed_dict):
        fetch_refs = [as_ref(f) for f in (fetches if isinstance(fetches, (list, tuple)) else [fetches])]
        feeds = {as_ref(k): v for k, v in (feed_dict or {}).items()}
        return fetch_refs, feeds

    def pruned_nodes(self, fetch_refs: Sequence[TensorRef],
                     feeds: Dict[TensorRef, Any]) -> Set[str]:
        """§4.2: nodes needed for the fetches, stopping at fed tensors.

        A node whose *every* output is fed need not run; we model the
        feed-node rewrite by cutting traversal through fed edges.
        """
        g = self.graph
        needed: Set[str] = set()
        stack = [r.node for r in fetch_refs]
        fed_ports = {(r.node, r.port) for r in feeds}
        while stack:
            n = stack.pop()
            if n in needed:
                continue
            needed.add(n)
            node = g.nodes[n]
            for ref in node.inputs:
                if (ref.node, ref.port) in fed_ports:
                    continue  # edge replaced by a feed node
                stack.append(ref.node)
            stack.extend(node.control_inputs)
        # nodes that are fetch targets but fully fed: keep out of execution
        fed_nodes = {r.node for r in fetch_refs if (r.node, r.port) in fed_ports}
        return needed - fed_nodes

    def executable(self, fetch_refs: Sequence[TensorRef],
                   feed_keys) -> Executable:
        """The cached Executable for one run signature (built on miss).

        Stale entries (older graph version, different device set) are
        purged lazily on every miss; ``Session.extend`` therefore
        invalidates automatically via the graph version in the key.
        """
        sig = RunSignature.for_session(self, fetch_refs, feed_keys)

        def build() -> Executable:
            self._executables.invalidate(
                lambda s: s.graph_version != sig.graph_version
                or s.device_fingerprint != sig.device_fingerprint)
            return Executable(self, sig.fetches, sig.feed_keys)

        return self._executables.get_or_build(sig, build)

    @property
    def cache_stats(self) -> Dict[str, int]:
        return dict(self._executables.stats)

    def run(self, fetches, feed_dict: Optional[Dict] = None,
            trace: Optional[List[str]] = None, tracer=None):
        """Eagerly execute the subgraph needed for ``fetches`` (§2/§4.2).

        Steady-state loops over one signature hit the Executable cache and
        skip prune/place/partition/schedule/static-analysis entirely.
        """
        fetch_refs, feeds = self._normalize(fetches, feed_dict)
        self._run_count += 1
        exe = self.executable(fetch_refs, feeds.keys())
        results = exe.run(feeds, trace=trace, tracer=tracer)
        if isinstance(fetches, (list, tuple)):
            return results
        return results[0]

    def make_callable(self, fetches, feed_refs: Sequence = ()) -> Callable[..., List[Any]]:
        """TF's ``Session.make_callable``: a fast positional-feed entry point.

        Returns ``call(*feed_values) -> [fetch_values]`` bound to the cached
        Executable for this signature; the signature is re-resolved through
        the cache on every call, so graph extension or device swaps rebuild
        transparently while the steady state stays a single dict lookup.
        """
        fetch_refs = [as_ref(f) for f in (fetches if isinstance(fetches, (list, tuple)) else [fetches])]
        feed_key_list = [as_ref(k) for k in feed_refs]
        feed_key_set = frozenset(feed_key_list)

        def call(*feed_values) -> List[Any]:
            if len(feed_values) != len(feed_key_list):
                raise ValueError(
                    f"expected {len(feed_key_list)} feed values, got {len(feed_values)}")
            self._run_count += 1
            exe = self.executable(fetch_refs, feed_key_set)
            return exe.run(dict(zip(feed_key_list, feed_values)))

        return call

    # ------------------------------------------------------------------
    def initialize_variables(self, names: Optional[Sequence[str]] = None) -> None:
        """Force-initialize Variables (reads them once so inits run)."""
        ctx = self._ctx()
        for node in self.graph.nodes.values():
            if node.op == "Variable" and (names is None or node.name in names):
                ctx.read_variable(node)

    def variable_value(self, name: str):
        return self.variables.read(name, self.graph.nodes[name].attrs)

    def set_variable(self, name: str, value) -> None:
        self.variables.write(name, value)

    # ------------------------------------------------------------------
    def compile(self, fetches, feeds: Sequence, **kw):
        """Lower a (feeds, fetches) signature to a pure JAX function (§10)."""
        from . import lowering

        return lowering.compile_subgraph(self, fetches, feeds, **kw)
