"""§3.1 single-device eager executor + §4.4 tagged-frame control flow.

The executor keeps, per node-execution, a count of not-yet-available
dependencies; when the count drops to zero the node joins a ready queue,
which delegates the node's kernel to its device (§3.1).  Control-flow
primitives (Switch/Merge/Enter/Exit/NextIteration) are interpreted with a
tags-and-frames scheme conceptually similar to the MIT Tagged-Token
machine (§4.4): every value is tagged with a frame context
``((frame_name, iteration), ...)`` so multiple loop iterations can be in
flight; dead tensors propagate through untaken branches, and dead
``NextIteration`` values are swallowed, which terminates loops.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Set, Tuple

from .graph import Graph, Node, TensorRef
from . import ops as ops_mod
from . import control_flow as cf_mod
from ..obs import spans as spans_mod
from ..runtime.rendezvous import DEAD_TENSOR

# A frame context: tuple of (frame_name, iteration) pairs; () is the root.
FrameCtx = Tuple[Tuple[str, int], ...]

_DEAD = object()  # dead-tensor marker

MAX_ITERATIONS = 100_000


def wire_key(node: Node, ctx: FrameCtx) -> str:
    """Rendezvous key for a Send/Recv executing in frame context ``ctx``.

    §4.4 distributed loops: every iteration of a cross-device loop is a
    distinct transfer, so in-frame Send/Recv pairs tag their static
    rendezvous key with the (frame, iteration) context.  Both ends of a
    pair execute in the same context by construction — the Send is driven
    by its in-frame data input, the Recv by its frame's iteration token
    (see partition._replicate_loop_frames) — so the tags always agree.
    Root-frame transfers keep the bare key.
    """
    key = node.attrs["rendezvous_key"]
    return key if not ctx else f"{key}#{ctx!r}"


class ExecutorError(Exception):
    pass


@dataclasses.dataclass
class ExecutionContext:
    """Runtime state handed to stateful kernels."""

    variables: Any  # runtime.containers.VariableStore
    rendezvous: Any = None
    queues: Dict[str, Any] = dataclasses.field(default_factory=dict)
    checkpoint_io: Any = None
    device_kind: str = "cpu"

    def read_variable(self, node: Node):
        return self.variables.read(node.name, node.attrs)

    def write_variable(self, var_name: str, value):
        self.variables.write(var_name, value)

    def queue(self, name: str):
        return self.queues[name]

    def save_checkpoint(self, path: str, values: Dict[str, Any]):
        self.checkpoint_io.save(path, values)

    def load_checkpoint(self, path: str) -> Dict[str, Any]:
        return self.checkpoint_io.load(path)


@dataclasses.dataclass
class ExecutorState:
    """All mutable per-run state of one graph execution.

    The :class:`Executor` itself holds only *immutable* static analysis
    (consumer index, control-consumer index, static frame paths), so one
    Executor can be cached inside an :class:`~repro.core.executable.Executable`
    and used by many concurrent ``run`` calls — each call allocates a fresh
    ExecutorState (DESIGN.md §5).
    """

    # value store: (node, port, frame_ctx) -> value (may be _DEAD)
    values: Dict[Tuple[str, int, FrameCtx], Any] = dataclasses.field(default_factory=dict)
    # per-(node, ctx) countdown of outstanding deps
    pending: Dict[Tuple[str, FrameCtx], int] = dataclasses.field(default_factory=dict)
    merge_fired: Set[Tuple[str, FrameCtx]] = dataclasses.field(default_factory=set)
    # deque: the scheduler pops from the head on every dispatch and
    # rotates deferred Recvs to the tail — O(1) both ways (a list's
    # pop(0) is O(n) per dispatch)
    ready: Deque[Tuple[str, FrameCtx]] = dataclasses.field(default_factory=deque)
    done: Set[Tuple[str, FrameCtx]] = dataclasses.field(default_factory=set)
    # loop-invariant inputs not yet produced: (producer, port|None) -> waiters
    waiters: Dict[Tuple[str, Any], List[Tuple[str, FrameCtx]]] = dataclasses.field(default_factory=dict)


def run_kernel(ctx: ExecutionContext, node: Node, inputs: Sequence[Any],
               device_kind: Optional[str] = None) -> Tuple[Any, ...]:
    """Dispatch to the device kernel for ``node`` (§2 Operations and Kernels)."""
    od = ops_mod.opdef(node.op)
    kind = device_kind or ctx.device_kind
    fn = od.kernels.get(kind, od.compute)
    outs = fn(ctx, node, *inputs)
    n_out = od.num_outputs(node)
    if len(outs) != n_out:
        raise ExecutorError(
            f"op {node.op} ({node.name}) produced {len(outs)} outputs, expected {n_out}")
    return outs


def run_fused_interpreted(ctx: ExecutionContext, node: Node,
                          inputs: Sequence[Any], tracer: Any,
                          device_label: str, frame_ctx: FrameCtx) -> Tuple[Any, ...]:
    """Execute a FusedRegion's members node-by-node through ``run_kernel``.

    Used when a tracer is attached: per-member events are recorded exactly
    as if the region had never been fused.  Variable reads/writes go
    straight through ``ctx`` (the eager semantics), so state effects are
    identical to both the jitted dispatch and the unfused executor.
    """
    spec = node.attrs["spec"]
    g = spec.subgraph
    vals: Dict[Tuple[str, int], Any] = {
        (r.node, r.port): v for r, v in zip(spec.input_refs, inputs)}
    bound = set(vals)  # fed member ports keep shadowing their producer (§4.2)
    for m in spec.members:  # topo order by construction
        mnode = g.nodes[m]
        ins = [vals[(r.node, r.port)] for r in mnode.inputs]
        t_start = tracer.now()
        outs = run_kernel(ctx, mnode, ins)
        tracer.record(m, mnode.op, device_label, t_start, tracer.now(),
                      frame_ctx)
        for p, v in enumerate(outs):
            if (m, p) not in bound:
                vals[(m, p)] = v
    return tuple(vals[(r.node, r.port)] for r in spec.output_refs)


class Executor:
    """Reference single-device executor over a (sub)graph.

    Construction performs the *static* analysis only — the O(edges)
    consumer index and the static-frame fixpoint — and mutates nothing
    afterwards, so a constructed Executor is immutable and reusable:
    ``run`` allocates a fresh :class:`ExecutorState` per call and takes
    per-run ``ctx``/``trace``/``tracer`` overrides.  The ``ctx`` passed at
    construction time is only a default for callers of the legacy
    one-shot API.
    """

    def __init__(self, graph: Graph, ctx: Optional[ExecutionContext] = None,
                 node_filter: Optional[Set[str]] = None,
                 trace: Optional[List[str]] = None,
                 tracer: Any = None,
                 spans: Optional[spans_mod.SpanRecorder] = None,
                 device_label: str = "/job:localhost/device:cpu:0") -> None:
        self.graph = graph
        self.ctx = ctx
        self.names = set(node_filter) if node_filter is not None else set(graph.nodes)
        self.trace = trace  # records execution order for tests
        self.tracer = tracer  # §9.2 EEG-style fine-grained tracing
        self.spans = spans  # §16 distributed EEG span stream
        self.device_label = device_label

        # static consumer index restricted to the executed node set
        self.consumers: Dict[Tuple[str, int], List[Tuple[str, int]]] = {}
        self.ctrl_consumers: Dict[str, List[str]] = {}
        for name in self.names:
            node = graph.nodes[name]
            for slot, ref in enumerate(node.inputs):
                self.consumers.setdefault((ref.node, ref.port), []).append((name, slot))
            for c in node.control_inputs:
                self.ctrl_consumers.setdefault(c, []).append(name)
        # static frame path per node (§4.4) — the shared analysis in
        # control_flow.static_frames, restricted to the executed set
        self.frames = cf_mod.static_frames(graph, self.names)

    # ------------------------------------------------------------------
    def run(self, fetches: Sequence[TensorRef],
            feeds: Optional[Dict[TensorRef, Any]] = None, *,
            ctx: Optional[ExecutionContext] = None,
            trace: Optional[List[str]] = None,
            tracer: Any = None,
            spans: Optional[spans_mod.SpanRecorder] = None) -> List[Any]:
        feeds = feeds or {}
        g = self.graph
        root: FrameCtx = ()

        # per-run overrides fall back to the construction-time defaults
        run_ctx = ctx if ctx is not None else self.ctx
        trace = trace if trace is not None else self.trace
        tracer = tracer if tracer is not None else self.tracer
        spans = spans if spans is not None else self.spans
        if run_ctx is None:
            raise ExecutorError("Executor.run needs an ExecutionContext "
                                "(pass ctx= or construct with one)")

        # all mutable state lives in a per-run ExecutorState so one Executor
        # can serve concurrent runs (DESIGN.md §5)
        state = ExecutorState()
        values = state.values
        pending = state.pending
        merge_fired = state.merge_fired
        ready = state.ready
        done = state.done
        waiters = state.waiters

        def trunc(ctx: FrameCtx, producer: str) -> FrameCtx:
            return ctx[: len(self.frames.get(producer, ()))]

        def exec_depth(name: str) -> int:
            # self.frames holds OUTPUT frames; Enter executes one frame up
            # (it consumes the parent value), Exit one frame down.
            node = g.nodes[name]
            d = len(self.frames.get(name, ()))
            if node.op == "Enter":
                return d - 1
            if node.op == "Exit":
                return d + 1
            return d

        def dep_count(name: str, ctx: FrameCtx) -> int:
            node = g.nodes[name]
            if node.op == "Merge":
                # Merge is ready as soon as ANY live input arrives (§4.4);
                # handled event-style below, so it never enters via counting.
                return -1
            depth = exec_depth(name)
            n = 0
            for ref in node.inputs:
                if TensorRef(ref.node, ref.port) in feeds:
                    continue
                if len(self.frames.get(ref.node, ())) < depth:
                    # loop-invariant: read from the outer frame when available
                    if (ref.node, ref.port, trunc(ctx, ref.node)) in values:
                        continue
                    waiters.setdefault((ref.node, ref.port), []).append((name, ctx))
                n += 1
            for c in node.control_inputs:
                if len(self.frames.get(c, ())) < depth:
                    if (c, trunc(ctx, c)) in done:
                        continue
                    waiters.setdefault((c, None), []).append((name, ctx))
                n += 1
            return n

        def init_pending(name: str, ctx: FrameCtx) -> None:
            key = (name, ctx)
            if key in pending or key in done:
                return
            node = g.nodes[name]
            cnt = dep_count(name, ctx)
            if cnt == 0:
                pending[key] = 0
                ready.append(key)
            else:
                pending[key] = cnt

        def notify_waiters(wkey: Tuple[str, Any]) -> None:
            for (cname, cctx) in waiters.pop(wkey, []):
                ckey = (cname, cctx)
                if ckey in done or ckey not in pending:
                    continue
                pending[ckey] -= 1
                if pending[ckey] == 0:
                    ready.append(ckey)

        def output_ctx(node: Node, ctx: FrameCtx) -> FrameCtx:
            if node.op == "Enter":
                return ctx + ((node.attrs["frame"], 0),)
            if node.op == "Exit":
                return ctx[:-1]
            if node.op == "NextIteration":
                frame, it = ctx[-1]
                return ctx[:-1] + ((frame, it + 1),)
            return ctx

        def deliver(src: str, port: int, ctx: FrameCtx, value: Any) -> None:
            """A value for (src:port) became available in frame ``ctx``."""
            values[(src, port, ctx)] = value
            for (cname, _slot) in self.consumers.get((src, port), []):
                if exec_depth(cname) != len(ctx):
                    continue  # cross-frame edge: handled by the waiter table
                cnode = g.nodes[cname]
                ckey = (cname, ctx)
                if cnode.op == "Merge":
                    if value is not _DEAD and ckey not in merge_fired and ckey not in done:
                        merge_fired.add(ckey)
                        ready.append(ckey)
                        pending.setdefault(ckey, 0)
                    elif value is _DEAD:
                        # fire dead Merge only if every input is dead
                        if ckey not in merge_fired and ckey not in done and all(
                            values.get((r.node, r.port, ctx), None) is _DEAD
                            for r in cnode.inputs
                        ):
                            merge_fired.add(ckey)
                            ready.append(ckey)
                            pending.setdefault(ckey, 0)
                    continue
                init_pending(cname, ctx)
                if ckey in done:
                    continue
                pending[ckey] -= 1
                if pending[ckey] == 0:
                    ready.append(ckey)
            notify_waiters((src, port))

        def deliver_control(src: str, ctx: FrameCtx) -> None:
            for cname in self.ctrl_consumers.get(src, []):
                if exec_depth(cname) != len(ctx):
                    continue  # cross-frame control edge: waiter table
                ckey = (cname, ctx)
                init_pending(cname, ctx)
                if ckey in done:
                    continue
                pending[ckey] -= 1
                if pending[ckey] == 0:
                    ready.append(ckey)
            notify_waiters((src, None))

        # --- seed: feeds + source nodes -------------------------------
        # Fed edges were excluded from dep_count, so only Merge consumers
        # (event-fired) need notification; the value itself is read from
        # ``feeds`` at execution time (§4.2 feed-node semantics).
        for ref, val in feeds.items():
            values[(ref.node, ref.port, root)] = val
            for (cname, _slot) in self.consumers.get((ref.node, ref.port), []):
                cnode = g.nodes[cname]
                if cnode.op == "Merge":
                    ckey = (cname, root)
                    if ckey not in merge_fired and ckey not in done:
                        merge_fired.add(ckey)
                        ready.append(ckey)
                        pending.setdefault(ckey, 0)
        for name in self.names:
            node = g.nodes[name]
            if dep_count(name, root) == 0 and node.op != "Merge":
                init_pending(name, root)

        # --- main loop --------------------------------------------------
        steps = 0
        deferred = 0  # consecutive Recv deferrals (see below)
        while ready:
            steps += 1
            if steps > MAX_ITERATIONS:
                raise ExecutorError("executor exceeded MAX_ITERATIONS (livelock?)")
            name, ctx = ready.popleft()
            key = (name, ctx)
            if key in done:
                continue
            node = g.nodes[name]

            # A Recv whose tensor has not arrived yet must not block this
            # device's single dispatch thread — if the sender is waiting on
            # one of OUR sends, that blocking is a rendezvous deadlock.
            # Defer the Recv behind other runnable work; once a full pass
            # over the ready queue found nothing else to run, wait for ANY
            # outstanding Recv (never one arbitrary key: the peer may
            # produce it last).
            if (node.op == "Recv" and run_ctx.rendezvous is not None
                    and not run_ctx.rendezvous.ready(wire_key(node, ctx))):
                if ready and deferred <= len(ready):
                    deferred += 1
                    ready.append(key)
                    continue
                pending_keys = [wire_key(node, ctx)] + [
                    wire_key(g.nodes[n], c)
                    for (n, c) in ready if g.nodes[n].op == "Recv"]
                observing = tracer is not None or spans is not None
                t_wait = time.time() if observing else None
                run_ctx.rendezvous.wait_any(pending_keys)
                if observing:
                    t_wend = time.time()
                    if spans is not None:
                        spans.record(name, spans_mod.CAT_WAIT,
                                     self.device_label, t_wait, t_wend,
                                     args={"keys": len(pending_keys)})
                    rw = getattr(tracer, "record_wait", None)
                    if rw is not None:
                        rw(name, self.device_label, t_wait, t_wend, ctx)
                if not run_ctx.rendezvous.ready(wire_key(node, ctx)):
                    deferred = 0  # progress was made elsewhere; re-rotate
                    ready.append(key)
                    continue
            deferred = 0
            done.add(key)
            octx = output_ctx(node, ctx)

            # gather inputs (feeds shadow node outputs, §4.2)
            ins: List[Any] = []
            any_dead = False
            for ref in node.inputs:
                fed = feeds.get(TensorRef(ref.node, ref.port))
                if fed is not None or TensorRef(ref.node, ref.port) in feeds:
                    v = feeds[TensorRef(ref.node, ref.port)]
                else:
                    v = values.get(
                        (ref.node, ref.port, trunc(ctx, ref.node)),
                        _DEAD if node.op == "Merge" else None)
                    if v is None:
                        raise ExecutorError(f"input {ref} of {name} missing in {ctx}")
                if v is _DEAD:
                    any_dead = True
                ins.append(v)

            if trace is not None:
                trace.append(name)

            od = ops_mod.opdef(node.op)

            # ---- control-flow interpretation --------------------------
            if node.op == "Switch":
                data, pred = ins
                if any_dead:
                    deliver(name, 0, octx, _DEAD)
                    deliver(name, 1, octx, _DEAD)
                else:
                    live_port = 1 if bool(pred) else 0
                    deliver(name, live_port, octx, data)
                    deliver(name, 1 - live_port, octx, _DEAD)
                deliver_control(name, octx)
                continue
            if node.op == "Merge":
                live = [(i, v) for i, v in enumerate(ins) if v is not _DEAD and v is not None]
                if live:
                    idx, v = live[0]
                    deliver(name, 0, octx, v)
                    import jax.numpy as jnp

                    deliver(name, 1, octx, jnp.asarray(idx, dtype=jnp.int32))
                else:
                    deliver(name, 0, octx, _DEAD)
                    deliver(name, 1, octx, _DEAD)
                deliver_control(name, octx)
                continue
            if node.op in ("Enter", "Exit", "LoopCond", "Identity"):
                v = ins[0]
                if node.op == "Exit" and v is _DEAD:
                    # dead Exit is swallowed, symmetric with dead
                    # NextIteration: the exit-side Switch port is dead on
                    # every *continuing* iteration, and all iterations of
                    # the frame share one parent context — propagating
                    # those would poison root-frame consumers (mark them
                    # done-with-dead) before the terminating iteration
                    # delivers the single live value that actually leaves
                    # the frame (§4.4; the numerics parity suite consumes
                    # loop outputs downstream and relies on this).
                    continue
                deliver(name, 0, octx, v)
                deliver_control(name, octx)
                continue
            if node.op == "NextIteration":
                v = ins[0]
                if v is _DEAD:
                    continue  # dead NextIteration is swallowed: loop terminates
                deliver(name, 0, octx, v)
                deliver_control(name, octx)
                continue

            # ---- Send/Recv: frame-tagged rendezvous + wire deadness ----
            # Interpreted here (not via run_kernel) because the rendezvous
            # key depends on the execution context, and because deadness
            # must cross the wire: a Send with a dead input transmits the
            # DEAD_TENSOR marker (untaken branch / terminating iteration)
            # so the peer device's consumers can propagate it (§4.4).
            if node.op == "Send":
                wkey = wire_key(node, ctx)
                if any_dead:
                    run_ctx.rendezvous.send(wkey, DEAD_TENSOR)
                else:
                    v = ins[0]
                    observing = tracer is not None or spans is not None
                    t_start = time.time() if observing else None
                    if node.attrs.get("compress", False):
                        from . import compression

                        v = compression.compress_f32_to_16(v)
                    run_ctx.rendezvous.send(wkey, v)
                    if tracer is not None:
                        tracer.record(name, node.op, self.device_label,
                                      t_start, time.time(), ctx)
                    elif spans is not None:
                        spans.record(name, spans_mod.CAT_OP,
                                     self.device_label, t_start, time.time(),
                                     args={"op": "Send"})
                deliver_control(name, octx)
                continue
            if node.op == "Recv":
                wkey = wire_key(node, ctx)
                observing = tracer is not None or spans is not None
                # Wait/compute split (§16.2): if the tensor is not already
                # sitting in the rendezvous, everything recv blocks on is
                # *stall* — attribute it to the rendezvous lane rather than
                # letting it inflate Recv "compute" time.
                t_start = time.time() if observing else None
                was_ready = (not observing
                             or run_ctx.rendezvous.ready(wkey))
                v = run_ctx.rendezvous.recv(wkey)
                t_recv = time.time() if observing else None
                if observing and not was_ready:
                    if spans is not None:
                        spans.record(name, spans_mod.CAT_WAIT,
                                     self.device_label, t_start, t_recv,
                                     args={"key": wkey})
                    rw = getattr(tracer, "record_wait", None)
                    if rw is not None:
                        rw(name, self.device_label, t_start, t_recv, ctx)
                if v is DEAD_TENSOR or any_dead:
                    # dead over the wire, or a dead iteration token (the
                    # loop's terminating iteration — the matching Send
                    # transmitted a marker, consumed above to keep the
                    # rendezvous balanced): propagate deadness locally
                    deliver(name, 0, octx, _DEAD)
                else:
                    if node.attrs.get("compress", False):
                        from . import compression

                        v = compression.decompress_16_to_f32(v)
                    deliver(name, 0, octx, v)
                    if tracer is not None:
                        tracer.record(name, node.op, self.device_label,
                                      t_start, time.time(), ctx)
                    elif spans is not None:
                        spans.record(name, spans_mod.CAT_OP,
                                     self.device_label, t_start, time.time(),
                                     args={"op": "Recv",
                                           "waited": not was_ready})
                deliver_control(name, octx)
                continue

            # ---- normal ops: dead-in -> dead-out -----------------------
            if any_dead:
                for p in range(od.num_outputs(node)):
                    deliver(name, p, octx, _DEAD)
                deliver_control(name, octx)
                continue

            if tracer is not None:
                if node.op == "FusedRegion":
                    # EEG-style tracing (§9.2) needs per-kernel events, which
                    # a jitted blob cannot provide: interpret the region's
                    # members one by one instead (identical semantics — this
                    # IS the eager path, scoped to the region).
                    outs = run_fused_interpreted(run_ctx, node, ins, tracer,
                                                 self.device_label, ctx)
                else:
                    t_start = tracer.now()
                    outs = run_kernel(run_ctx, node, ins)
                    tracer.record(name, node.op, self.device_label,
                                  t_start, tracer.now(), ctx)
            elif spans is not None:
                # §16 span path: a FusedRegion stays ONE span over the real
                # jitted dispatch (never demoted to per-member
                # interpretation like the legacy tracer), annotated with
                # its member count and any registered-kernel dispatches the
                # call triggered (non-empty only on the compiling run —
                # dispatch accounting is trace-time, DESIGN.md §12).
                if node.op == "FusedRegion":
                    from . import kernel_registry

                    spec = node.attrs["spec"]
                    before = kernel_registry.dispatch_counts(spec.backend)
                    t_start = time.time()
                    outs = run_kernel(run_ctx, node, ins)
                    t_end = time.time()
                    after = kernel_registry.dispatch_counts(spec.backend)
                    args: Dict[str, Any] = {"members": len(spec.members),
                                            "backend": spec.backend}
                    delta = {k: after[k] - before.get(k, 0)
                             for k in after if after[k] != before.get(k, 0)}
                    if delta:
                        args["kernels"] = delta
                    spans.record(name, spans_mod.CAT_REGION,
                                 self.device_label, t_start, t_end, args=args)
                else:
                    t_start = time.time()
                    outs = run_kernel(run_ctx, node, ins)
                    spans.record(name, spans_mod.CAT_OP, self.device_label,
                                 t_start, time.time(),
                                 args={"op": node.op})
            else:
                outs = run_kernel(run_ctx, node, ins)
            for p, v in enumerate(outs):
                deliver(name, p, octx, v)
            deliver_control(name, octx)

        # --- collect fetches --------------------------------------------
        results = []
        for ref in fetches:
            if ref in feeds:
                results.append(feeds[ref])
                continue
            v = values.get((ref.node, ref.port, root))
            if v is None:
                # fetching an operation with no outputs (e.g. a train_op
                # group) just means "make sure it ran" — TF semantics.
                node = g.nodes.get(ref.node)
                if node is not None and ops_mod.opdef(node.op).num_outputs(node) == 0 \
                        and (ref.node, root) in done:
                    results.append(None)
                    continue
                raise ExecutorError(f"fetch {ref} was never produced")
            if v is _DEAD:
                raise ExecutorError(f"fetch {ref} is dead (untaken branch)")
            results.append(v)
        return results
