"""§4.4 control flow: Switch/Merge/Enter/Exit/NextIteration + builders.

High-level ``cond``/``while_loop`` constructs are compiled into the five
primitive operators exactly as the paper describes; the eager executor
interprets the primitives with tagged frames (executor.py).  The builders
additionally record a structured spec (graph.loop_specs / cond_specs) so
the JIT lowering can emit ``lax.cond`` / ``lax.while_loop`` for the same
subgraphs — the §10 compiler path for cyclic dataflow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .graph import Graph, Node, TensorRef, as_ref
from .ops import GraphBuilder


def static_frames(g: Graph, names: Optional[Iterable[str]] = None
                  ) -> Dict[str, Tuple[str, ...]]:
    """Static frame path (tuple of frame names) per node (§4.4).

    ``Enter`` pushes its ``frame`` attr onto the producing path, ``Exit``
    pops it, every other node lives in the deepest frame of its inputs —
    loop-invariant values produced in an *outer* frame are read from the
    outer context by consumers in inner frames (TF's is_constant-Enter
    semantics without materialising extra nodes).  Shared by the
    executor's tagged-frame interpreter, the §3.2.2 frame-aware
    partitioner, the §5.2 Recv scheduler and the §7 fusion pass, all of
    which must agree on which frame a node executes in.
    """
    keep = set(names) if names is not None else set(g.nodes)
    frames: Dict[str, Tuple[str, ...]] = {n: () for n in keep}
    # Fixpoint over the (cycle-tolerant) topological order: all frame
    # information flows along forward data edges, so one sweep propagates
    # every path and the second merely confirms convergence.  Iterating an
    # unordered set instead can need one sweep per chain hop and silently
    # truncate at the cap — wrong (root) frames for deep loop bodies.
    order = g.topo_sort(keep)
    for _ in range(64):
        changed = False
        for name in order:
            node = g.nodes[name]
            if node.op == "Enter":
                base = frames.get(node.inputs[0].node, ()) if node.inputs else ()
                f = base + (node.attrs["frame"],)
            elif node.op == "Exit":
                f = frames.get(node.inputs[0].node, ())[:-1] if node.inputs else ()
            else:
                f = frames[name]
                for ref in node.inputs:
                    pf = frames.get(ref.node, ())
                    if len(pf) > len(f):
                        f = pf
            if f != frames[name]:
                frames[name] = f
                changed = True
        if not changed:
            return frames
    raise ValueError(
        "static_frames did not converge: malformed Enter/Exit nesting?")


@dataclasses.dataclass
class LoopSpec:
    name: str
    init_refs: List[TensorRef]          # initial loop-variable values (outside)
    merge_names: List[str]              # per-var Merge node (loop-var binding point)
    pred_ref: TensorRef                 # cond output
    cond_nodes: List[str]               # nodes built by cond_fn
    body_nodes: List[str]               # nodes built by body_fn
    body_out_refs: List[TensorRef]      # per-var next value
    switch_names: List[str]
    exit_names: List[str]               # per-var Exit node (loop results)


@dataclasses.dataclass
class CondSpec:
    name: str
    pred_ref: TensorRef
    input_refs: List[TensorRef]
    switch_names: List[str]
    true_nodes: List[str]
    false_nodes: List[str]
    true_out_refs: List[TensorRef]
    false_out_refs: List[TensorRef]
    merge_names: List[str]              # per-output Merge (results)


def loop_spec_members(lname: str, spec: "LoopSpec") -> List[str]:
    """Every node name belonging to loop ``lname`` (primitives included).

    Shared by the §10 lowering (macro expansion), the §5.1 CSE guard and
    the region-fusion pass — all of which must treat a loop's members as
    one indivisible control-flow unit.
    """
    return (
        spec.cond_nodes + spec.body_nodes + spec.merge_names
        + spec.switch_names + spec.exit_names
        + [f"{lname}/enter{i}" for i in range(len(spec.init_refs))]
        + [f"{lname}/next{i}" for i in range(len(spec.init_refs))]
        + [f"{lname}/cond"]
    )


def cond_spec_members(spec: "CondSpec") -> List[str]:
    """Every node name belonging to a conditional (primitives included)."""
    return (spec.switch_names + spec.true_nodes + spec.false_nodes
            + spec.merge_names)


def while_loop(
    b: GraphBuilder,
    cond_fn: Callable[..., "Node | TensorRef"],
    body_fn: Callable[..., Sequence["Node | TensorRef"]],
    loop_vars: Sequence["Node | TensorRef"],
    name: str = "while",
) -> List[TensorRef]:
    """Build Enter -> Merge -> [cond] -> Switch -> ([body] -> NextIteration | Exit)."""
    g = b.graph
    name = g.unique_name(name)
    init_refs = [as_ref(v) for v in loop_vars]

    enters = [
        g.add_node("Enter", [r], name=f"{name}/enter{i}", attrs={"frame": name})
        for i, r in enumerate(init_refs)
    ]
    # Merge gets its back edge appended after NextIteration exists (cyclic graph).
    merges = [
        g.add_node("Merge", [e], name=f"{name}/merge{i}") for i, e in enumerate(enters)
    ]
    merge_refs = [m.ref for m in merges]

    before = set(g.nodes)
    pred = as_ref(cond_fn(*merge_refs))
    cond_nodes = [n for n in g.nodes if n not in before]
    loop_cond = g.add_node("LoopCond", [pred], name=f"{name}/cond")

    switches = [
        g.add_node("Switch", [m, loop_cond], name=f"{name}/switch{i}")
        for i, m in enumerate(merge_refs)
    ]
    exits = [
        g.add_node("Exit", [TensorRef(s.name, 0)], name=f"{name}/exit{i}")
        for i, s in enumerate(switches)
    ]
    body_in = [TensorRef(s.name, 1) for s in switches]

    before = set(g.nodes)
    body_out = body_fn(*body_in)
    if not isinstance(body_out, (list, tuple)):
        body_out = [body_out]
    body_out_refs = [as_ref(r) for r in body_out]
    body_nodes = [n for n in g.nodes if n not in before]

    for i, (m, out_ref) in enumerate(zip(merges, body_out_refs)):
        nxt = g.add_node("NextIteration", [out_ref], name=f"{name}/next{i}")
        m.inputs.append(nxt.ref)  # the back edge

    g.loop_specs[name] = LoopSpec(
        name=name,
        init_refs=init_refs,
        merge_names=[m.name for m in merges],
        pred_ref=pred,
        cond_nodes=cond_nodes,
        body_nodes=body_nodes,
        body_out_refs=body_out_refs,
        switch_names=[s.name for s in switches],
        exit_names=[e.name for e in exits],
    )
    return [e.ref for e in exits]


def cond(
    b: GraphBuilder,
    pred: "Node | TensorRef",
    true_fn: Callable[..., Sequence["Node | TensorRef"]],
    false_fn: Callable[..., Sequence["Node | TensorRef"]],
    inputs: Sequence["Node | TensorRef"],
    name: str = "cond",
) -> List[TensorRef]:
    """Switch each input on pred; Merge the branch results (§4.4)."""
    g = b.graph
    name = g.unique_name(name)
    pred_ref = as_ref(pred)
    input_refs = [as_ref(x) for x in inputs]

    switches = [
        g.add_node("Switch", [r, pred_ref], name=f"{name}/switch{i}")
        for i, r in enumerate(input_refs)
    ]
    t_in = [TensorRef(s.name, 1) for s in switches]
    f_in = [TensorRef(s.name, 0) for s in switches]

    before = set(g.nodes)
    t_out = true_fn(*t_in)
    t_out = t_out if isinstance(t_out, (list, tuple)) else [t_out]
    t_refs = [as_ref(r) for r in t_out]
    true_nodes = [n for n in g.nodes if n not in before]

    before = set(g.nodes)
    f_out = false_fn(*f_in)
    f_out = f_out if isinstance(f_out, (list, tuple)) else [f_out]
    f_refs = [as_ref(r) for r in f_out]
    false_nodes = [n for n in g.nodes if n not in before]

    if len(t_refs) != len(f_refs):
        raise ValueError("true_fn and false_fn must return the same number of outputs")

    merges = [
        g.add_node("Merge", [tr, fr], name=f"{name}/merge{i}")
        for i, (tr, fr) in enumerate(zip(t_refs, f_refs))
    ]
    g.cond_specs[name] = CondSpec(
        name=name,
        pred_ref=pred_ref,
        input_refs=input_refs,
        switch_names=[s.name for s in switches],
        true_nodes=true_nodes,
        false_nodes=false_nodes,
        true_out_refs=t_refs,
        false_out_refs=f_refs,
        merge_names=[m.name for m in merges],
    )
    return [m.ref for m in merges]
