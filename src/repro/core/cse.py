"""§5.1 common subexpression elimination (after Click's GVN).

Canonicalises multiple copies of operations with identical op types,
attributes and (canonicalised) inputs to a single node and redirects
edges.  Stateful ops, placeholders and ops with unhashable attrs (e.g.
closures on ``Call`` nodes, unless they are the *same* function object)
are never merged.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .graph import Graph, TensorRef
from . import ops as ops_mod

_NEVER_MERGE = {"Placeholder", "Variable", "Recv", "Switch", "Merge", "Enter",
                "Exit", "NextIteration"}


def _attr_key(attrs) -> Tuple:
    items = []
    for k in sorted(attrs):
        v = attrs[k]
        try:
            hash(v)
        except TypeError:
            v = id(v)  # closures: identical only if the same object
        items.append((k, v))
    return tuple(items)


def eliminate_common_subexpressions(g: Graph, node_names=None) -> Dict[str, str]:
    """Rewrite ``g`` in place; return {eliminated_node: survivor}.

    ``node_names`` restricts which nodes may be *merged* (eliminated or
    chosen as a survivor); edges of every node are still rewired.  The
    region-fusion pass uses this to scope CSE to one device's fusible
    node set so nodes are never merged across devices or into
    control-flow bodies.
    """
    canonical: Dict[Tuple, str] = {}
    replaced: Dict[str, str] = {}
    mergeable = set(node_names) if node_names is not None else None

    def resolve(ref: TensorRef) -> TensorRef:
        while ref.node in replaced:
            ref = TensorRef(replaced[ref.node], ref.port)
        return ref

    for name in g.topo_sort():
        node = g.nodes[name]
        node.inputs = [resolve(r) for r in node.inputs]
        node.control_inputs = [replaced.get(c, c) for c in node.control_inputs]
        if node.op in _NEVER_MERGE or ops_mod.opdef(node.op).stateful:
            continue
        if mergeable is not None and name not in mergeable:
            continue
        key = (
            node.op,
            tuple(str(r) for r in node.inputs),
            tuple(sorted(node.control_inputs)),
            _attr_key(node.attrs),
            node.device,
        )
        if key in canonical:
            replaced[name] = canonical[key]
        else:
            canonical[key] = name

    for dead in replaced:
        del g.nodes[dead]
    # fix edges in survivors that pointed at eliminated nodes
    for node in g.nodes.values():
        node.inputs = [resolve(r) for r in node.inputs]
        node.control_inputs = [replaced.get(c, c) for c in node.control_inputs]
    return replaced
