"""§5.5 lossy compression on cross-device edges.

The paper truncates an IEEE float32 to a "32-bit float with 16 bits less
mantissa" for transmission and zero-fills on the receiving side (cheaper
than probabilistic rounding).  Keeping the top 16 bits of a float32 —
sign, 8 exponent bits, 7 mantissa bits — is exactly the bfloat16 bit
pattern, which is why this 2015 trick is native TPU arithmetic today
(DESIGN.md §2).  We implement the bit-level contract faithfully: the wire
type is uint16 and decompression is a zero-fill shift, deterministic,
never a hardware cast.  A Pallas TPU kernel with the same semantics lives
in ``repro.kernels.compress16``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_f32_to_16(x: jax.Array) -> jax.Array:
    """float32 -> uint16 wire format (truncate low 16 mantissa bits)."""
    x = jnp.asarray(x, dtype=jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return (bits >> 16).astype(jnp.uint16)


def decompress_16_to_f32(w: jax.Array) -> jax.Array:
    """uint16 wire format -> float32 by zero-filling the lost mantissa."""
    bits = w.astype(jnp.uint32) << 16
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def roundtrip(x: jax.Array) -> jax.Array:
    return decompress_16_to_f32(compress_f32_to_16(x))


def max_relative_error() -> float:
    """Truncating 16 mantissa bits leaves 7; worst-case rel err < 2**-7."""
    return 2.0 ** -7
