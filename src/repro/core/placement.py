"""§3.2.1 cost-model-driven greedy placement + §4.3 device constraints.

The placer runs a *simulated execution* of the graph: it walks nodes in
dependency order, and for each node examines the set of feasible devices
(a device is feasible if it provides a kernel for the op and satisfies the
node's partial constraint).  Placing the node on each candidate is scored
by simulated completion time = max(device free time, inputs ready time +
cross-device transfer time) + estimated compute time; the device where the
node would *finish soonest* wins.  Colocation constraints are resolved
first with union-find over the colocation graph, intersecting feasible
sets per component (§4.3).

The cost model is either static (bytes/FLOP heuristics per op type) or
measured (fed back from executor traces) — both paths the paper describes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph, Node, TensorRef
from . import ops as ops_mod
from ..obs.metrics import StatsDict
from ..runtime.devices import DeviceSet

WIRE_LATENCY_S = 25e-6  # per cross-device hop
WIRE_BYTES_PER_S = 12.5e9  # ~100 Gb/s interconnect

# pass-invocation counter: the Executable cache's contract is that this
# pass runs once per run *signature*, not once per Session.run — tests and
# benchmarks assert on it (DESIGN.md §5).  Registry-backed since §16.4.
STATS = StatsDict("placement", keys=("place_calls",))


@dataclasses.dataclass
class CostModel:
    """Tensor sizes + per-(node, device-kind) compute-time estimates."""

    # measured overrides: {(node_name): seconds}, {(node_name, port): bytes}
    measured_time: Dict[str, float] = dataclasses.field(default_factory=dict)
    measured_bytes: Dict[Tuple[str, int], int] = dataclasses.field(default_factory=dict)

    def output_bytes(self, node: Node, port: int = 0) -> int:
        if (node.name, port) in self.measured_bytes:
            return self.measured_bytes[(node.name, port)]
        shape = node.attrs.get("shape")
        if shape:
            return int(np.prod(shape)) * 4
        val = node.attrs.get("value")
        if val is not None:
            return int(np.asarray(val).nbytes)
        return 4 * 1024  # default guess

    def compute_seconds(self, node: Node, device) -> float:
        if node.name in self.measured_time:
            return self.measured_time[node.name]
        # static heuristic: matmul-ish ops are compute bound, others move bytes
        heavy = {"MatMul": 100.0, "Call": 10.0, "SoftmaxXent": 5.0}
        weight = heavy.get(node.op, 1.0)
        nbytes = self.output_bytes(node)
        return weight * nbytes / device.bytes_per_sec + 1e-6

    def record_measurement(self, node_name: str, seconds: float,
                           out_bytes: Optional[List[int]] = None) -> None:
        self.measured_time[node_name] = seconds
        for p, b in enumerate(out_bytes or []):
            self.measured_bytes[(node_name, p)] = b


class _UnionFind:
    def __init__(self):
        self.parent: Dict[str, str] = {}

    def find(self, x: str) -> str:
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: str, b: str) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


class PlacementError(Exception):
    pass


def feasible_devices(node: Node, devices: DeviceSet) -> List[str]:
    od = ops_mod.opdef(node.op)
    by_kind = set(devices.feasible(od.device_kinds))
    by_constraint = set(devices.matches(node.device))
    out = [n for n in devices.names() if n in by_kind and n in by_constraint]
    return out


def colocation_groups(g: Graph, node_names) -> Dict[str, List[str]]:
    """§4.3: union-find over 'colocate_with' attrs; Assign ops colocate with
    their Variable (state must live with its mutations).

    §4.4: each while-loop's control skeleton (Enter/Merge/Switch/Exit/
    NextIteration/LoopCond) plus its predicate computation is one
    colocation group — the frame's *home* device.  The loop *body* places
    freely; the partitioner replicates the skeleton on every other
    participating device and broadcasts the predicate from home once per
    iteration (partition._replicate_loop_frames), so distributing the
    skeleton itself would only add per-iteration round trips.
    """
    from . import control_flow as cf_mod

    uf = _UnionFind()
    name_set = set(node_names)
    for name in node_names:
        node = g.nodes[name]
        uf.find(name)
        target = node.attrs.get("colocate_with")
        if target:
            uf.union(target, name)
        if node.op in ("Assign", "AssignAdd", "Variable") and node.inputs:
            uf.union(node.inputs[0].node, name)
    for lname, spec in g.loop_specs.items():
        body = set(spec.body_nodes)
        skeleton = [m for m in cf_mod.loop_spec_members(lname, spec)
                    if m in name_set and m not in body]
        for a, b in zip(skeleton, skeleton[1:]):
            uf.union(a, b)
    groups: Dict[str, List[str]] = {}
    for name in node_names:
        groups.setdefault(uf.find(name), []).append(name)
    return groups


def _describe_infeasible_group(g: Graph, root: str, members) -> str:
    """§14 Diagnostic-formatted colocation failure: names every
    constrained member and its device, and — when the group is a loop
    skeleton whose predicate carries a conflicting constraint — states
    the carried predicate-on-home-device rule (F302) explicitly instead
    of the old bare 'no feasible device for group of <root>'."""
    from ..analysis.diagnostics import make

    constrained = [(m, g.nodes[m].device) for m in members
                   if g.nodes[m].device]
    devices = sorted({d for _, d in constrained})
    in_loop = None
    for lname, spec in g.loop_specs.items():
        skel = set(spec.switch_names) | set(spec.merge_names) | \
            set(spec.cond_nodes) | {f"{lname}/cond"}
        if skel & set(members):
            in_loop = lname
            break
    if in_loop is not None and len(devices) > 1:
        d = make(
            "F302",
            f"loop {in_loop!r}'s skeleton + predicate form one "
            f"colocation group (the predicate must compute on the "
            f"loop's home device, §4.4) but its members carry "
            f"conflicting device constraints: "
            + ", ".join(f"{m!r} on {dev!r}" for m, dev in constrained),
            nodes=[m for m, _ in constrained] or [root],
            devices=devices,
            fix="drop the conflicting constraint or pin the whole "
                "predicate to the loop's home device")
        return "no feasible device for colocation group: " + d.format()
    detail = (", ".join(f"{m!r} (device={dev!r})" for m, dev in constrained)
              or f"members {sorted(members)[:8]}")
    return (f"no feasible device for colocation group of {root!r}: "
            f"constrained members: {detail}")


def place(
    g: Graph,
    devices: DeviceSet,
    cost_model: Optional[CostModel] = None,
    node_names=None,
) -> Dict[str, str]:
    """Greedy simulated placement; returns {node_name: device_name}."""
    STATS["place_calls"] += 1
    cm = cost_model or CostModel()
    names = list(node_names) if node_names is not None else list(g.nodes)
    name_set = set(names)

    groups = colocation_groups(g, names)
    group_of = {n: root for root, members in groups.items() for n in members}
    group_feasible: Dict[str, List[str]] = {}
    for root, members in groups.items():
        feas = None
        for m in members:
            f = set(feasible_devices(g.nodes[m], devices))
            feas = f if feas is None else (feas & f)
        if not feas:
            raise PlacementError(_describe_infeasible_group(g, root, members))
        group_feasible[root] = [d for d in devices.names() if d in feas]

    placement: Dict[str, str] = {}
    group_device: Dict[str, str] = {}
    device_free: Dict[str, float] = {d: 0.0 for d in devices.names()}
    finish: Dict[str, float] = {}

    for name in g.topo_sort(name_set):
        node = g.nodes[name]
        root = group_of[name]
        if root in group_device:
            dev_name = group_device[root]
            # still advance the simulation clocks for this node
            start = device_free[dev_name]
            for ref in node.inputs:
                if ref.node not in name_set:
                    continue
                t = finish.get(ref.node, 0.0)
                if placement.get(ref.node) != dev_name:
                    t += WIRE_LATENCY_S + cm.output_bytes(g.nodes[ref.node], ref.port) / WIRE_BYTES_PER_S
                start = max(start, t)
            end = start + cm.compute_seconds(node, devices[dev_name])
            device_free[dev_name] = end
            finish[name] = end
            placement[name] = dev_name
            continue

        best: Tuple[float, str] = (float("inf"), "")
        for dev_name in group_feasible[root]:
            start = device_free[dev_name]
            for ref in node.inputs:
                if ref.node not in name_set:
                    continue
                t = finish.get(ref.node, 0.0)
                if placement.get(ref.node) != dev_name:
                    t += WIRE_LATENCY_S + cm.output_bytes(g.nodes[ref.node], ref.port) / WIRE_BYTES_PER_S
                start = max(start, t)
            end = start + cm.compute_seconds(node, devices[dev_name])
            if end < best[0]:
                best = (end, dev_name)
        dev_name = best[1]
        group_device[root] = dev_name
        placement[name] = dev_name
        device_free[dev_name] = best[0]
        finish[name] = best[0]
    return placement
