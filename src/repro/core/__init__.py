"""repro.core — the paper's dataflow-graph system (TensorFlow, 2015).

Public surface:
  Graph / Node / TensorRef      §2 graph IR
  GraphBuilder                  §2 Python front-end
  Session / SessionOptions      §2 Sessions (Extend/Run), §4.2 partial execution;
                                all config on one options object (§15)
  gradients                     §4.1 autodiff by graph extension
  while_loop / cond             §4.4 control flow builders
  compile_subgraph              §10 JIT lowering to a pure JAX function
  numerics                      §9 tolerance-gated fast-numerics parity
                                (import as a submodule — not re-exported
                                here so `python -m repro.core.numerics`
                                stays runpy-clean)
"""
from .graph import Graph, Node, TensorRef, GraphError, as_ref
from .ops import GraphBuilder, register, register_gradient, register_kernel, REGISTRY
from .executable import Executable, ExecutableCache, RunSignature
from .options import SessionOptions
from .session import Session
from .autodiff import gradients
from .control_flow import while_loop, cond
from .lowering import compile_subgraph, lower_region, Lowered, LoweringError
from .fusion import FusionError, FusionResult, RegionSpec

__all__ = [
    "Graph", "Node", "TensorRef", "GraphError", "as_ref",
    "GraphBuilder", "register", "register_gradient", "register_kernel", "REGISTRY",
    "Executable", "ExecutableCache", "RunSignature",
    "Session", "SessionOptions", "gradients", "while_loop", "cond",
    "compile_subgraph", "lower_region", "Lowered", "LoweringError",
    "FusionError", "FusionResult", "RegionSpec",
]
