"""§3.2/§3.3 multi-device eager execution: place -> partition -> run.

One Executor per device, each in its own thread (the paper's per-worker
decentralised scheduling: the master issues a single Run per participating
device and Send/Recv impart all cross-device synchronisation).  All
executors share the Session's variable store, queues, and a per-run
rendezvous.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Set

from .graph import TensorRef
from .executor import ExecutionContext, Executor
from . import placement as placement_mod
from . import partition as partition_mod
from . import scheduler as scheduler_mod
from ..runtime.rendezvous import Rendezvous


def run_partitioned(
    session,
    node_set: Set[str],
    fetch_refs: Sequence[TensorRef],
    feeds: Dict[TensorRef, Any],
    trace: Optional[List[str]] = None,
    compress: bool = False,
    cost_model=None,
    tracer=None,
) -> List[Any]:
    g = session.graph
    devices = session.devices
    cm = cost_model or placement_mod.CostModel()

    place = placement_mod.place(g, devices, cm, node_set)
    parted = partition_mod.partition(g, place, node_set, compress=compress)
    scheduler_mod.schedule_recvs(
        parted.graph, set(parted.graph.nodes), cm, devices, parted.placement)

    run_rdv = Rendezvous()
    results: Dict[int, Any] = {}
    errors: List[BaseException] = []
    lock = threading.Lock()

    # fetches grouped by owning device
    fetch_by_dev: Dict[str, List[int]] = {}
    for i, ref in enumerate(fetch_refs):
        dev = parted.placement[ref.node]
        fetch_by_dev.setdefault(dev, []).append(i)

    def worker(dev_name: str, names: Set[str]) -> None:
        ctx = ExecutionContext(
            variables=session.variables,
            rendezvous=run_rdv,
            queues=session.queues,
            checkpoint_io=session.checkpoint_io,
            device_kind=dev_name.split("device:")[-1].split(":")[0],
        )
        local_trace: Optional[List[str]] = [] if trace is not None else None
        ex = Executor(parted.graph, ctx, node_filter=names, trace=local_trace,
                      tracer=tracer, device_label=dev_name)
        idxs = fetch_by_dev.get(dev_name, [])
        local_fetches = [fetch_refs[i] for i in idxs]
        try:
            vals = ex.run(local_fetches, feeds)
            with lock:
                for i, v in zip(idxs, vals):
                    results[i] = v
                if trace is not None:
                    trace.extend(local_trace or [])
        except BaseException as e:  # noqa: BLE001 — §3.3: surface any worker failure
            with lock:
                errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(dev, names), daemon=True)
        for dev, names in parted.device_nodes.items()
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    if errors:
        # §3.3 fault tolerance: abort the whole graph execution on any failure
        raise errors[0]
    return [results[i] for i in range(len(fetch_refs))]
