"""§3.2/§3.3 multi-device eager execution — thin front of the Executable.

Historically this module re-ran place -> partition -> schedule and rebuilt
per-device executors on every call.  That whole pipeline now lives in
:class:`repro.core.executable.Executable`, which prepares the worker
structure once and reuses it across runs (the paper's master-side graph
cache, DESIGN.md §5); ``run_partitioned`` survives as a compatibility
entry point that builds a one-off Executable and runs it.

Worker failure semantics (§3.3): any worker exception aborts the whole
graph execution; workers that never finish within ``timeout`` raise an
:class:`~repro.core.executor.ExecutorError` naming the stuck device(s)
*and their owning worker process* (in-process: thread + pid; cluster:
task + host:port + pid via repro.distrib) instead of silently dropping
their fetches.

When the session carries a ``cluster=`` spec (DESIGN.md §11) the same
entry point executes across OS processes: the Executable ships each
per-device subgraph to its owning worker and Send/Recv — including the
§5.5 ``compress=True`` lossy wire compression — ride the TCP
:class:`~repro.distrib.wire.WireRendezvous` instead of the in-process
table.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Set

from .graph import TensorRef
from .executable import Executable


def run_partitioned(
    session,
    node_set: Set[str],
    fetch_refs: Sequence[TensorRef],
    feeds: Dict[TensorRef, Any],
    trace: Optional[List[str]] = None,
    compress: bool = False,
    cost_model=None,
    tracer=None,
    timeout: float = 60.0,
) -> List[Any]:
    exe = Executable(session, fetch_refs, feeds.keys(), node_set=node_set,
                     compress=compress, cost_model=cost_model,
                     force_partitioned=True)
    return exe.run(feeds, trace=trace, tracer=tracer, timeout=timeout)
