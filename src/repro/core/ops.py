"""Operation registry + the core op set of Table 1.

An *operation* is an abstract computation; a *kernel* is a device-specific
implementation (§2 "Operations and Kernels").  ``OpDef.compute`` is the
reference kernel written with ``jax.numpy`` so the same definition serves
both the eager executor (running on concrete arrays) and the JIT lowering
(running on tracers).  Per-device kernel overrides (e.g. a Pallas TPU
kernel for MatMul) are registered in ``OpDef.kernels`` keyed by device
type, mirroring the paper's kernel-registration mechanism.
"""
from __future__ import annotations

import collections
import dataclasses
import importlib
import pickle
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .graph import Graph, Node, TensorRef

# ---------------------------------------------------------------------------
# Registry


@dataclasses.dataclass
class OpDef:
    name: str
    compute: Callable[..., Tuple[Any, ...]]  # (ctx, node, *inputs) -> tuple outputs
    num_outputs: Callable[[Node], int]
    grad: Optional[Callable[..., List[Any]]] = None  # (node, inputs, outputs, gouts) -> gins
    stateful: bool = False
    # device kinds that provide a kernel for this op (§3.2.1 feasibility)
    device_kinds: Tuple[str, ...] = ("cpu", "tpu", "gpu")
    # per-device-kind kernel overrides: {"tpu": fn(ctx, node, *inputs)}
    kernels: Dict[str, Callable[..., Tuple[Any, ...]]] = dataclasses.field(default_factory=dict)


REGISTRY: Dict[str, OpDef] = {}


def register(
    name: str,
    *,
    num_outputs: "int | Callable[[Node], int]" = 1,
    grad: Optional[Callable[..., List[Any]]] = None,
    stateful: bool = False,
    device_kinds: Tuple[str, ...] = ("cpu", "tpu", "gpu"),
) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        n_out = num_outputs if callable(num_outputs) else (lambda node, k=num_outputs: k)
        REGISTRY[name] = OpDef(
            name=name, compute=fn, num_outputs=n_out, grad=grad,
            stateful=stateful, device_kinds=device_kinds,
        )
        return fn

    return deco


def register_gradient(op_name: str) -> Callable[[Callable], Callable]:
    """§4.1: "A gradient function may be registered by any operation"."""

    def deco(fn: Callable) -> Callable:
        REGISTRY[op_name].grad = fn
        return fn

    return deco


def register_kernel(op_name: str, device_kind: str) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        REGISTRY[op_name].kernels[device_kind] = fn
        return fn

    return deco


def opdef(name: str) -> OpDef:
    if name not in REGISTRY:
        raise KeyError(f"unregistered op {name!r}")
    return REGISTRY[name]


def is_stateful(node: Node) -> bool:
    return opdef(node.op).stateful


# ---------------------------------------------------------------------------
# Graph-builder helpers (the Python "front end" of §2, Figure 1)


class GraphBuilder:
    """Thin convenience layer used by clients and tests to build graphs."""

    def __init__(self, graph: Optional[Graph] = None) -> None:
        self.graph = graph or Graph()

    def _op(self, op, inputs=(), name=None, attrs=None, control_inputs=(), device=None) -> Node:
        return self.graph.add_node(op, inputs, name=name, attrs=attrs,
                                   control_inputs=control_inputs, device=device)

    # --- leaf / stateful
    def placeholder(self, name="placeholder", shape=None, dtype=None) -> Node:
        return self._op("Placeholder", name=name, attrs={"shape": shape, "dtype": dtype})

    def constant(self, value, name="const", device=None) -> Node:
        return self._op("Const", name=name, attrs={"value": value}, device=device)

    def variable(self, name, init_value=None, *, container="", sharding=None, device=None) -> Node:
        return self._op("Variable", name=name, device=device,
                        attrs={"init": init_value, "container": container, "sharding": sharding})

    def assign(self, var: Node, value, name=None, control_inputs=()) -> Node:
        return self._op("Assign", [var, value], name=name or f"{var.name}/assign",
                        control_inputs=control_inputs)

    def assign_add(self, var: Node, value, name=None, control_inputs=()) -> Node:
        return self._op("AssignAdd", [var, value], name=name or f"{var.name}/assign_add",
                        control_inputs=control_inputs)

    def group(self, deps: Sequence[Node], name="group") -> Node:
        """A no-output op that completes when all ``deps`` complete."""
        return self._op("NoOp", name=name, control_inputs=list(deps))

    # --- math
    def add(self, a, b, name="add", device=None):
        return self._op("Add", [a, b], name=name, device=device)

    def sub(self, a, b, name="sub", device=None):
        return self._op("Sub", [a, b], name=name, device=device)

    def mul(self, a, b, name="mul", device=None):
        return self._op("Mul", [a, b], name=name, device=device)

    def div(self, a, b, name="div", device=None):
        return self._op("Div", [a, b], name=name, device=device)

    def exp(self, a, name="exp", device=None):
        return self._op("Exp", [a], name=name, device=device)

    def log(self, a, name="log", device=None):
        return self._op("Log", [a], name=name, device=device)

    def neg(self, a, name="neg", device=None):
        return self._op("Neg", [a], name=name, device=device)

    def square(self, a, name="square", device=None):
        return self._op("Square", [a], name=name, device=device)

    def rsqrt(self, a, name="rsqrt", device=None):
        return self._op("Rsqrt", [a], name=name, device=device)

    def greater(self, a, b, name="greater"):
        return self._op("Greater", [a, b], name=name)

    def less(self, a, b, name="less"):
        return self._op("Less", [a, b], name=name)

    def equal(self, a, b, name="equal"):
        return self._op("Equal", [a, b], name=name)

    # --- array
    def concat(self, xs, axis=0, name="concat"):
        return self._op("Concat", list(xs), name=name, attrs={"axis": axis})

    def slice_(self, x, begin, size, name="slice"):
        return self._op("Slice", [x], name=name, attrs={"begin": tuple(begin), "size": tuple(size)})

    def reshape(self, x, shape, name="reshape"):
        return self._op("Reshape", [x], name=name, attrs={"shape": tuple(shape)})

    def shape(self, x, name="shape"):
        return self._op("Shape", [x], name=name)

    def rank(self, x, name="rank"):
        return self._op("Rank", [x], name=name)

    def reduce_sum(self, x, axis=None, name="reduce_sum", device=None,
                   keepdims=False):
        return self._op("ReduceSum", [x], name=name,
                        attrs={"axis": axis, "keepdims": keepdims},
                        device=device)

    def reduce_mean(self, x, axis=None, name="reduce_mean", device=None,
                    keepdims=False):
        return self._op("ReduceMean", [x], name=name,
                        attrs={"axis": axis, "keepdims": keepdims},
                        device=device)

    def cast(self, x, dtype, name="cast"):
        return self._op("Cast", [x], name=name, attrs={"dtype": jnp.dtype(dtype).name})

    # --- matrix / NN
    def matmul(self, a, b, name="matmul", device=None):
        return self._op("MatMul", [a, b], name=name, device=device)

    def relu(self, x, name="relu", device=None):
        return self._op("ReLU", [x], name=name, device=device)

    def sigmoid(self, x, name="sigmoid"):
        return self._op("Sigmoid", [x], name=name)

    def tanh(self, x, name="tanh"):
        return self._op("Tanh", [x], name=name)

    def softmax(self, x, name="softmax", device=None):
        return self._op("SoftMax", [x], name=name, device=device)

    def softmax_xent(self, logits, labels, name="softmax_xent"):
        """Mean softmax cross-entropy with integer labels."""
        return self._op("SoftmaxXent", [logits, labels], name=name)

    # --- LM-block idioms as primitive ops.  These are the shapes the
    # kernel-backend registry pattern-matches (DESIGN.md §12): built from
    # primitives they lower through generic XLA, and under
    # Session(backend="pallas") fused-region lowering rewrites them onto
    # the hand-written kernels.
    def rmsnorm(self, x, w, eps=1e-5, name="rmsnorm", device=None):
        """``x * rsqrt(mean(x^2, -1) + eps) * w`` over the last axis."""
        sq = self.square(x, name=f"{name}/sq", device=device)
        ms = self.reduce_mean(sq, axis=-1, name=f"{name}/ms", device=device,
                              keepdims=True)
        epsc = self.constant(jnp.float32(eps), name=f"{name}/eps",
                             device=device)
        veps = self.add(ms, epsc, name=f"{name}/veps", device=device)
        rs = self.rsqrt(veps, name=f"{name}/rs", device=device)
        norm = self.mul(x, rs, name=f"{name}/norm", device=device)
        return self.mul(norm, w, name=name, device=device)

    def attention(self, q, kT, v, scale=None, name="attn", device=None):
        """``softmax(q @ kT * scale) @ v`` — q (S,D), kT (D,T), v (T,D)."""
        s = self.matmul(q, kT, name=f"{name}/scores", device=device)
        if scale is not None:
            sc = self.constant(jnp.float32(scale), name=f"{name}/scale",
                               device=device)
            s = self.mul(s, sc, name=f"{name}/scaled", device=device)
        p = self.softmax(s, name=f"{name}/probs", device=device)
        return self.matmul(p, v, name=name, device=device)

    def ssd_scan(self, x, dt, A_log, Bc, Cc, D_skip, chunk=128, name="ssd",
                 device=None):
        """Mamba-2 SSD scan in the models layout: x (B,S,H,P),
        dt (B,S,H), A_log (H,), Bc/Cc (B,S,G,N), D_skip (H,)."""
        return self._op("SSDScan", [x, dt, A_log, Bc, Cc, D_skip],
                        name=name, attrs={"chunk": chunk}, device=device)

    # --- composite escape hatch: any pure jax-traceable function as one node.
    def call(self, fn: Callable, inputs: Sequence, name="call", n_out=1, attrs=None, device=None):
        a = dict(attrs or {})
        a["fn"] = fn
        a["n_out"] = n_out
        return self._op("Call", list(inputs), name=name, attrs=a, device=device)

    def call_factory(self, factory: str, inputs: Sequence, *, args: Sequence = (),
                     kwargs: Optional[Dict[str, Any]] = None, name="call",
                     n_out=1, attrs=None, device=None) -> Node:
        """A *wire-shippable* Call (DESIGN.md §15): instead of capturing a
        Python callable (which cannot ship over the wire when it closes over
        locals), the node carries an importable ``"module:qualname"`` factory
        spec plus static ``args``/``kwargs``.  Every process that executes
        the node rebuilds the kernel as ``factory(*args, **kwargs)`` — once,
        memoised per ``(factory, args)`` — so the same graph runs in-process
        and on remote workers.  ``args``/``kwargs`` must be picklable."""
        if not isinstance(factory, str) or ":" not in factory:
            raise ValueError(
                f"call_factory expects an importable 'module:qualname' spec, "
                f"got {factory!r}")
        a = dict(attrs or {})
        a["call_factory"] = factory
        a["factory_args"] = tuple(args)
        a["factory_kwargs"] = dict(kwargs or {})
        a["n_out"] = n_out
        return self._op("Call", list(inputs), name=name, attrs=a, device=device)

    # --- io / checkpoint / queues (stateful)
    def save(self, variables: Sequence[Node], path_attr: str, name="save"):
        return self._op("Save", list(variables), name=name,
                        attrs={"path": path_attr, "var_names": [v.name for v in variables]})

    def restore(self, variables: Sequence[Node], path_attr: str, name="restore"):
        return self._op("Restore", [], name=name,
                        attrs={"path": path_attr, "var_names": [v.name for v in variables]})


# ---------------------------------------------------------------------------
# Op implementations.  compute(ctx, node, *inputs) -> tuple of outputs.


def _unary(fn):
    def compute(ctx, node, x):
        return (fn(x),)
    return compute


def _binary(fn):
    def compute(ctx, node, a, b):
        return (fn(a, b),)
    return compute


# --- leaves ---------------------------------------------------------------

@register("Placeholder")
def _placeholder(ctx, node):
    raise RuntimeError(f"placeholder {node.name!r} was not fed")


@register("Const")
def _const(ctx, node):
    return (jnp.asarray(node.attrs["value"]),)


@register("NoOp", num_outputs=0)
def _noop(ctx, node):
    return ()


@register("Identity", grad=lambda node, ins, outs, g: [g[0]])
def _identity(ctx, node, x):
    return (x,)


# --- stateful variables (§2 Variables) -------------------------------------

@register("Variable", stateful=True)
def _variable(ctx, node):
    return (ctx.read_variable(node),)


@register("Assign", stateful=True)
def _assign(ctx, node, var_val, new_val):
    ctx.write_variable(node.inputs[0].node, new_val)
    return (new_val,)


@register("AssignAdd", stateful=True)
def _assign_add(ctx, node, var_val, delta):
    new = var_val + delta
    ctx.write_variable(node.inputs[0].node, new)
    return (new,)


# --- element-wise math ------------------------------------------------------

register("Add", grad=lambda n, i, o, g: [_unbroadcast(g[0], jnp.shape(i[0])),
                                         _unbroadcast(g[0], jnp.shape(i[1]))])(_binary(jnp.add))
register("Sub", grad=lambda n, i, o, g: [_unbroadcast(g[0], jnp.shape(i[0])),
                                         _unbroadcast(-g[0], jnp.shape(i[1]))])(_binary(jnp.subtract))
register("Mul", grad=lambda n, i, o, g: [_unbroadcast(g[0] * i[1], jnp.shape(i[0])),
                                         _unbroadcast(g[0] * i[0], jnp.shape(i[1]))])(_binary(jnp.multiply))
register("Div", grad=lambda n, i, o, g: [_unbroadcast(g[0] / i[1], jnp.shape(i[0])),
                                         _unbroadcast(-g[0] * i[0] / (i[1] * i[1]), jnp.shape(i[1]))])(_binary(jnp.divide))
register("Exp", grad=lambda n, i, o, g: [g[0] * o[0]])(_unary(jnp.exp))
register("Log", grad=lambda n, i, o, g: [g[0] / i[0]])(_unary(jnp.log))
register("Neg", grad=lambda n, i, o, g: [-g[0]])(_unary(jnp.negative))
register("Square", grad=lambda n, i, o, g: [2.0 * i[0] * g[0]])(_unary(jnp.square))
# d/dx x^(-1/2) = -1/2 x^(-3/2) = -o^3 / 2
register("Rsqrt", grad=lambda n, i, o, g: [-0.5 * o[0] ** 3 * g[0]])(
    _unary(jax.lax.rsqrt))
register("Greater", device_kinds=("cpu", "tpu", "gpu"))(_binary(jnp.greater))
register("Less")(_binary(jnp.less))
register("Equal")(_binary(jnp.equal))


def _unbroadcast(g, shape):
    """Sum ``g`` down to ``shape`` (gradient of implicit broadcasting)."""
    if jnp.shape(g) == tuple(shape):
        return g
    g_shape = jnp.shape(g)
    ndiff = len(g_shape) - len(shape)
    axes = tuple(range(ndiff)) + tuple(
        i + ndiff for i, s in enumerate(shape) if s == 1 and g_shape[i + ndiff] != 1
    )
    return jnp.sum(g, axis=axes, keepdims=False).reshape(shape)


# --- array ops ---------------------------------------------------------------

@register("Concat", grad=lambda n, i, o, g: _concat_grad(n, i, g))
def _concat(ctx, node, *xs):
    return (jnp.concatenate(xs, axis=node.attrs["axis"]),)


def _concat_grad(node, ins, g):
    axis = node.attrs["axis"]
    sizes = [jnp.shape(x)[axis] for x in ins]
    splits = list(jnp.cumsum(jnp.array(sizes))[:-1])
    return list(jnp.split(g[0], [int(s) for s in splits], axis=axis))


@register("Slice", grad=lambda n, i, o, g: [_slice_grad(n, i[0], g[0])])
def _slice(ctx, node, x):
    begin, size = node.attrs["begin"], node.attrs["size"]
    return (jax.lax.slice(x, begin, tuple(b + s for b, s in zip(begin, size))),)


def _slice_grad(node, x, g):
    begin = node.attrs["begin"]
    pads = [(b, jnp.shape(x)[d] - b - jnp.shape(g)[d], 0) for d, b in enumerate(begin)]
    return jax.lax.pad(g, jnp.zeros((), g.dtype), pads)


@register("Reshape", grad=lambda n, i, o, g: [jnp.reshape(g[0], jnp.shape(i[0]))])
def _reshape(ctx, node, x):
    return (jnp.reshape(x, node.attrs["shape"]),)


@register("Shape")
def _shape(ctx, node, x):
    return (jnp.asarray(jnp.shape(x), dtype=jnp.int32),)


@register("Rank")
def _rank(ctx, node, x):
    return (jnp.asarray(jnp.ndim(x), dtype=jnp.int32),)


@register("Cast", grad=lambda n, i, o, g: [g[0].astype(jnp.result_type(i[0]))])
def _cast(ctx, node, x):
    return (x.astype(node.attrs["dtype"]),)


@register("ReduceSum", grad=lambda n, i, o, g: [_reduce_sum_grad(n, i[0], g[0])])
def _reduce_sum(ctx, node, x):
    return (jnp.sum(x, axis=node.attrs["axis"],
                    keepdims=bool(node.attrs.get("keepdims", False))),)


def _reduce_sum_grad(node, x, g):
    axis = node.attrs["axis"]
    if axis is None:
        return jnp.broadcast_to(g, jnp.shape(x))
    if not node.attrs.get("keepdims", False):
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        g = jnp.expand_dims(g, axes)
    return jnp.broadcast_to(g, jnp.shape(x))


@register("ReduceMean", grad=lambda n, i, o, g: [_reduce_mean_grad(n, i[0], g[0])])
def _reduce_mean(ctx, node, x):
    return (jnp.mean(x, axis=node.attrs["axis"],
                     keepdims=bool(node.attrs.get("keepdims", False))),)


def _reduce_mean_grad(node, x, g):
    axis = node.attrs["axis"]
    shape = jnp.shape(x)
    if axis is None:
        denom = 1
        for s in shape:
            denom *= s
        return jnp.broadcast_to(g / denom, shape)
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    denom = 1
    for a in axes:
        denom *= shape[a]
    if not node.attrs.get("keepdims", False):
        g = jnp.expand_dims(g, axes)
    return jnp.broadcast_to(g / denom, shape)


# --- matrix / NN -------------------------------------------------------------

@register("MatMul", grad=lambda n, i, o, g: [g[0] @ i[1].T, i[0].T @ g[0]])
def _matmul(ctx, node, a, b):
    return (a @ b,)


register("ReLU", grad=lambda n, i, o, g: [g[0] * (i[0] > 0).astype(g[0].dtype)])(
    _unary(jax.nn.relu))
register("Sigmoid", grad=lambda n, i, o, g: [g[0] * o[0] * (1 - o[0])])(
    _unary(jax.nn.sigmoid))
register("Tanh", grad=lambda n, i, o, g: [g[0] * (1 - o[0] * o[0])])(_unary(jnp.tanh))


@register("SoftMax", grad=lambda n, i, o, g: [_softmax_grad(o[0], g[0])])
def _softmax(ctx, node, x):
    return (jax.nn.softmax(x, axis=-1),)


def _softmax_grad(y, g):
    return y * (g - jnp.sum(y * g, axis=-1, keepdims=True))


@register("SoftmaxXent", grad=lambda n, i, o, g: [_xent_grad(i[0], i[1], g[0]), None])
def _softmax_xent(ctx, node, logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return (jnp.mean(nll),)


def _xent_grad(logits, labels, g):
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    denom = 1
    for s in logits.shape[:-1]:
        denom *= s
    return g * (p - onehot) / denom


@register("SSDScan")
def _ssd_scan_op(ctx, node, x, dt, A_log, Bc, Cc, D_skip):
    """Mamba-2 SSD scan, reference semantics (sequential lax.scan over
    time in f32 — the order-faithful oracle the chunked Pallas kernel is
    gated against).  Layouts match kernels.ops.ssd_scan: x (B,S,H,P),
    dt (B,S,H), A_log (H,), Bc/Cc (B,S,G,N), D_skip (H,)."""
    B, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    a = -jnp.exp(A_log.astype(jnp.float32))                      # (H,)
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P).astype(jnp.float32)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S).astype(jnp.float32)
    af = jnp.tile(a, (B,))                                       # (B*H,)
    Bf = jnp.repeat(Bc, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(B * H, S, N).astype(jnp.float32)
    Cf = jnp.repeat(Cc, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(B * H, S, N).astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                                    # (BH,·)
        dA = jnp.exp(dtt * af)
        state = state * dA[:, None, None] + jnp.einsum(
            "b,bn,bp->bnp", dtt, Bt, xt)
        y = jnp.einsum("bn,bnp->bp", Ct, state)
        return state, y

    state0 = jnp.zeros((B * H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, state0, (
        jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, H, S, P) \
        .transpose(0, 2, 1, 3).astype(x.dtype)
    return (y + D_skip.astype(y.dtype)[None, None, :, None] * x,)


# --- composite (arbitrary pure jax function as a node) ----------------------
#
# Two declaration forms (DESIGN.md §15):
#   - ``attrs["fn"]``: a direct Python callable.  Cheapest, but closures
#     over locals cannot ship to worker processes.
#   - ``attrs["call_factory"]``: an importable ``"module:qualname"`` spec +
#     static ``factory_args``/``factory_kwargs``.  The kernel is rebuilt as
#     ``factory(*args, **kwargs)`` in whichever process executes the node.
#
# Resolution is memoised at two levels: per node-attrs identity (the hot
# per-dispatch lookup) and per ``(factory, pickled args)`` so N replicas of
# the same step build the underlying model once per process.  The cache is
# deliberately NOT stored in ``node.attrs`` — attrs ship over the wire and
# must stay free of unpicklable closures.

_CALL_NODE_CACHE: "collections.OrderedDict[int, Tuple[dict, Callable]]" = \
    collections.OrderedDict()
_CALL_FACTORY_CACHE: Dict[Tuple[str, bytes], Callable] = {}
_CALL_CACHE_LOCK = threading.Lock()
_CALL_NODE_CACHE_MAX = 1024


def _import_factory(spec: str) -> Callable:
    """Import ``"module:qualname"``.  Note the trust boundary: resolving a
    factory imports and runs arbitrary code named by the graph, so workers
    must only register graphs from a trusted master (DESIGN.md §15)."""
    module_name, _, qualname = spec.partition(":")
    if not module_name or not qualname:
        raise ValueError(f"malformed Call factory spec {spec!r} "
                         f"(expected 'module:qualname')")
    try:
        obj: Any = importlib.import_module(module_name)
    except ImportError as e:
        raise ImportError(f"Call factory module {module_name!r} is not "
                          f"importable in this process: {e}") from e
    for part in qualname.split("."):
        try:
            obj = getattr(obj, part)
        except AttributeError as e:
            raise AttributeError(
                f"Call factory {spec!r}: {module_name!r} has no attribute "
                f"path {qualname!r}") from e
    if not callable(obj):
        raise TypeError(f"Call factory {spec!r} resolved to non-callable "
                        f"{obj!r}")
    return obj


def resolve_call_fn(node: Node) -> Callable:
    """Resolve a Call node's kernel: ``attrs["fn"]`` if present, else build
    (and memoise) it from the node's ``call_factory`` spec."""
    attrs = node.attrs
    fn = attrs.get("fn")
    if fn is not None:
        return fn
    key = id(attrs)
    with _CALL_CACHE_LOCK:
        ent = _CALL_NODE_CACHE.get(key)
        if ent is not None and ent[0] is attrs:
            _CALL_NODE_CACHE.move_to_end(key)
            return ent[1]
    spec = attrs.get("call_factory")
    if spec is None:
        raise KeyError(
            f"Call node {node.name!r} has neither an 'fn' nor a "
            f"'call_factory' attr")
    args = tuple(attrs.get("factory_args", ()))
    kwargs = dict(attrs.get("factory_kwargs") or {})
    try:
        fkey: Optional[Tuple[str, bytes]] = (
            spec, pickle.dumps((args, sorted(kwargs.items())), protocol=4))
    except Exception:
        fkey = None  # unpicklable static args: still works, just unshared
    with _CALL_CACHE_LOCK:
        fn = _CALL_FACTORY_CACHE.get(fkey) if fkey is not None else None
    if fn is None:
        fn = _import_factory(spec)(*args, **kwargs)
    with _CALL_CACHE_LOCK:
        if fkey is not None:
            fn = _CALL_FACTORY_CACHE.setdefault(fkey, fn)
        _CALL_NODE_CACHE[key] = (attrs, fn)
        while len(_CALL_NODE_CACHE) > _CALL_NODE_CACHE_MAX:
            _CALL_NODE_CACHE.popitem(last=False)
    return fn


def _call_num_outputs(node: Node) -> int:
    return int(node.attrs.get("n_out", 1))


def _call_grad(node, ins, outs, gouts):
    fn = resolve_call_fn(node)

    def scalar_fn(*args):
        res = fn(*args)
        return res if isinstance(res, tuple) else (res,)

    _, vjp = jax.vjp(scalar_fn, *ins)
    gouts_full = tuple(
        jnp.zeros_like(o) if g is None else g for o, g in zip(outs, gouts)
    )
    return list(vjp(gouts_full))


@register("Call", num_outputs=_call_num_outputs, grad=_call_grad)
def _call(ctx, node, *ins):
    res = resolve_call_fn(node)(*ins)
    return res if isinstance(res, tuple) else (res,)


# --- checkpoint (§3.3) -------------------------------------------------------

@register("Save", num_outputs=0, stateful=True)
def _save(ctx, node, *var_vals):
    ctx.save_checkpoint(node.attrs["path"], dict(zip(node.attrs["var_names"], var_vals)))
    return ()


@register("Restore", num_outputs=0, stateful=True)
def _restore(ctx, node):
    values = ctx.load_checkpoint(node.attrs["path"])
    for vname in node.attrs["var_names"]:
        ctx.write_variable(vname, values[vname])
    return ()


# --- queues (§4.6) -----------------------------------------------------------

@register("QueueEnqueue", num_outputs=0, stateful=True)
def _enqueue(ctx, node, *vals):
    ctx.queue(node.attrs["queue"]).enqueue(tuple(vals))
    return ()


def _dequeue_num_outputs(node: Node) -> int:
    return int(node.attrs.get("n_components", 1))


@register("QueueDequeue", num_outputs=_dequeue_num_outputs, stateful=True)
def _dequeue(ctx, node):
    return tuple(ctx.queue(node.attrs["queue"]).dequeue())


# --- §5.5 lossy compression ops (inserted on cross-device edges) -------------

@register("CompressF32ToB16", grad=lambda n, i, o, g: [g[0]])
def _compress(ctx, node, x):
    from . import compression

    return (compression.compress_f32_to_16(x),)


@register("DecompressB16ToF32", grad=lambda n, i, o, g: [g[0]])
def _decompress(ctx, node, x):
    from . import compression

    return (compression.decompress_16_to_f32(x),)


# --- region fusion (§10) — a compiled pure subregion as one super-node ------


def _fused_region_num_outputs(node: Node) -> int:
    return len(node.attrs["spec"].output_refs)


@register("FusedRegion", num_outputs=_fused_region_num_outputs, stateful=True)
def _fused_region(ctx, node, *inputs):
    """Dispatch one fused region: the RegionSpec reads its variables from
    ``ctx``, calls the jitted region kernel, and commits variable writes
    (repro.core.fusion; DESIGN.md §7)."""
    return node.attrs["spec"].dispatch(ctx, inputs)


# --- control flow primitives (§4.4) — executor gives these special handling --

@register("Switch", num_outputs=2)
def _switch(ctx, node, data, pred):
    raise RuntimeError("Switch must be interpreted by the executor")


@register("Merge", num_outputs=2)
def _merge(ctx, node, *ins):
    raise RuntimeError("Merge must be interpreted by the executor")


@register("Enter")
def _enter(ctx, node, x):
    raise RuntimeError("Enter must be interpreted by the executor")


@register("Exit")
def _exit(ctx, node, x):
    raise RuntimeError("Exit must be interpreted by the executor")


@register("NextIteration")
def _next_iteration(ctx, node, x):
    raise RuntimeError("NextIteration must be interpreted by the executor")


@register("LoopCond")
def _loop_cond(ctx, node, x):
    raise RuntimeError("LoopCond must be interpreted by the executor")


# --- Send/Recv (§3.2.2) — inserted by partitioning, executed via rendezvous --
# NOTE: the executor interprets Send/Recv itself (frame-tagged rendezvous
# keys + wire deadness, executor.py §4.4) and never dispatches them through
# run_kernel; the kernels below exist as the non-executed reference
# semantics (and so the ops are registered/placeable like any other).

@register("Send", num_outputs=0, stateful=True)
def _send(ctx, node, x):
    key = node.attrs["rendezvous_key"]
    if node.attrs.get("compress", False):
        from . import compression

        x = compression.compress_f32_to_16(x)
    ctx.rendezvous.send(key, x)
    return ()


@register("Recv", stateful=True)
def _recv(ctx, node, *_token):
    # ``_token``: the optional per-iteration frame token attached by the
    # §4.4 frame-aware partitioner (drives re-execution; value unused)
    key = node.attrs["rendezvous_key"]
    x = ctx.rendezvous.recv(key)
    if node.attrs.get("compress", False):
        from . import compression

        x = compression.decompress_16_to_f32(x)
    return (x,)
