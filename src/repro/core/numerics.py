"""Tolerance-gated numerics parity (DESIGN.md §9).

PR 2's region fusion shipped with a bit-parity contract: fused regions
compile at XLA backend-opt-level 0 and accumulation-order-sensitive ops
(MatMul, reductions, ``Call``) stay eagerly dispatched, so fused ==
unfused bit-for-bit.  That leaves most of the paper's "compile subgraphs
into efficient kernels" win (§3.3/§4; TF-OSDI'16 accepts reassociation
drift for fused kernels) on the table.  ``numerics="fast"`` fuses
everything at full XLA optimization — and *this module is the contract
that makes fast mode safe*:

* a per-op-class tolerance table (ULP + relative, either satisfies);
* a suite of representative parity cases — matmul chains, residual
  towers, softmax/layernorm reductions, a multi-device partitioned
  step, a while-loop body, a ``Call`` train step — each executed
  fused-fast and unfused-strict on identical feeds/state;
* a structured :class:`ParityReport` of the max observed drift per op
  class, breaching if any element of any fetch/variable exceeds *both*
  bounds of its class tolerance;
* a CLI gate (``python -m repro.core.numerics --gate``) that CI runs on
  every PR, so the tolerance table is re-proven continuously (the
  pytest marker ``paritygate`` wraps the same suite).

The Session-level counterpart lives in ``executable.Executable``: a
fast-mode Executable verifies its first run against the unfused-strict
reference with :func:`compare` and falls back to strict execution (with
a warning) on a breach.

Comparison semantics: an element passes if its ULP distance is within
``Tolerance.ulp`` **or** its *scale-relative* error — ``|a-b|`` divided
by the larger array's max magnitude, the ``np.allclose`` convention with
``atol = rtol * amax`` — is within ``Tolerance.rel``.  ULP is the
natural unit for well-scaled floats; the scale-relative bound absorbs
near-zero elements (tiny gradients, optimizer second moments) where one
reassociated rounding step is enormous relative to *that element* but
meaningless relative to the tensor.  Non-float values (ints, bools,
shapes) must match exactly.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# op classes and the tolerance table


#: op -> op class; anything unlisted is "elementwise" (order-insensitive
#: elementwise / data-movement ops, whose only fast-mode drift source is
#: cross-op FMA contraction).
OP_CLASSES: Dict[str, str] = {
    "MatMul": "matmul",
    "ReduceSum": "reduction",
    "ReduceMean": "reduction",
    "SoftMax": "softmax",
    "SoftmaxXent": "softmax",
    "SSDScan": "scan",
    "Call": "call",
}

#: op classes with no float output to drift: compared exactly, and they
#: contribute no tolerance of their own.
_EXACT_OPS = {
    "Const", "Placeholder", "Variable", "Shape", "Rank", "NoOp",
    "Identity", "Switch", "Merge", "Enter", "Exit", "NextIteration",
    "LoopCond", "Send", "Recv", "FusedRegion",
}


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Max allowed drift for one op class: ULP distance OR relative error
    (an element within either bound passes)."""

    ulp: float
    rel: float

    def __or__(self, other: "Tolerance") -> "Tolerance":
        return Tolerance(ulp=max(self.ulp, other.ulp),
                         rel=max(self.rel, other.rel))

    def __str__(self) -> str:  # for warnings/reports
        return f"(ulp<={self.ulp:g} | rel<={self.rel:g})"


#: The §9 base tolerance table (fp32-calibrated; see DESIGN.md §9 for the
#: derivation).  Bounds are the observed fast-vs-strict drift of the
#: parity suite with ~8-32x headroom, not theoretical worst cases — the
#: CI gate exists precisely to catch the day an XLA upgrade blows past
#: them, at which point the table is re-negotiated consciously.
_BASE: Dict[str, Tolerance] = {
    # FMA contraction on mul->add chains: each fused pair is <= 1 ulp off,
    # chains compound a handful of ulps
    "elementwise": Tolerance(ulp=32, rel=1e-6),
    # vectorized partial sums vs linear accumulation: O(log n) reassociation
    "reduction": Tolerance(ulp=256, rel=1e-5),
    # dot reassociation + FMA over the contraction dim, compounding
    # through chained layers
    "matmul": Tolerance(ulp=512, rel=1e-5),
    # exp/log rewrites + a reduction in the denominator; xent adds a log
    "softmax": Tolerance(ulp=1024, rel=1e-4),
    # order-sensitive recurrent scans (SSDScan): sequential f32 reference
    # vs XLA's fused scan body
    "scan": Tolerance(ulp=1024, rel=1e-4),
    # user closures: arbitrary compositions of the above
    "call": Tolerance(ulp=2048, rel=1e-4),
}

#: Per-device-kind tolerance tables (DESIGN.md §12).  CPU/GPU XLA share
#: the fp32 calibration; TPU loosens the accumulation-sensitive classes
#: (MXU partial-sum shapes and bf16-internal rewrites differ from the
#: host backends — provisional until calibrated on real hardware).
TOLERANCES: Dict[str, Dict[str, Tolerance]] = {
    "cpu": dict(_BASE),
    "gpu": dict(_BASE),
    "tpu": {**_BASE,
            "reduction": Tolerance(ulp=512, rel=2e-5),
            "matmul": Tolerance(ulp=1024, rel=2e-5),
            "softmax": Tolerance(ulp=2048, rel=2e-4),
            "scan": Tolerance(ulp=2048, rel=2e-4)},
}

#: Per-backend calibration overlays, merged (loosest-wins) onto the
#: device-kind table.  The Pallas kernels legitimately reassociate more
#: than generic XLA: the matmul K-loop accumulates in f32 VMEM scratch
#: blockwise, flash attention's online softmax rescales the accumulator
#: once per KV block, and the SSD scan replaces the sequential recurrence
#: with a chunked cumsum/segment-matmul algorithm.  Bounds are observed
#: pallas-vs-strict drift of the parity suite with the same ~8-32x
#: headroom policy as the base table (calibration procedure: DESIGN.md
#: §12).
BACKEND_CALIBRATION: Dict[str, Dict[str, Tolerance]] = {
    "generic": {},
    "pallas": {
        "reduction": Tolerance(ulp=1024, rel=1e-4),
        "matmul": Tolerance(ulp=1024, rel=2e-5),
        "softmax": Tolerance(ulp=4096, rel=5e-4),
        "scan": Tolerance(ulp=4096, rel=5e-4),
        "call": Tolerance(ulp=4096, rel=5e-4),
    },
}


def tolerance_table(device_kind: str = "cpu",
                    backend: str = "generic") -> Dict[str, Tolerance]:
    """The effective per-class table for one (device kind, backend)."""
    table = dict(TOLERANCES.get(device_kind, TOLERANCES["cpu"]))
    for cls, tol in BACKEND_CALIBRATION.get(backend, {}).items():
        table[cls] = table.get(cls, tol) | tol
    return table


def op_class(op: str) -> Optional[str]:
    """The tolerance class of ``op`` (None for exact/structural ops)."""
    if op in OP_CLASSES:
        return OP_CLASSES[op]
    if op in _EXACT_OPS:
        return None
    return "elementwise"


def tolerance_for_classes(classes: Iterable[str], device_kind: str = "cpu",
                          backend: str = "generic") -> Tolerance:
    table = tolerance_table(device_kind, backend)
    tol = table["elementwise"]
    for c in classes:
        tol = tol | table[c]
    return tol


def tolerance_for_ops(ops: Iterable[str],
                      device_kinds: Iterable[str] = ("cpu",),
                      backend: str = "generic") -> Tolerance:
    """The merged tolerance for a graph containing ``ops`` — the loosest
    bound among the op classes present, across every device kind the
    graph runs on (used by the Session-level guard, which sees whole
    executables, not per-class fetches)."""
    classes = [c for c in (op_class(op) for op in set(ops)) if c is not None]
    tol: Optional[Tolerance] = None
    for kind in device_kinds:
        t = tolerance_for_classes(classes, kind, backend)
        tol = t if tol is None else (tol | t)
    return tol if tol is not None else tolerance_for_classes(classes)


# ---------------------------------------------------------------------------
# drift measurement


@dataclasses.dataclass(frozen=True)
class Drift:
    """Max observed divergence: ULP distance and relative error (each the
    max over all compared elements — possibly different elements)."""

    ulp: float = 0.0
    rel: float = 0.0

    def __or__(self, other: "Drift") -> "Drift":
        return Drift(ulp=max(self.ulp, other.ulp), rel=max(self.rel, other.rel))

    def __str__(self) -> str:
        return f"(ulp={self.ulp:g}, rel={self.rel:g})"


_EXACT_MISMATCH = Drift(ulp=float("inf"), rel=float("inf"))


def _is_float_dtype(dt: np.dtype) -> bool:
    """True for numpy floats AND the ml_dtypes extended floats (bfloat16,
    fp8) jax uses — ``np.issubdtype`` alone misclassifies those as
    non-float, which would exact-compare them (1 ULP => infinite drift)."""
    if np.issubdtype(dt, np.floating):
        return True
    try:
        import jax.numpy as jnp

        return bool(jnp.issubdtype(dt, jnp.floating))
    except Exception:  # noqa: BLE001 — unknown custom dtype: exact-compare
        return False


def _effective_ulp(ulp: float, dt: np.dtype) -> float:
    """Scale an fp32-calibrated ULP bound to ``dt``'s resolution.

    The TOLERANCES table is calibrated in fp32 ULPs (23-bit mantissa).
    In a narrower format the same *value* drift spans proportionally
    fewer ULPs — carrying 2048 fp32-ULPs over to bfloat16 (7-bit
    mantissa) would span ~16 binades and make the bound vacuous.  Floor
    of 8: reassociation legitimately moves a few ULPs in any format.
    """
    try:
        nmant = int(np.finfo(dt).nmant)
    except ValueError:
        try:  # ml_dtypes extended floats need their own finfo
            import ml_dtypes

            nmant = int(ml_dtypes.finfo(dt).nmant)
        except (ImportError, ValueError):
            return ulp
    if nmant >= 23:
        return ulp  # f32/f64: the calibrated unit
    return max(8.0, ulp / float(2 ** (23 - nmant)))


def _canonical_bits(a: np.ndarray) -> np.ndarray:
    """Map float bit patterns to a monotone integer line: adjacent floats
    differ by exactly 1, ``-0.0`` and ``+0.0`` coincide."""
    int_t = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}[a.dtype.itemsize]
    i = a.view(int_t).astype(np.int64)
    min_i = np.int64(-(2 ** (8 * a.dtype.itemsize - 1)))
    return np.where(i >= 0, i, min_i - i)


def ulp_distance(a: Any, b: Any) -> np.ndarray:
    """Elementwise ULP distance between two same-dtype float arrays
    (float64-valued: distances beyond 2**53 saturate approximately, which
    is far past any tolerance anyway)."""
    a = np.asarray(a)
    b = np.asarray(b)
    d = np.abs(_canonical_bits(a).astype(np.float64)
               - _canonical_bits(b).astype(np.float64))
    both_nan = np.isnan(a) & np.isnan(b)
    either_nan = np.isnan(a) | np.isnan(b)
    d = np.where(both_nan, 0.0, np.where(either_nan, np.inf, d))
    return d


def _leaves(x: Any) -> List[Any]:
    import jax

    return jax.tree.leaves(x)


def leaf_drift(ref: Any, got: Any) -> Tuple[Drift, np.ndarray]:
    """Drift of one array-ish leaf pair; returns (max drift, elementwise
    pass-relevant ulp array) — non-float or mismatched leaves are
    exact-compared and report infinite drift on mismatch."""
    if ref is None or got is None:
        ok = ref is None and got is None
        return (Drift() if ok else _EXACT_MISMATCH), np.zeros(())
    r = np.asarray(ref)
    g = np.asarray(got)
    if r.shape != g.shape or r.dtype != g.dtype:
        return _EXACT_MISMATCH, np.full((), np.inf)
    if not _is_float_dtype(r.dtype):
        ok = bool(np.array_equal(r, g))
        return (Drift() if ok else _EXACT_MISMATCH), np.zeros(())
    ulp = ulp_distance(r, g)
    rel = _scaled_rel(r, g)
    return Drift(ulp=float(np.max(ulp, initial=0.0)),
                 rel=float(np.max(rel, initial=0.0))), ulp


def _scaled_rel(r: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Elementwise scale-relative error: |r-g| over the pair's max
    magnitude (the allclose atol=rtol*amax convention — near-zero
    elements are judged against the tensor's scale, not their own)."""
    rf = r.astype(np.float64)
    gf = g.astype(np.float64)
    finite_max = 0.0
    for a in (rf, gf):
        fin = a[np.isfinite(a)]
        if fin.size:
            finite_max = max(finite_max, float(np.max(np.abs(fin))))
    denom = max(finite_max, float(np.finfo(np.float64).tiny))
    with np.errstate(invalid="ignore"):
        rel = np.abs(rf - gf) / denom
    both_nan = np.isnan(rf) & np.isnan(gf)
    either_nan = np.isnan(rf) | np.isnan(gf)
    return np.where(both_nan, 0.0, np.where(either_nan, np.inf, rel))


def compare(ref: Any, got: Any, tol: Tolerance) -> Tuple[bool, Drift]:
    """Pytree-aware comparison.  Returns (ok, max drift); ``ok`` is the
    *elementwise* either-criterion — every element must be within
    ``tol.ulp`` ULPs or within ``tol.rel`` scale-relative error."""
    import jax

    # structure check (not just leaf count): jax drops None subtrees from
    # the leaf list, so [None, x] vs [x] would otherwise look identical
    if jax.tree.structure(ref) != jax.tree.structure(got):
        return False, _EXACT_MISMATCH
    ref_leaves = _leaves(ref)
    got_leaves = _leaves(got)
    if len(ref_leaves) != len(got_leaves):
        return False, _EXACT_MISMATCH
    ok = True
    drift = Drift()
    for r, g in zip(ref_leaves, got_leaves):
        d, ulp = leaf_drift(r, g)
        drift = drift | d
        ra = np.asarray(r) if r is not None else None
        is_float = (ra is not None and g is not None
                    and np.asarray(g).shape == ra.shape
                    and np.asarray(g).dtype == ra.dtype
                    and _is_float_dtype(ra.dtype))
        # the ULP bound is fp32-calibrated; judge each leaf in its own
        # dtype's resolution (bf16 ULPs are ~65536x coarser)
        eff_ulp = _effective_ulp(tol.ulp, ra.dtype) if is_float else tol.ulp
        if d.ulp <= eff_ulp or d.rel <= tol.rel:
            continue  # whole leaf within one of the bounds
        if not is_float:
            ok = False  # exact-compare leaf mismatched: no elementwise rescue
            continue
        # mixed leaf: some elements ulp-close, the rest scale-close —
        # re-check the either-criterion per element
        rel = _scaled_rel(ra, np.asarray(g))
        if not bool(np.all((ulp <= eff_ulp) | (rel <= tol.rel))):
            ok = False
    return ok, drift


# NOTE: there is deliberately no aggregate `within(drift, tol)` helper —
# a pytree's max ULP and max rel can come from different tensors that
# each pass on their own bound, so any comparator must go through
# :func:`compare`'s elementwise either-criterion.


# ---------------------------------------------------------------------------
# the parity-case suite


@dataclasses.dataclass
class ParityCase:
    """One representative graph executed fused-fast vs unfused-strict.

    ``build(b)`` constructs the graph on a fresh ``GraphBuilder`` and
    returns a dict of named handles; ``fetches(extras)`` the fetch list;
    ``feeds(extras, step)`` per-run feed dict (or None); ``fetch_classes``
    the op class gating each fetch positionally; ``must_fuse_ops`` ops
    that MUST end up inside a fused region in fast mode — the gate fails
    if they stay eager, so it can never pass vacuously.
    """

    name: str
    build: Callable[[Any], Dict[str, Any]]
    fetches: Callable[[Dict[str, Any]], List[Any]]
    fetch_classes: Tuple[str, ...]
    feeds: Optional[Callable[[Dict[str, Any], int], Dict[Any, Any]]] = None
    devices: Optional[Callable[[], Any]] = None
    var_class: str = "elementwise"
    n_runs: int = 3
    must_fuse_ops: Tuple[str, ...] = ()


def _rng(case_seed: int, step: int) -> np.random.RandomState:
    return np.random.RandomState(1_000_003 * case_seed + step)


def _case_matmul_chain() -> ParityCase:
    """Deep residual matmul chain — dot reassociation + FMA compounding
    through layers (the §3.3 'compile subgraphs' headline shape)."""
    import jax.numpy as jnp

    n_layers = 8

    def build(b):
        rs = _rng(1, 0)
        W = b.constant(jnp.asarray(rs.randn(96, 96).astype("f") * 0.1),
                       name="W")
        x = b.placeholder("x")
        cur = x
        for i in range(n_layers):
            h = b.matmul(cur, W, name=f"mm{i}")
            cur = b.relu(b.add(h, cur, name=f"res{i}"), name=f"r{i}")
        total = b.reduce_sum(cur, name="total")
        return {"x": x, "out": cur, "total": total}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(1, step + 1)
        return {ex["x"].ref: jnp.asarray(rs.randn(32, 96).astype("f"))}

    return ParityCase(
        name="matmul_chain", build=build,
        fetches=lambda ex: [ex["out"].ref, ex["total"].ref],
        fetch_classes=("matmul", "reduction"),
        feeds=feeds, must_fuse_ops=("MatMul", "ReduceSum"))


def _case_residual_tower() -> ParityCase:
    """Elementwise mul->add tower: pure FMA-contraction bait."""

    def build(b):
        x = b.placeholder("x")
        w = b.placeholder("w")
        cur = x
        for i in range(24):
            cur = b.add(b.mul(cur, w, name=f"fm{i}"), x, name=f"fa{i}")
        return {"x": x, "w": w, "out": cur}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(2, step)
        return {ex["x"].ref: jnp.asarray(rs.randn(257).astype("f")),
                ex["w"].ref: jnp.asarray(rs.randn(257).astype("f") * 0.5)}

    return ParityCase(
        name="residual_tower", build=build,
        fetches=lambda ex: [ex["out"].ref],
        fetch_classes=("elementwise",), feeds=feeds,
        must_fuse_ops=("Mul", "Add"))


def _case_softmax_layernorm() -> ParityCase:
    """Softmax + a hand-built layernorm: reductions in denominators,
    exp/log rewrites, rsqrt — the transformer-block numerics."""
    import jax.numpy as jnp

    def build(b):
        x = b.placeholder("x")
        labels = b.placeholder("labels")
        # layernorm(x) = (x - mean) / sqrt(var + eps)
        mu = b.reduce_mean(x, axis=-1, name="mu")
        cen = b.sub(x, b.reshape(mu, (16, 1), name="mu_col"), name="cen")
        var = b.reduce_mean(b.square(cen, name="cen2"), axis=-1, name="var")
        eps = b.constant(jnp.float32(1e-5), name="eps")
        denom = b.reshape(
            b.exp(b.mul(b.log(b.add(var, eps, name="veps"), name="lv"),
                        b.constant(jnp.float32(0.5), name="half"),
                        name="hl"), name="rootv"),
            (16, 1), name="denom")
        ln = b.div(cen, denom, name="ln")
        sm = b.softmax(ln, name="sm")
        xent = b.softmax_xent(ln, labels, name="xent")
        return {"x": x, "labels": labels, "ln": ln, "sm": sm, "xent": xent}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(3, step)
        return {ex["x"].ref: jnp.asarray(rs.randn(16, 64).astype("f") * 3.0),
                ex["labels"].ref: jnp.asarray(
                    rs.randint(0, 64, 16).astype(np.int32))}

    return ParityCase(
        name="softmax_layernorm", build=build,
        fetches=lambda ex: [ex["ln"].ref, ex["sm"].ref, ex["xent"].ref],
        fetch_classes=("reduction", "softmax", "softmax"),
        feeds=feeds, must_fuse_ops=("SoftMax", "SoftmaxXent", "ReduceMean"))


def _case_multi_device_step() -> ParityCase:
    """2-worker partitioned step: matmuls/reductions fusing on each side
    of Send/Recv cut edges (the b13 shape, with real contraction ops)."""
    import jax.numpy as jnp

    def build(b):
        rs = _rng(4, 0)
        remotes = [
            b.constant(jnp.asarray(rs.randn(24, 24).astype("f") * 0.2),
                       name=f"r{i}", device="/job:worker/task:0")
            for i in range(4)]
        seed = b.placeholder("seed")
        cur = seed
        for i, r in enumerate(remotes):
            mm = b.matmul(cur, r, name=f"mm{i}", device="/job:worker/task:1")
            cur = b.add(mm, cur, name=f"acc{i}", device="/job:worker/task:1")
        out = b.reduce_sum(cur, name="out", device="/job:worker/task:1")
        back = b.reduce_mean(b.square(cur, name="sq",
                                      device="/job:worker/task:0"),
                             name="back", device="/job:worker/task:0")
        return {"seed": seed, "out": out, "back": back}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(4, step + 1)
        return {ex["seed"].ref: jnp.asarray(rs.randn(24, 24).astype("f"))}

    def devices():
        from ..runtime.devices import DeviceSet

        return DeviceSet.make_cluster(2, 1, kind="cpu")

    return ParityCase(
        name="multi_device_step", build=build,
        fetches=lambda ex: [ex["out"].ref, ex["back"].ref],
        fetch_classes=("reduction", "reduction"),
        feeds=feeds, devices=devices, must_fuse_ops=("MatMul",))


def _case_while_loop_body() -> ParityCase:
    """A while loop whose surrounding pre/post-compute fuses while the
    frame stays interpreted; the loop body itself does matmul work."""
    import jax.numpy as jnp

    def build(b):
        from .control_flow import while_loop

        rs = _rng(5, 0)
        W = b.constant(jnp.asarray(rs.randn(16, 16).astype("f") * 0.2),
                       name="W")
        x = b.placeholder("x")
        pre = b.relu(b.matmul(x, W, name="premm"), name="pre")
        lim = b.constant(jnp.asarray(4), name="lim")
        one = b.constant(jnp.asarray(1), name="one")
        i0 = b.constant(jnp.asarray(0), name="i0")
        outs = while_loop(
            b, lambda i, a: b.less(i, lim),
            lambda i, a: [b.add(i, one, name="inc"),
                          b.add(b.matmul(a, W, name="bodymm"), a,
                                name="bodyacc")],
            [i0, pre])
        post = b.reduce_sum(b.mul(outs[1], outs[1], name="postsq"),
                            name="post")
        return {"x": x, "loop_out": outs[1], "post": post}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(5, step + 1)
        return {ex["x"].ref: jnp.asarray(rs.randn(8, 16).astype("f"))}

    return ParityCase(
        name="while_loop_body", build=build,
        fetches=lambda ex: [ex["loop_out"], ex["post"].ref],
        fetch_classes=("matmul", "reduction"),
        feeds=feeds, must_fuse_ops=("MatMul",))


def _case_call_train_step() -> ParityCase:
    """A ``Call`` closure (the eager train/serve step shape) plus a
    variable read-modify-write — Call closures join regions in fast mode
    and variable commits must still match the reference."""
    import jax.numpy as jnp

    def loss_fn(W, x, y):
        import jax.numpy as jnp

        p = x @ W
        d = p - y
        return (jnp.mean(d * d),)

    def build(b):
        v = b.variable("v", init_value=lambda: jnp.full((4, 1), 0.25,
                                                        jnp.float32))
        x = b.placeholder("x")
        y = b.placeholder("y")
        loss = b.call(loss_fn, [v, x, y], name="loss", n_out=1)
        upd = b.assign_add(v, b.constant(jnp.full((4, 1), 0.01, jnp.float32),
                                         name="delta"))
        return {"x": x, "y": y, "loss": loss, "upd": upd}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(6, step)
        return {ex["x"].ref: jnp.asarray(rs.randn(8, 4).astype("f")),
                ex["y"].ref: jnp.asarray(rs.randn(8, 1).astype("f"))}

    return ParityCase(
        name="call_train_step", build=build,
        fetches=lambda ex: [ex["loss"].output(0), ex["upd"].ref],
        fetch_classes=("call", "elementwise"),
        feeds=feeds, var_class="call", n_runs=4, must_fuse_ops=("Call",))


def _case_lm_kernels() -> ParityCase:
    """The registry-matchable LM idioms (rmsnorm, scaled attention, SSD
    scan) built from primitive ops — under ``--backend pallas`` the fused
    candidate dispatches the hand-written kernels for all of them, under
    ``generic`` they lower through plain XLA (DESIGN.md §12)."""
    import jax.numpy as jnp

    def build(b):
        rs = _rng(7, 0)
        x = b.placeholder("x")        # (64, 32)
        kT = b.placeholder("kT")      # (32, 64)
        v = b.placeholder("v")        # (64, 32)
        w = b.constant(jnp.asarray(np.abs(rs.randn(32)).astype("f") + 0.5),
                       name="w")
        Wq = b.constant(jnp.asarray(rs.randn(32, 32).astype("f") * 0.2),
                        name="Wq")
        xn = b.rmsnorm(x, w, name="xn")
        q = b.matmul(xn, Wq, name="q")
        att = b.attention(q, kT, v, scale=0.125, name="att")
        y = b.add(att, x, name="y")
        sx = b.placeholder("sx")      # (1, 64, 2, 16)
        sdt = b.placeholder("sdt")    # (1, 64, 2)
        A_log = b.constant(jnp.asarray(rs.randn(2).astype("f") * 0.1),
                           name="A_log")
        sB = b.placeholder("sB")      # (1, 64, 1, 8)
        sC = b.placeholder("sC")
        D_skip = b.constant(jnp.asarray(rs.randn(2).astype("f") * 0.1),
                            name="D_skip")
        sy = b.ssd_scan(sx, sdt, A_log, sB, sC, D_skip, name="ssd")
        tot = b.reduce_sum(sy, name="tot")
        return {"x": x, "kT": kT, "v": v, "sx": sx, "sdt": sdt,
                "sB": sB, "sC": sC, "y": y, "sy": sy, "tot": tot}

    def feeds(ex, step):
        import jax.numpy as jnp

        rs = _rng(7, step + 1)
        return {
            ex["x"].ref: jnp.asarray(rs.randn(64, 32).astype("f")),
            ex["kT"].ref: jnp.asarray(rs.randn(32, 64).astype("f")),
            ex["v"].ref: jnp.asarray(rs.randn(64, 32).astype("f")),
            ex["sx"].ref: jnp.asarray(rs.randn(1, 64, 2, 16).astype("f")),
            ex["sdt"].ref: jnp.asarray(
                np.abs(rs.randn(1, 64, 2)).astype("f") * 0.1),
            ex["sB"].ref: jnp.asarray(rs.randn(1, 64, 1, 8).astype("f")),
            ex["sC"].ref: jnp.asarray(rs.randn(1, 64, 1, 8).astype("f")),
        }

    return ParityCase(
        name="lm_kernels", build=build,
        fetches=lambda ex: [ex["y"].ref, ex["sy"].ref, ex["tot"].ref],
        fetch_classes=("softmax", "scan", "scan"),
        feeds=feeds,
        must_fuse_ops=("MatMul", "SoftMax", "SSDScan", "Rsqrt"))


def default_cases() -> List[ParityCase]:
    return [
        _case_matmul_chain(),
        _case_residual_tower(),
        _case_softmax_layernorm(),
        _case_multi_device_step(),
        _case_while_loop_body(),
        _case_call_train_step(),
        _case_lm_kernels(),
    ]


# ---------------------------------------------------------------------------
# gate runner + report


@dataclasses.dataclass
class CaseResult:
    name: str
    drift_per_class: Dict[str, Drift]
    breaches: List[str]
    regions: int
    ops_fused: int


@dataclasses.dataclass
class ParityReport:
    """Structured outcome of one gate run (max observed drift per op
    class across all cases, plus per-case detail)."""

    cases: List[CaseResult]
    breaches: List[str]
    backend: str = "generic"

    @property
    def passed(self) -> bool:
        return not self.breaches

    @property
    def per_class(self) -> Dict[str, Drift]:
        agg: Dict[str, Drift] = {}
        for c in self.cases:
            for cls, d in c.drift_per_class.items():
                agg[cls] = agg.get(cls, Drift()) | d
        return agg

    def to_json(self) -> Dict[str, Any]:
        return {
            "passed": self.passed,
            "backend": self.backend,
            "breaches": list(self.breaches),
            "tolerances": {
                c: {"ulp": t.ulp, "rel": t.rel}
                for c, t in sorted(
                    tolerance_table("cpu", self.backend).items())},
            "max_drift_per_class": {
                c: {"ulp": d.ulp, "rel": d.rel}
                for c, d in sorted(self.per_class.items())},
            "cases": [{
                "name": c.name,
                "breaches": c.breaches,
                "regions": c.regions,
                "ops_fused": c.ops_fused,
                "drift_per_class": {
                    cls: {"ulp": d.ulp, "rel": d.rel}
                    for cls, d in sorted(c.drift_per_class.items())},
            } for c in self.cases],
        }

    def to_markdown(self) -> str:
        lines = ["# Numerics parity gate (fused-fast vs unfused-strict)", "",
                 f"**Result: {'PASS' if self.passed else 'BREACH'}** "
                 f"(kernel backend: `{self.backend}`)", "",
                 "| op class | tolerance (ulp \\| rel) | max observed "
                 "(ulp \\| rel) |", "|---|---|---|"]
        per_class = self.per_class
        for cls, tol in sorted(tolerance_table("cpu", self.backend).items()):
            d = per_class.get(cls)
            obs = f"{d.ulp:g} \\| {d.rel:.2e}" if d else "—"
            lines.append(f"| {cls} | {tol.ulp:g} \\| {tol.rel:.0e} | {obs} |")
        lines += ["", "| case | fused regions | ops fused | status |",
                  "|---|---|---|---|"]
        for c in self.cases:
            status = "ok" if not c.breaches else "; ".join(c.breaches)
            lines.append(f"| {c.name} | {c.regions} | {c.ops_fused} |"
                         f" {status} |")
        if self.breaches:
            lines += ["", "## Breaches", ""]
            lines += [f"- {b}" for b in self.breaches]
        return "\n".join(lines)


def run_case(case: ParityCase, backend: str = "generic") -> CaseResult:
    """Execute one case fused-fast vs unfused-strict and collect drift.

    The *reference* session is always generic (unfused-strict is the
    oracle); ``backend`` selects the kernel backend of the fused-fast
    candidate, and the drift is gated against that backend's calibrated
    tolerance table (DESIGN.md §12)."""
    from .graph import as_ref
    from .options import SessionOptions
    from .ops import GraphBuilder
    from .session import Session

    built = []
    for fast in (False, True):
        b = GraphBuilder()
        extras = case.build(b)
        sess = Session(b.graph, options=SessionOptions(
            fuse_regions=fast,
            numerics="fast" if fast else "strict",
            parity_guard=False,  # the gate itself is the comparator
            backend=backend if fast else "generic",
            devices=case.devices() if case.devices else None))
        built.append((sess, extras))
    (ref_sess, ref_ex), (cand_sess, cand_ex) = built

    drifts: Dict[str, Drift] = {}
    breaches: List[str] = []

    def record(cls: str, ref_v: Any, got_v: Any, what: str) -> None:
        tol = tolerance_for_classes([cls], "cpu", backend)
        ok, d = compare(ref_v, got_v, tol)
        drifts[cls] = drifts.get(cls, Drift()) | d
        if not ok:
            breaches.append(
                f"{case.name}/{what}: drift {d} exceeds {tol} [{cls}]")

    for step in range(case.n_runs):
        ref_feeds = case.feeds(ref_ex, step) if case.feeds else None
        cand_feeds = case.feeds(cand_ex, step) if case.feeds else None
        rv = ref_sess.run(case.fetches(ref_ex), ref_feeds)
        cv = cand_sess.run(case.fetches(cand_ex), cand_feeds)
        for i, (r, g) in enumerate(zip(rv, cv)):
            record(case.fetch_classes[i], r, g, f"fetch{i}@run{step}")
        for vn in sorted(n for n in ref_sess.graph.nodes
                         if ref_sess.graph.nodes[n].op == "Variable"):
            if ref_sess.variables.has(vn):
                record(case.var_class, ref_sess.variable_value(vn),
                       cand_sess.variable_value(vn), f"var:{vn}@run{step}")

    # the gate must never pass vacuously: fast mode has to have actually
    # fused the contraction ops this case exists to exercise
    fetch_refs = [as_ref(f) for f in case.fetches(cand_ex)]
    feed_keys = frozenset(
        as_ref(k) for k in (case.feeds(cand_ex, 0) or {})) if case.feeds \
        else frozenset()
    exe = cand_sess.executable(fetch_refs, feed_keys)
    regions = exe.fusion.regions if exe.fusion is not None else []
    fused_ops = {spec.subgraph.nodes[m].op
                 for spec in regions for m in spec.members}
    for op in case.must_fuse_ops:
        if op not in fused_ops:
            breaches.append(
                f"{case.name}: op {op} did not join any fused region in "
                f"fast mode (gate would be vacuous)")
    return CaseResult(name=case.name, drift_per_class=drifts,
                      breaches=breaches, regions=len(regions),
                      ops_fused=sum(len(s.members) for s in regions))


def run_parity_gate(cases: Optional[Sequence[ParityCase]] = None, *,
                    backend: str = "generic") -> ParityReport:
    cases = list(cases) if cases is not None else default_cases()
    before = 0
    if backend != "generic":
        from . import kernel_registry

        before = kernel_registry.dispatch_total(backend)
    results = [run_case(c, backend=backend) for c in cases]
    breaches = [b for r in results for b in r.breaches]
    if backend != "generic":
        from . import kernel_registry

        if kernel_registry.dispatch_total(backend) == before:
            # same anti-vacuity contract as must_fuse_ops: a backend gate
            # that never dispatched a registered kernel proved nothing
            breaches.append(
                f"backend {backend!r}: no registered kernel dispatched "
                "across the suite (gate would be vacuous)")
    return ParityReport(cases=results, breaches=breaches, backend=backend)


# ---------------------------------------------------------------------------
# CLI: python -m repro.core.numerics --gate [--json PATH] [--cases SUBSTR]


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.numerics",
        description="Numerics parity gate: prove fused-fast execution "
                    "stays within the §9 tolerances of unfused-strict.")
    ap.add_argument("--gate", action="store_true",
                    help="run the parity suite; exit 1 on any breach")
    ap.add_argument("--cases", default=None,
                    help="substring filter on case names")
    ap.add_argument("--json", default=None,
                    help="also write the structured report to this path")
    ap.add_argument("--backend", default="generic",
                    help="kernel backend for the fused-fast candidate "
                         "(generic | pallas); the reference stays generic")
    args = ap.parse_args(argv)
    if not args.gate:
        ap.print_help()
        return 2
    from . import kernel_registry

    if args.backend not in kernel_registry.available_backends():
        print(f"unknown backend {args.backend!r}; available: "
              f"{kernel_registry.available_backends()}", file=sys.stderr)
        return 2
    cases = default_cases()
    if args.cases:
        cases = [c for c in cases if args.cases in c.name]
        if not cases:
            print(f"no parity case matches {args.cases!r}", file=sys.stderr)
            return 2
    report = run_parity_gate(cases, backend=args.backend)
    print(report.to_markdown())
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report.to_json(), fh, indent=2, sort_keys=True)
        print(f"\n# wrote {args.json}")
    return 0 if report.passed else 1


if __name__ == "__main__":
    raise SystemExit(main())
