"""Pluggable kernel-backend registry for fused-region lowering (§5.4).

The paper attributes much of the single-device performance story to
"optimized libraries for kernel implementations" selected per device.
This module is that mechanism for *fused regions*: a registry mapping
(subgraph pattern, device kind) -> backend kernel, consulted once per
region by :func:`repro.core.lowering.lower_region`.  Each registered
:class:`KernelRule` pattern-matches a recognized idiom inside the region
(a MatMul, the rmsnorm chain emitted by ``GraphBuilder.rmsnorm``, the
softmax-attention chain, the SSDScan op) and rewrites its anchor node
onto one of the hand-written Pallas entry points in
:mod:`repro.kernels.ops` — ``interpret=True`` on CPU pools, compiled on
TPU.  Anything that does not match, or whose shapes the kernel cannot
take (checked at trace time), falls back to the generic jnp path.

Backends are named ("generic", "pallas") and join the RunSignature via
``Session(backend=...)`` / ``REPRO_KERNEL_BACKEND`` so flipping backends
never reuses a stale Executable.  Dispatch/fallback counters are bumped
at trace time — once per compiled region signature — so benchmarks and
the parity gate can assert the Pallas path actually ran (DESIGN.md §12).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ops as ops_mod
from .graph import Graph, Node, TensorRef
from ..obs.metrics import StatsDict


class BackendError(ValueError):
    """Unknown backend name (subclasses ValueError for Session plumbing)."""


def _interpret() -> bool:
    # interpret=True emulates the Pallas kernels through XLA on CPU/GPU
    # pools; on a real TPU the same entry points compile to Mosaic.
    return jax.default_backend() != "tpu"


def _feasible(*dims: int, block: int = 128) -> bool:
    # Every Pallas kernel clamps its block to min(block, dim) and then
    # requires dim % block == 0 — so any dim <= block is automatically
    # fine and larger dims must tile evenly.
    return all(d > 0 and (d <= block or d % block == 0) for d in dims)


def _is_float(x: Any) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype if not hasattr(x, "dtype")
                          else x.dtype, jnp.floating)


# ---------------------------------------------------------------------------
# Registry types


@dataclasses.dataclass
class Match:
    """A recognized idiom: ``anchor`` is the member whose compute is
    replaced; ``leaves`` are the dataflow inputs the kernel consumes;
    ``interior`` is every member subsumed by the rewrite (claimed so it
    cannot anchor another match)."""

    rule: "KernelRule"
    anchor: str
    leaves: Dict[str, TensorRef]
    params: Dict[str, Any]
    interior: Set[str]


@dataclasses.dataclass(frozen=True)
class KernelRule:
    """One (pattern -> kernel) rewrite.

    ``matcher(g, anchor_name, members)`` inspects graph structure only
    (no shapes — those are unknown until trace time) and returns a Match
    or None.  ``emit(match, vals, device_kind)`` runs at trace time with
    the leaf values (tracers), re-checks shape/dtype feasibility, and
    returns the kernel output array — or None to fall back to the
    generic path for this anchor.
    """

    name: str
    anchor_op: str
    matcher: Callable[[Graph, str, Set[str]], Optional[Match]]
    emit: Callable[[Match, Dict[str, Any], str], Optional[Any]]


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    name: str
    rules: Tuple[KernelRule, ...]
    device_kinds: Tuple[str, ...] = ("cpu", "gpu", "tpu")


BACKENDS: Dict[str, KernelBackend] = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> KernelBackend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise BackendError(
            f"unknown kernel backend {name!r}; available: "
            f"{sorted(BACKENDS)}") from None


def available_backends() -> List[str]:
    return sorted(BACKENDS)


# ---------------------------------------------------------------------------
# Dispatch accounting (trace-time: once per compiled region signature)

_LOCK = threading.Lock()
DISPATCH: Dict[Tuple[str, str], int] = {}
# registry-backed (§16.4): same dict surface as before, but every count
# is also a ``kernel_registry.*`` counter in repro.obs.metrics.REGISTRY
STATS = StatsDict("kernel_registry",
                  keys=("planned", "matched", "dispatched", "fallbacks"))


def _bump_dispatch(backend: str, kernel: str) -> None:
    with _LOCK:
        DISPATCH[(backend, kernel)] = DISPATCH.get((backend, kernel), 0) + 1
        STATS["dispatched"] += 1


def _bump(key: str, n: int = 1) -> None:
    with _LOCK:
        STATS[key] += n


def dispatch_counts(backend: str) -> Dict[str, int]:
    with _LOCK:
        return {k: v for (b, k), v in DISPATCH.items() if b == backend}


def dispatch_total(backend: str) -> int:
    return sum(dispatch_counts(backend).values())


def reset_stats() -> None:
    with _LOCK:
        DISPATCH.clear()
        for k in STATS:
            STATS[k] = 0


# ---------------------------------------------------------------------------
# Pattern matchers.  All shape checks live in emit() — at match time we
# only see graph structure.


def _producer(g: Graph, members: Set[str], ref: TensorRef) -> Optional[Node]:
    """The in-region node producing ``ref``, or None (leaves stay refs)."""
    if ref.port != 0 or ref.node not in members:
        return None
    return g.nodes.get(ref.node)


def _const_scalar(node: Node) -> Optional[float]:
    val = np.asarray(node.attrs.get("value"))
    if val.ndim != 0:
        return None
    return float(val)


def _match_matmul(g: Graph, anchor: str, members: Set[str]) -> Optional[Match]:
    node = g.nodes[anchor]
    return Match(rule=MATMUL_RULE, anchor=anchor,
                 leaves={"a": node.inputs[0], "b": node.inputs[1]},
                 params={}, interior={anchor})


def _emit_matmul(match: Match, vals: Dict[str, Any],
                 device_kind: str) -> Optional[Any]:
    from .. import kernels
    a, b = vals["a"], vals["b"]
    if getattr(a, "ndim", None) != 2 or getattr(b, "ndim", None) != 2:
        return None
    if a.dtype != b.dtype or not _is_float(a):
        return None
    (M, K), (K2, N) = a.shape, b.shape
    if K != K2 or not _feasible(M, K, N):
        return None
    return kernels.ops.matmul(a, b, interpret=_interpret())


def _match_rmsnorm(g: Graph, anchor: str, members: Set[str]) -> Optional[Match]:
    # Mul(Mul(x, Rsqrt(Add(ReduceMean(Square(x), -1, keepdims), eps))), w)
    node = g.nodes[anchor]
    for norm_ref, w_ref in ((node.inputs[0], node.inputs[1]),
                            (node.inputs[1], node.inputs[0])):
        norm = _producer(g, members, norm_ref)
        if norm is None or norm.op != "Mul":
            continue
        for x_ref, rs_ref in ((norm.inputs[0], norm.inputs[1]),
                              (norm.inputs[1], norm.inputs[0])):
            rs = _producer(g, members, rs_ref)
            if rs is None or rs.op != "Rsqrt":
                continue
            veps = _producer(g, members, rs.inputs[0])
            if veps is None or veps.op != "Add":
                continue
            for ms_ref, eps_ref in ((veps.inputs[0], veps.inputs[1]),
                                    (veps.inputs[1], veps.inputs[0])):
                ms = _producer(g, members, ms_ref)
                epsn = _producer(g, members, eps_ref)
                if ms is None or ms.op != "ReduceMean":
                    continue
                if epsn is None or epsn.op != "Const":
                    continue
                if ms.attrs.get("axis") != -1 or not ms.attrs.get("keepdims"):
                    continue
                sq = _producer(g, members, ms.inputs[0])
                if sq is None or sq.op != "Square" or sq.inputs[0] != x_ref:
                    continue
                eps = _const_scalar(epsn)
                if eps is None:
                    continue
                return Match(
                    rule=RMSNORM_RULE, anchor=anchor,
                    leaves={"x": x_ref, "w": w_ref}, params={"eps": eps},
                    interior={anchor, norm.name, rs.name, veps.name,
                              ms.name, sq.name})
    return None


def _emit_rmsnorm(match: Match, vals: Dict[str, Any],
                  device_kind: str) -> Optional[Any]:
    from .. import kernels
    x, w = vals["x"], vals["w"]
    if getattr(w, "ndim", None) != 1 or getattr(x, "ndim", 0) < 2:
        return None
    if x.shape[-1] != w.shape[0] or not _is_float(x) or not _is_float(w):
        return None
    rows = int(np.prod(x.shape[:-1]))
    if not _feasible(rows, block=256):
        return None
    return kernels.ops.rmsnorm(x, w, eps=match.params["eps"],
                               interpret=_interpret())


def _match_attention(g: Graph, anchor: str,
                     members: Set[str]) -> Optional[Match]:
    # MatMul(SoftMax(opt-Mul(MatMul(q, kT), scale)), v)
    node = g.nodes[anchor]
    probs = _producer(g, members, node.inputs[0])
    if probs is None or probs.op != "SoftMax":
        return None
    s = _producer(g, members, probs.inputs[0])
    interior = {anchor, probs.name}
    scale = None
    if s is not None and s.op == "Mul":
        for mm_ref, sc_ref in ((s.inputs[0], s.inputs[1]),
                               (s.inputs[1], s.inputs[0])):
            mm = _producer(g, members, mm_ref)
            sc = _producer(g, members, sc_ref)
            if (mm is not None and mm.op == "MatMul"
                    and sc is not None and sc.op == "Const"):
                scale = _const_scalar(sc)
                if scale is None:
                    return None
                interior.add(s.name)
                s = mm
                break
        else:
            return None
    if s is None or s.op != "MatMul":
        return None
    interior.add(s.name)
    return Match(rule=ATTENTION_RULE, anchor=anchor,
                 leaves={"q": s.inputs[0], "kT": s.inputs[1],
                         "v": node.inputs[1]},
                 params={"scale": scale}, interior=interior)


def _emit_attention(match: Match, vals: Dict[str, Any],
                    device_kind: str) -> Optional[Any]:
    from .. import kernels
    q, kT, v = vals["q"], vals["kT"], vals["v"]
    if any(getattr(t, "ndim", None) != 2 for t in (q, kT, v)):
        return None
    if not all(_is_float(t) for t in (q, kT, v)):
        return None
    (S, D), (Dk, T), (Tv, Dv) = q.shape, kT.shape, v.shape
    if D != Dk or T != Tv or Dv != D:
        return None  # flash kernel needs v rows in the q/k feature dim
    if not _feasible(S, T):
        return None
    return kernels.ops.attention(q, kT, v, scale=match.params["scale"],
                                 interpret=_interpret())


def _match_ssd(g: Graph, anchor: str, members: Set[str]) -> Optional[Match]:
    node = g.nodes[anchor]
    names = ("x", "dt", "A_log", "Bc", "Cc", "D_skip")
    return Match(rule=SSD_RULE, anchor=anchor,
                 leaves=dict(zip(names, node.inputs)),
                 params={"chunk": int(node.attrs.get("chunk", 128))},
                 interior={anchor})


def _emit_ssd(match: Match, vals: Dict[str, Any],
              device_kind: str) -> Optional[Any]:
    from .. import kernels
    x, dt, A_log = vals["x"], vals["dt"], vals["A_log"]
    Bc, Cc, D_skip = vals["Bc"], vals["Cc"], vals["D_skip"]
    if getattr(x, "ndim", None) != 4 or getattr(Bc, "ndim", None) != 4:
        return None
    B, S, H, P = x.shape
    G = Bc.shape[2]
    if (dt.shape != (B, S, H) or A_log.shape != (H,)
            or Bc.shape[:2] != (B, S) or Cc.shape != Bc.shape
            or D_skip.shape != (H,) or G == 0 or H % G != 0):
        return None
    if not _is_float(x):
        return None
    chunk = match.params["chunk"]
    if not _feasible(S, block=min(chunk, S)):
        return None
    return kernels.ops.ssd_scan(x, dt, A_log, Bc, Cc, D_skip,
                                chunk=chunk, interpret=_interpret())


MATMUL_RULE = KernelRule("matmul", "MatMul", _match_matmul, _emit_matmul)
RMSNORM_RULE = KernelRule("rmsnorm", "Mul", _match_rmsnorm, _emit_rmsnorm)
ATTENTION_RULE = KernelRule("flash_attention", "MatMul", _match_attention,
                            _emit_attention)
SSD_RULE = KernelRule("ssd_scan", "SSDScan", _match_ssd, _emit_ssd)


# ---------------------------------------------------------------------------
# Region planning


def plan_region_overrides(
        g: Graph, members: Set[str], backend_name: str,
        device_kind: str) -> Dict[str, Callable]:
    """Match the backend's rules over a fused region's members.

    Returns {anchor_name: override(ev, node) -> outputs-tuple} for
    :class:`repro.core.lowering._Evaluator`.  Members are visited
    consumers-first (reverse insertion order ~ reverse topo within a
    region) so a composite idiom claims its interior before an interior
    node can anchor a smaller match; rules are tried in backend order
    (flash_attention before matmul — both anchor MatMul).
    """
    backend = get_backend(backend_name)
    if not backend.rules or device_kind not in backend.device_kinds:
        return {}
    _bump("planned")

    claimed: Set[str] = set()
    overrides: Dict[str, Callable] = {}
    for name in reversed(list(members)):
        if name in claimed or name in overrides:
            continue
        node = g.nodes.get(name)
        if node is None:
            continue
        for rule in backend.rules:
            if node.op != rule.anchor_op:
                continue
            match = rule.matcher(g, name, members)
            if match is None:
                continue
            _bump("matched")
            claimed |= match.interior - {name}
            overrides[name] = _make_override(backend.name, rule, match,
                                             device_kind)
            break
    return overrides


def _make_override(backend_name: str, rule: KernelRule, match: Match,
                   device_kind: str) -> Callable:
    def override(ev: Any, node: Node) -> Tuple[Any, ...]:
        vals = {k: ev.value(r) for k, r in match.leaves.items()}
        out = rule.emit(match, vals, device_kind)
        if out is None:
            # shapes/dtypes the kernel cannot take: generic fallback
            _bump("fallbacks")
            ins = [ev.value(r) for r in node.inputs]
            return ops_mod.opdef(node.op).compute(ev.state, node, *ins)
        _bump_dispatch(backend_name, rule.name)
        return (out,)

    return override


# ---------------------------------------------------------------------------
# Built-in backends.  "generic" is the identity backend (no rewrites);
# "pallas" dispatches onto the hand-written kernels.  Rule order matters:
# flash_attention must precede matmul (both anchor MatMul).

register_backend(KernelBackend("generic", rules=()))
register_backend(KernelBackend(
    "pallas",
    rules=(ATTENTION_RULE, MATMUL_RULE, RMSNORM_RULE, SSD_RULE)))
