"""§3.2.2 graph partitioning with Send/Recv insertion.

After placement, the graph is split into one subgraph per device.  Every
cross-device data edge x:p -> y is replaced by x -> Send (on x's device)
and Recv -> y (on y's device), where Send/Recv coordinate through the
rendezvous.  All users of a given (tensor, destination-device) pair are
canonicalised onto a *single* Recv node so each tensor crosses each
device pair at most once and is allocated once at the destination.
Cross-device *control* edges become a zero-byte token transfer.

Optionally (§5.5) Send/Recv pairs carry the lossy 32->16-bit compression.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .graph import Graph, Node, TensorRef
from ..runtime import rendezvous as rdv


# pass-invocation counter (see placement.STATS; DESIGN.md §5)
STATS = {"partition_calls": 0}


@dataclasses.dataclass
class Partitioned:
    graph: Graph                      # rewritten graph containing Send/Recv
    device_nodes: Dict[str, Set[str]]  # device -> node names
    placement: Dict[str, str]          # node -> device (incl. new nodes)
    n_transfers: int = 0


def partition(
    g: Graph,
    placement: Dict[str, str],
    node_names=None,
    compress: bool = False,
) -> Partitioned:
    STATS["partition_calls"] += 1
    names = set(node_names) if node_names is not None else set(placement)
    pg = g.subgraph(names)
    place = dict(placement)

    # one Recv per (src_node, port, dst_device); one Send per (src_node, port, src->dst)
    recv_cache: Dict[Tuple[str, int, str], str] = {}
    n_transfers = 0

    def get_recv(ref: TensorRef, dst_dev: str) -> str:
        nonlocal n_transfers
        key = (ref.node, ref.port, dst_dev)
        if key in recv_cache:
            return recv_cache[key]
        src_dev = place[ref.node]
        rkey = rdv.make_key(str(ref), src_dev, dst_dev)
        send = pg.add_node(
            "Send", [ref], name=f"send/{ref.node}_{ref.port}/to_{len(recv_cache)}",
            attrs={"rendezvous_key": rkey, "compress": compress}, device=src_dev)
        recv = pg.add_node(
            "Recv", [], name=f"recv/{ref.node}_{ref.port}/at_{len(recv_cache)}",
            attrs={"rendezvous_key": rkey, "compress": compress}, device=dst_dev)
        place[send.name] = src_dev
        place[recv.name] = dst_dev
        recv_cache[key] = recv.name
        n_transfers += 1
        return recv.name

    for name in list(names):
        node = pg.nodes[name]
        dst_dev = place[name]
        new_inputs: List[TensorRef] = []
        for ref in node.inputs:
            if ref.node in names and place[ref.node] != dst_dev:
                new_inputs.append(TensorRef(get_recv(ref, dst_dev), 0))
            else:
                new_inputs.append(ref)
        node.inputs = new_inputs
        new_ctrl: List[str] = []
        for c in node.control_inputs:
            if c in names and place[c] != dst_dev:
                # zero-byte control token across devices
                src_dev = place[c]
                tok = pg.add_node("Const", [], name=f"ctok/{c}/{name}",
                                  attrs={"value": 0}, control_inputs=[c], device=src_dev)
                place[tok.name] = src_dev
                recv_name = get_recv(tok.ref, dst_dev)
                new_ctrl.append(recv_name)
            else:
                new_ctrl.append(c)
        node.control_inputs = new_ctrl

    device_nodes: Dict[str, Set[str]] = {}
    for n in pg.nodes:
        device_nodes.setdefault(place[n], set()).add(n)
    return Partitioned(graph=pg, device_nodes=device_nodes, placement=place,
                       n_transfers=n_transfers)
