"""§3.2.2 graph partitioning with Send/Recv insertion.

After placement, the graph is split into one subgraph per device.  Every
cross-device data edge x:p -> y is replaced by x -> Send (on x's device)
and Recv -> y (on y's device), where Send/Recv coordinate through the
rendezvous.  All users of a given (tensor, destination-device) pair are
canonicalised onto a *single* Recv node so each tensor crosses each
device pair at most once and is allocated once at the destination.
Cross-device *control* edges become a zero-byte token transfer — frame
aware: a same-frame edge rides a per-iteration token, an edge leaving a
loop frame rides an Exit-gated token that fires once at termination.

§4.4 distributed control flow: when a while-loop's body straddles
devices, the loop's Enter/Merge/Switch/Exit control skeleton is
replicated on every participating device and the predicate is broadcast
from the frame's *home* device (where LoopCond lives) once per
iteration, so every device learns iteration-termination exactly as the
paper prescribes.  Recvs inside the frame carry the local skeleton's
``Switch:1`` output as an *iteration token* input — it is live once per
continuing iteration (driving the Recv's re-execution in the right
(frame, iteration) context) and dead on the terminating one (killing the
Recv via ordinary dead-tensor propagation).  The executor tags in-frame
rendezvous keys with the frame context so each iteration is a distinct
transfer (executor.wire_key).

Optionally (§5.5) Send/Recv pairs carry the lossy 32->16-bit compression.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from .graph import Graph, GraphError, TensorRef
from . import control_flow as cf_mod
from ..obs.metrics import StatsDict
from ..runtime import rendezvous as rdv


# pass-invocation counter (see placement.STATS; DESIGN.md §5),
# registry-backed since §16.4
STATS = StatsDict("partition", keys=("partition_calls", "frames_replicated"))


@dataclasses.dataclass
class Partitioned:
    graph: Graph                      # rewritten graph containing Send/Recv
    device_nodes: Dict[str, Set[str]]  # device -> node names
    placement: Dict[str, str]          # node -> device (incl. new nodes)
    n_transfers: int = 0


def _replicate_loop_frames(
    g: Graph,
    pg: Graph,
    names: Set[str],
    place: Dict[str, str],
) -> Tuple[Dict[Tuple[str, str], TensorRef], Dict[Tuple[str, int, str], str], int]:
    """Replicate loop control skeletons across participating devices (§4.4).

    For every while-frame in ``g.loop_specs`` whose executed members land
    on more than one device: the device holding ``LoopCond`` is the
    frame's *home*; every other participant gets a private
    Const -> Enter -> Merge -> Switch -> (NextIteration | Exit) skeleton
    whose predicate arrives from home via a per-iteration Send/Recv pair.

    Returns ``(tokens, recv_cache_seed, n_transfers)`` where ``tokens``
    maps (frame, device) to the Switch:1 ref that is live exactly once
    per continuing iteration on that device, and ``recv_cache_seed``
    pre-seeds the partitioner's Recv canonicalisation with the predicate
    Recvs (so a body node consuming ``LoopCond`` output cross-device
    reuses the broadcast instead of creating a colliding transfer).
    """
    tokens: Dict[Tuple[str, str], TensorRef] = {}
    recv_seed: Dict[Tuple[str, int, str], str] = {}
    n_transfers = 0
    for lname, spec in g.loop_specs.items():
        members = [m for m in cf_mod.loop_spec_members(lname, spec)
                   if m in names]
        if not members:
            continue
        cond_name = f"{lname}/cond"
        home = place.get(cond_name)
        if home is None:
            continue
        devs = sorted({place[m] for m in members if m in place})
        # home's own iteration token: any surviving loop variable's
        # Switch:1, live exactly while the loop continues (feed/fetch
        # pruning may have dropped unobserved variables' switches)
        home_switch = next((s for s in spec.switch_names if s in names), None)
        if home_switch is not None:
            tokens[(lname, home)] = TensorRef(home_switch, 1)
        if len(devs) < 2:
            continue
        STATS["frames_replicated"] += 1
        for i, dev in enumerate(d for d in devs if d != home):
            pfx = f"{lname}/ctl{i}"
            tok = pg.add_node("Const", [], name=f"{pfx}/token",
                              attrs={"value": 0}, device=dev)
            ent = pg.add_node("Enter", [tok], name=f"{pfx}/enter",
                              attrs={"frame": lname}, device=dev)
            mrg = pg.add_node("Merge", [ent], name=f"{pfx}/merge", device=dev)
            rkey = rdv.make_key(f"{cond_name}:0", home, dev)
            snd = pg.add_node(
                "Send", [TensorRef(cond_name, 0)], name=f"{pfx}/pred_send",
                attrs={"rendezvous_key": rkey, "compress": False}, device=home)
            rcv = pg.add_node(
                "Recv", [mrg.ref], name=f"{pfx}/pred_recv",
                attrs={"rendezvous_key": rkey, "compress": False}, device=dev)
            sw = pg.add_node("Switch", [mrg, rcv], name=f"{pfx}/switch",
                             device=dev)
            nxt = pg.add_node("NextIteration", [TensorRef(sw.name, 1)],
                              name=f"{pfx}/next", device=dev)
            mrg.inputs.append(nxt.ref)  # the replicated back edge
            ext = pg.add_node("Exit", [TensorRef(sw.name, 0)],
                              name=f"{pfx}/exit", device=dev)
            for n in (tok, ent, mrg, nxt, rcv, sw, ext):
                place[n.name] = dev
            place[snd.name] = home
            tokens[(lname, dev)] = TensorRef(sw.name, 1)
            recv_seed[(cond_name, 0, dev)] = rcv.name
            n_transfers += 1
    return tokens, recv_seed, n_transfers


def partition(
    g: Graph,
    placement: Dict[str, str],
    node_names=None,
    compress: bool = False,
) -> Partitioned:
    STATS["partition_calls"] += 1
    names = set(node_names) if node_names is not None else set(placement)
    pg = g.subgraph(names)
    place = dict(placement)

    # §4.4: static frame per node (from the Enter frame attrs) decides
    # which Recvs need an iteration token; replicate control skeletons
    # for loop frames that straddle devices before splitting edges.
    frames = cf_mod.static_frames(pg, names)
    frame_tokens, recv_cache, n_transfers = _replicate_loop_frames(
        g, pg, names, place)

    # one Recv per (src_node, port, dst_device); one Send per (src_node, port, src->dst)
    # (pre-seeded with the predicate-broadcast Recvs)

    def get_recv(ref: TensorRef, dst_dev: str) -> str:
        nonlocal n_transfers
        key = (ref.node, ref.port, dst_dev)
        if key in recv_cache:
            return recv_cache[key]
        src_dev = place[ref.node]
        rkey = rdv.make_key(str(ref), src_dev, dst_dev)
        send = pg.add_node(
            "Send", [ref], name=f"send/{ref.node}_{ref.port}/to_{len(recv_cache)}",
            attrs={"rendezvous_key": rkey, "compress": compress}, device=src_dev)
        # §4.4: a producer inside a loop frame fires once per iteration —
        # the Recv must too, so it takes that frame's iteration token on
        # the destination device as a data input (live per continuing
        # iteration, dead on the terminating one).
        recv_inputs: List[TensorRef] = []
        fpath = frames.get(ref.node, ())
        if fpath:
            if len(fpath) > 1:
                # §14: route through the Diagnostic formatter so the
                # error names nodes AND devices (satellite of ISSUE 8)
                from ..analysis.frames import describe_nested_straddle

                raise GraphError(
                    f"cross-device edge {ref} leaves a nested loop frame: "
                    + describe_nested_straddle(
                        fpath, [ref.node], [src_dev, dst_dev]))
            tok = frame_tokens.get((fpath[-1], dst_dev))
            if tok is None:
                raise GraphError(
                    f"no iteration token for frame {fpath[-1]!r} on "
                    f"{dst_dev!r} (consumer of {ref} is outside the loop?)")
            recv_inputs = [tok]
        recv = pg.add_node(
            "Recv", recv_inputs, name=f"recv/{ref.node}_{ref.port}/at_{len(recv_cache)}",
            attrs={"rendezvous_key": rkey, "compress": compress}, device=dst_dev)
        place[send.name] = src_dev
        place[recv.name] = dst_dev
        recv_cache[key] = recv.name
        n_transfers += 1
        return recv.name

    # §4.4 control edges out of a loop frame: an Exit-gated token.  A
    # root-depth ctok Const with a control dep on an in-frame producer
    # would never be satisfied (the producer fires at frame depth d+1,
    # the executor only delivers control to consumers at the same depth)
    # and the consumer's device would hang.  Instead: (a) the producer
    # becomes a control input of the frame's NextIteration on its device
    # — iteration k+1 cannot start before the producer's k-th firing, so
    # every iteration happens-before the terminating Switch — and (b) a
    # dedicated Exit on Switch:0 yields a token that is dead on every
    # continuing iteration and live exactly once, at termination, at root
    # depth, which is where the consumer waits.
    exit_tokens: Dict[Tuple[str, str], TensorRef] = {}

    def get_exit_token(lname: str, dev: str, ctrl_src: str) -> TensorRef:
        sw_ref = frame_tokens.get((lname, dev))
        if sw_ref is None:
            raise GraphError(
                f"no iteration token for frame {lname!r} on {dev!r} "
                f"(control edge from {ctrl_src!r} leaves the loop frame)")
        sw = sw_ref.node
        # the frame's NextIteration pairs with its Switch by name on both
        # the home ({lname}/next{i} vs {lname}/switch{i}) and replicated
        # ({pfx}/next vs {pfx}/switch) skeletons
        nxt = "next".join(sw.rsplit("switch", 1))
        if nxt not in pg.nodes:
            raise GraphError(
                f"cannot find NextIteration for switch {sw!r} of frame "
                f"{lname!r} (control edge from {ctrl_src!r})")
        if ctrl_src not in pg.nodes[nxt].control_inputs:
            pg.nodes[nxt].control_inputs.append(ctrl_src)
        key = (lname, dev)
        if key not in exit_tokens:
            ex = pg.add_node("Exit", [TensorRef(sw, 0)],
                             name=f"{lname}/ctl_exit{len(exit_tokens)}",
                             device=dev)
            place[ex.name] = dev
            exit_tokens[key] = ex.ref
        return exit_tokens[key]

    for name in list(names):
        node = pg.nodes[name]
        dst_dev = place[name]
        new_inputs: List[TensorRef] = []
        for ref in node.inputs:
            if ref.node in names and place[ref.node] != dst_dev:
                new_inputs.append(TensorRef(get_recv(ref, dst_dev), 0))
            else:
                new_inputs.append(ref)
        node.inputs = new_inputs
        new_ctrl: List[str] = []
        for c in node.control_inputs:
            if c in names and place[c] != dst_dev:
                src_dev = place[c]
                src_f = frames.get(c, ())
                dst_f = frames.get(name, ())
                if len(src_f) > 1:
                    from ..analysis.frames import describe_nested_straddle

                    raise GraphError(
                        f"control edge {c} -> {name} leaves a nested loop "
                        f"frame: " + describe_nested_straddle(
                            src_f, [c, name], [src_dev, dst_dev]))
                if not src_f:
                    # root-frame producer: zero-byte control token
                    tok = pg.add_node(
                        "Const", [], name=f"ctok/{c}/{name}",
                        attrs={"value": 0}, control_inputs=[c],
                        device=src_dev)
                    place[tok.name] = src_dev
                    recv_name = get_recv(tok.ref, dst_dev)
                elif dst_f == src_f:
                    # same frame, different device: a per-iteration token
                    # gated by the source device's iteration Switch so it
                    # fires (and dies) in the right iteration context
                    sw_ref = frame_tokens.get((src_f[-1], src_dev))
                    if sw_ref is None:
                        raise GraphError(
                            f"no iteration token for frame {src_f[-1]!r} "
                            f"on {src_dev!r} (control edge {c} -> {name})")
                    tok = pg.add_node(
                        "Identity", [sw_ref], name=f"ctok/{c}/{name}",
                        control_inputs=[c], device=src_dev)
                    place[tok.name] = src_dev
                    frames[tok.name] = src_f
                    recv_name = get_recv(tok.ref, dst_dev)
                elif not dst_f:
                    # in-frame producer, root-frame consumer
                    recv_name = get_recv(
                        get_exit_token(src_f[-1], src_dev, c), dst_dev)
                else:
                    raise GraphError(
                        f"control edge {c} -> {name} crosses loop frames "
                        f"{src_f!r} -> {dst_f!r}; route it through a loop "
                        "output instead")
                new_ctrl.append(recv_name)
            else:
                new_ctrl.append(c)
        node.control_inputs = new_ctrl

    device_nodes: Dict[str, Set[str]] = {}
    for n in pg.nodes:
        device_nodes.setdefault(place[n], set()).add(n)
    return Partitioned(graph=pg, device_nodes=device_nodes, placement=place,
                       n_transfers=n_transfers)
