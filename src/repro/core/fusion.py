"""Region fusion: compile pure subregions of partitioned graphs (§10).

The OSDI follow-up to the whitepaper closed the interpreter-dispatch gap
by fusing dataflow subgraphs into compiled kernels while leaving
communication and state in the runtime.  This pass does the same on top
of the §10 lowering: after placement/partitioning, each per-device
subgraph is decomposed into maximal acyclic *pure regions* — connected
node sets containing no Send/Recv, no control-flow primitives and no
eager-runtime-only stateful ops — and each region becomes a single
``FusedRegion`` super-node whose kernel is the region lowered through
:func:`repro.core.lowering.lower_region` and ``jax.jit``-compiled.  The
executor then dispatches a handful of fused kernels interleaved with the
runtime ops it must interpret (Send/Recv, queues, control flow) instead
of hundreds of Python-dispatched nodes.

Region criteria (the fused/unfused bit-parity contract, DESIGN.md §7):

* no runtime-only op (Send/Recv, queues, Save/Restore, Placeholder) and
  no control-flow primitive;
* no node *downstream* of a control-flow primitive — dead tensors
  (§4.4) must never cross a region boundary;
* no ``Variable`` node whose variable is written anywhere in the
  executed node set — the eager executor reads such variables in the
  first ready wave, before any assignment can run, and fusing the read
  into a later-dispatched region would observe the post-write value;
* no op with a per-device kernel override for the node's device kind
  (the lowering always traces the reference ``compute`` kernel);
* no node marked ``attrs={"nofuse": True}`` (the per-node escape hatch);
* no fetched zero-output node (operation fetches are resolved through
  the executor's ``done`` set, which only tracks dispatched nodes).

Acyclicity: nodes are labelled with a *phase* that is monotone along
every dependency edge — including the implicit Send→Recv pairing across
devices — and strictly increases when an edge leaves a non-fusible
node.  All fusible nodes of one device that share a phase form one
region: any would-be cycle through external nodes must pass a runtime
op and therefore re-enter at a strictly larger phase, a contradiction.

Before region discovery each partition runs a pre-fusion optimization
pipeline — prune → constant-fold → (scoped) CSE (§5.1) — so fusion
operates on a minimized graph.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from .graph import Graph, GraphError, Node, TensorRef
from . import control_flow as cf_mod
from . import cse as cse_mod
from . import ops as ops_mod
from ..obs.metrics import StatsDict

CF_PRIMITIVES = {"Switch", "Merge", "Enter", "Exit", "NextIteration", "LoopCond"}
RUNTIME_ONLY = {"Send", "Recv", "Save", "Restore", "QueueEnqueue",
                "QueueDequeue", "Placeholder"} | CF_PRIMITIVES
# stateful ops the §10 lowering models functionally (reads become inputs,
# writes become outputs committed by the dispatcher)
FUSIBLE_STATEFUL = {"Variable", "Assign", "AssignAdd"}
# Ops whose result depends on an accumulation/library-kernel order:
# MatMul (Eigen gemm vs naive loops), reductions (vectorized partial
# sums vs linear), Call (user closures may contain either).  Under the
# bit-parity contract ("strict" numerics) they stay eagerly dispatched —
# a fused kernel compiled at a different backend optimization level
# reassociates them — while order-insensitive elementwise/data-movement
# ops fuse freely.  numerics="fast" fuses everything at full XLA
# optimization under the per-op-class tolerance contract of DESIGN.md §9
# (repro.core.numerics), re-proven by the CI parity gate.
STRICT_UNFUSIBLE = {"MatMul", "Call", "ReduceSum", "ReduceMean",
                    "SoftMax", "SoftmaxXent", "SSDScan"}

# pass-invocation counters (see placement.STATS; DESIGN.md §5/§7),
# registry-backed since §16.4 — also visible as fusion.* counters
STATS = StatsDict("fusion", keys=(
    "fuse_calls", "regions_built", "nodes_fused",
    "consts_folded", "nodes_pruned", "cse_merged", "fallbacks"))


def REGION_CACHE_SIZE() -> int:
    """Per-region cap on cached (shape, dtype) -> jitted-executable entries
    (``REPRO_REGION_CACHE``, default 32; DESIGN.md §7)."""
    import os

    try:
        return int(os.environ.get("REPRO_REGION_CACHE", "32"))
    except ValueError:
        return 32


class FusionError(Exception):
    pass


def written_variables(g: Graph, names: Iterable[str]) -> Set[str]:
    """Variables mutated by any node of ``names`` (Assign/AssignAdd/Restore)."""
    written: Set[str] = set()
    for n in names:
        node = g.nodes[n]
        if node.op in ("Assign", "AssignAdd") and node.inputs:
            written.add(node.inputs[0].node)
        elif node.op == "Restore":
            written.update(node.attrs.get("var_names", ()))
    return written


def _device_kind(dev: Optional[str], default: str = "cpu") -> str:
    if not dev or "device:" not in dev:
        return default
    return dev.split("device:")[-1].split(":")[0]


@dataclasses.dataclass
class RegionSpec:
    """One fused region: members + the cut-edge contract (DESIGN.md §7).

    ``input_refs``/``output_refs`` are in the *original* node namespace
    (the partitioned graph before the rewrite); the rewritten
    ``FusedRegion`` node's inputs are positionally aligned with
    ``input_refs`` and its output port ``i`` carries ``output_refs[i]``.
    """

    name: str
    members: List[str]                 # topo order (also the effect order)
    subgraph: Graph                    # member nodes, original external refs
    input_refs: List[TensorRef]        # external data cut edges, positional
    output_refs: List[TensorRef]       # exported member tensors, positional
    control_externals: List[str]       # external control-dep sources
    var_read_attrs: Dict[str, Dict[str, Any]]  # Variable member -> attrs
    var_writes: List[str]
    device: Optional[str] = None
    # "strict": compile at XLA backend-optimization-level 0 so the fused
    # kernel is bit-identical to per-op eager dispatch (no FMA contraction
    # or cross-op rewrites) — the parity contract.  "fast": full backend
    # optimization; results may differ from the interpreter by ~1 ulp.
    numerics: str = "strict"
    # kernel-backend registry key (DESIGN.md §12): under a non-generic
    # backend, lower_region rewrites recognized idioms among the members
    # onto registered kernels for this region's device kind.  Dispatch is
    # fast-numerics-only: strict's bit-parity contract (and its
    # STRICT_UNFUSIBLE exclusions) keeps the matchable anchors out of
    # strict regions anyway.
    backend: str = "generic"
    device_kind: str = "cpu"

    def __post_init__(self) -> None:
        self._fn: Optional[Any] = None   # lowered python callable (trace source)
        self._jit_cache: Optional[Any] = None  # per-signature LRU of jitted fns
        self._var_order = sorted(self.var_read_attrs)  # fixed signature order
        # steady-state fast path: the last (signature, jitted fn) pair,
        # read/written without the LRU lock (a lost race merely rebuilds)
        self._last: Optional[Tuple[Any, Any]] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _lowered(self):
        with self._lock:
            if self._fn is None:
                from . import lowering

                backend = self.backend if self.numerics == "fast" else "generic"
                self._fn = lowering.lower_region(
                    self.subgraph, self.members, self.input_refs,
                    self.output_refs, self.members,
                    backend=backend, device_kind=self.device_kind)
            return self._fn

    def _cache(self):
        with self._lock:
            if self._jit_cache is None:
                # lazy import: executable.py imports this module at top level
                from .executable import ExecutableCache

                self._jit_cache = ExecutableCache(maxsize=REGION_CACHE_SIZE())
            return self._jit_cache

    def _jit(self):
        """A fresh jitted callable for one input signature.

        One ``jax.jit`` wrapper per (shape, dtype) signature, held in a
        bounded LRU: ``jax.jit``'s own per-wrapper trace cache is
        unbounded, so a serving workload feeding many shapes through one
        long-lived wrapper would grow memory without limit.  Evicting a
        wrapper drops its traces/executables; re-feeding that signature
        re-compiles transparently.
        """
        fn = self._lowered()
        if self.numerics == "strict":
            try:
                return jax.jit(fn, compiler_options={
                    "xla_backend_optimization_level": 0})
            except TypeError:  # older jax without compiler_options
                import warnings

                warnings.warn(
                    "this jax version cannot compile fused regions "
                    "at backend-opt-level 0; region "
                    f"{self.name!r} falls back to numerics='fast' "
                    "(fused results may differ from unfused by "
                    "~1 ulp)", RuntimeWarning, stacklevel=2)
                self.numerics = "fast"  # report the effective mode
        # "fast": plain jax.jit == full XLA backend optimization (FMA
        # contraction, reduction reassociation) — the §9 tolerance
        # contract bounds the drift and the CI parity gate enforces it
        return jax.jit(fn)

    @staticmethod
    def _abstract(v: Any):
        return (tuple(getattr(v, "shape", ()) or ()),
                str(getattr(v, "dtype", type(v).__name__)))

    def executable_for(self, inputs: Sequence[Any],
                       var_values: Dict[str, Any]):
        sig = (tuple(self._abstract(v) for v in inputs),
               tuple(self._abstract(var_values[k]) for k in self._var_order))
        last = self._last
        if last is not None and last[0] == sig:
            return last[1]  # single-signature steady state: no lock, no LRU
        jfn = self._cache().get_or_build(sig, self._jit)
        self._last = (sig, jfn)
        return jfn

    def dispatch(self, ctx, inputs: Sequence[Any]) -> Tuple[Any, ...]:
        """Run the compiled region: read vars, call the jitted kernel,
        commit variable writes (the FusedRegion opdef's kernel)."""
        var_values = {name: ctx.variables.read(name, attrs)
                      for name, attrs in self.var_read_attrs.items()}
        jfn = self.executable_for(inputs, var_values)
        outs, new_vars = jfn(tuple(inputs), var_values)
        for vname, v in new_vars.items():
            ctx.write_variable(vname, v)
        return tuple(outs)


@dataclasses.dataclass
class FusionResult:
    graph: Graph                        # rewritten graph with FusedRegion nodes
    names: Set[str]                     # executed node set in ``graph``
    regions: List[RegionSpec]
    fetch_map: Dict[TensorRef, TensorRef]   # original fetch ref -> rewritten
    placement: Optional[Dict[str, str]]     # node -> device (incl. regions)
    # True if the pre-fusion pipeline (prune/fold/CSE) or the rewrite
    # changed anything — the optimized graph is worth executing even when
    # no region met the size threshold
    changed: bool = False


# ---------------------------------------------------------------------------
# pre-fusion optimization pipeline: prune -> constant-fold -> scoped CSE


def _prune(g: Graph, names: Set[str], fetch_refs: Sequence[TensorRef],
           fed_ports: Set[Tuple[str, int]]) -> Set[str]:
    """Drop pure nodes that feed neither a fetch nor a stateful op."""
    roots = [r.node for r in fetch_refs if r.node in names]
    roots += [n for n in names if ops_mod.opdef(g.nodes[n].op).stateful]
    keep: Set[str] = set()
    stack = list(roots)
    while stack:
        n = stack.pop()
        if n in keep or n not in names:
            continue
        keep.add(n)
        node = g.nodes[n]
        for r in node.inputs:
            if (r.node, r.port) in fed_ports:
                continue  # §4.2: traversal stops at fed tensors
            stack.append(r.node)
        stack.extend(node.control_inputs)
    for n in names - keep:
        del g.nodes[n]
    STATS["nodes_pruned"] += len(names) - len(keep)
    return keep


def _fold_constants(g: Graph, names: Set[str],
                    fed_ports: Set[Tuple[str, int]],
                    kind_of) -> int:
    """Evaluate pure single-output ops whose inputs are all Const (§5.1)."""
    folded = 0
    for n in g.topo_sort(names):
        node = g.nodes[n]
        od = ops_mod.opdef(node.op)
        if (node.op == "Const" or node.op == "Call" or node.op in RUNTIME_ONLY
                or od.stateful or node.control_inputs or not node.inputs
                or od.num_outputs(node) != 1
                or kind_of(n) in od.kernels):
            continue
        vals = []
        for r in node.inputs:
            p = g.nodes.get(r.node)
            if (r.node, r.port) in fed_ports or p is None \
                    or p.op != "Const" or r.port != 0:
                vals = None
                break
            vals.append(jnp.asarray(p.attrs["value"]))
        if vals is None:
            continue
        try:
            out = od.compute(None, node, *vals)
        except Exception:  # noqa: BLE001 — a kernel that needs ctx stays unfolded
            continue
        node.op = "Const"
        node.inputs = []
        node.attrs = {"value": out[0]}
        folded += 1
    STATS["consts_folded"] += folded
    return folded


# ---------------------------------------------------------------------------
# region planning


def _classify(g: Graph, names: Set[str], placement: Optional[Dict[str, str]],
              default_kind: str, fed_ports: Set[Tuple[str, int]],
              fetch_nodes: Set[str], written_vars: Set[str],
              numerics: str = "strict"):
    """Per-node fusibility + phase labels (see module docstring)."""
    order = g.topo_sort(names)  # GraphError on real cycles
    idx = {n: i for i, n in enumerate(order)}

    # dependency edges, back edges dropped, plus Send->Recv pairing edges
    edges: List[Tuple[str, str]] = []
    by_key: Dict[str, Dict[str, str]] = {}
    for n in order:
        node = g.nodes[n]
        for d in g.deps(node):
            if d in names and g.nodes[d].op != "NextIteration":
                edges.append((d, n))
        if node.op in ("Send", "Recv"):
            by_key.setdefault(node.attrs["rendezvous_key"], {})[node.op] = n
    for pair in by_key.values():
        if "Send" in pair and "Recv" in pair:
            edges.append((pair["Send"], pair["Recv"]))
    edges.sort(key=lambda e: idx[e[0]])

    # frame boundary rule (§4.4 / DESIGN.md §8): a region never spans a
    # loop-frame boundary — every node with a non-root static frame stays
    # interpreted so the tagged-frame executor keeps driving it once per
    # iteration.  (The control-flow taint below subsumes this for graphs
    # built by the while_loop builder; the explicit frame check keeps the
    # invariant independent of how the frame was constructed.)
    frames = cf_mod.static_frames(g, names)

    # taint: anything downstream of a control-flow primitive may carry
    # dead tensors (§4.4) and must stay interpreted
    tainted = {n for n in names if g.nodes[n].op in CF_PRIMITIVES}
    for _ in range(len(names) + 2):
        changed = False
        for a, b in edges:
            if a in tainted and b not in tainted:
                tainted.add(b)
                changed = True
        if not changed:
            break

    def kind_of(n: str) -> str:
        if placement is not None and n in placement:
            return _device_kind(placement[n], default_kind)
        return _device_kind(g.nodes[n].device, default_kind)

    fusible: Dict[str, bool] = {}
    for n in names:
        node = g.nodes[n]
        od = ops_mod.opdef(node.op)
        fusible[n] = not (
            node.op in RUNTIME_ONLY
            or (numerics == "strict" and node.op in STRICT_UNFUSIBLE)
            or n in tainted
            or bool(frames.get(n))
            or (od.stateful and node.op not in FUSIBLE_STATEFUL)
            or (node.op == "Variable" and n in written_vars)
            or node.attrs.get("nofuse", False)
            or kind_of(n) in od.kernels
            or (n in fetch_nodes and od.num_outputs(node) == 0)
        )

    # phases: monotone along edges, +1 when leaving a non-fusible node.
    phase = {n: 0 for n in names}
    for it in range(len(names) + 2):
        changed = False
        for a, b in edges:
            p = phase[a] + (0 if fusible[a] else 1)
            if p > phase[b]:
                phase[b] = p
                changed = True
        if not changed:
            break
    else:
        raise FusionError("phase labelling did not converge (cyclic Send/Recv?)")
    return order, fusible, phase, kind_of


# ---------------------------------------------------------------------------


def fuse(
    g: Graph,
    node_names: Iterable[str],
    *,
    placement: Optional[Dict[str, str]] = None,
    device_kind: str = "cpu",
    feeds: Iterable[TensorRef] = (),
    fetch_refs: Sequence[TensorRef] = (),
    written_vars: Optional[Set[str]] = None,
    min_region_size: int = 2,
    run_optimizations: bool = True,
    numerics: Optional[str] = None,
    backend: str = "generic",
) -> FusionResult:
    """Plan regions over ``node_names`` of ``g`` and rewrite into a new
    graph where each region is one ``FusedRegion`` super-node.

    ``g`` is never mutated; the optimization pipeline and the rewrite
    operate on private copies.  ``placement`` (multi-device) groups
    regions per device; without it the whole set is one device of kind
    ``device_kind``.
    """
    STATS["fuse_calls"] += 1
    if numerics is None:
        import os
        numerics = os.environ.get("REPRO_FUSE_NUMERICS", "strict")
    names = set(node_names)
    g2 = g.subgraph(names)
    fed_ports = {(r.node, r.port) for r in feeds}
    fetch_nodes = {r.node for r in fetch_refs}
    if written_vars is None:
        written_vars = written_variables(g2, names)

    n_changes = 0
    if run_optimizations:
        n_changes += _fold_constants(
            g2, names, fed_ports,
            lambda n: _device_kind(
                placement[n] if placement and n in placement else g2.nodes[n].device,
                device_kind))
        kept = _prune(g2, names, fetch_refs, fed_ports)
        n_changes += len(names) - len(kept)
        names = kept

    order, fusible, phase, kind_of = _classify(
        g2, names, placement, device_kind, fed_ports, fetch_nodes,
        written_vars, numerics)

    def dev_of(n: str) -> str:
        if placement is not None:
            return placement.get(n, "")
        return ""

    if run_optimizations:
        # scoped CSE (§5.1): merge only within ONE device's fusible set —
        # the CSE key carries the node's *constraint* (often None), not
        # its placement, so a cross-device merge would leave a
        # cross-device edge with no Send/Recv pair.  Fetched nodes and
        # fed-port producers keep their identity.
        protected = fetch_nodes | {p for (p, _port) in fed_ports}
        by_dev: Dict[str, Set[str]] = {}
        for n in names:
            if fusible[n] and n not in protected:
                by_dev.setdefault(dev_of(n), set()).add(n)
        replaced: Dict[str, str] = {}
        for _dev, mergeable in sorted(by_dev.items()):
            if len(mergeable) > 1:
                replaced.update(
                    cse_mod.eliminate_common_subexpressions(g2, mergeable))
        if replaced:
            STATS["cse_merged"] += len(replaced)
            n_changes += len(replaced)
            names -= set(replaced)
            order = [n for n in order if n not in replaced]

    # group fusible nodes by (device, phase), members in topo order
    groups: Dict[Tuple[str, int], List[str]] = {}
    for n in order:
        if fusible[n]:
            groups.setdefault((dev_of(n), phase[n]), []).append(n)

    specs: List[RegionSpec] = []
    member_to_region: Dict[str, str] = {}
    for gi, ((dev, ph), members) in enumerate(sorted(
            groups.items(), key=lambda kv: (kv[0][1], kv[0][0]))):
        if len(members) < min_region_size:
            continue
        mset = set(members)
        rname = f"fused/d{gi}/p{ph}"
        while rname in g2.nodes:
            rname += "_"
        in_refs: List[TensorRef] = []
        seen_in: Set[Tuple[str, int]] = set()
        ctrl: List[str] = []
        for m in members:
            node = g2.nodes[m]
            for r in node.inputs:
                key = (r.node, r.port)
                if (r.node not in mset or key in fed_ports) and key not in seen_in:
                    seen_in.add(key)
                    in_refs.append(TensorRef(r.node, r.port))
            for c in node.control_inputs:
                if c not in mset and c not in ctrl:
                    ctrl.append(c)
        out_refs: List[TensorRef] = []
        seen_out: Set[Tuple[str, int]] = set()

        def _export(r: TensorRef) -> None:
            key = (r.node, r.port)
            if r.node in mset and key not in fed_ports and key not in seen_out:
                seen_out.add(key)
                out_refs.append(TensorRef(r.node, r.port))

        for n2 in order:
            if n2 in mset:
                continue
            for r in g2.nodes[n2].inputs:
                _export(r)
        for fr in fetch_refs:
            _export(fr)

        sub = g2.subgraph(members)
        sub.loop_specs = {}
        sub.cond_specs = {}
        specs.append(RegionSpec(
            name=rname,
            members=members,
            subgraph=sub,
            input_refs=in_refs,
            output_refs=out_refs,
            control_externals=ctrl,
            var_read_attrs={m: dict(g2.nodes[m].attrs) for m in members
                            if g2.nodes[m].op == "Variable"},
            var_writes=sorted({g2.nodes[m].inputs[0].node for m in members
                               if g2.nodes[m].op in ("Assign", "AssignAdd")}),
            device=dev or None,
            numerics=numerics,
            backend=backend,
            device_kind=_device_kind(dev or None, device_kind),
        ))
        for m in members:
            member_to_region[m] = rname

    # ---- rewrite -----------------------------------------------------
    out_index: Dict[Tuple[str, int], Tuple[str, int]] = {}
    spec_by_name = {s.name: s for s in specs}
    for s in specs:
        for i, r in enumerate(s.output_refs):
            out_index[(r.node, r.port)] = (s.name, i)

    def map_ref(r: TensorRef) -> TensorRef:
        key = (r.node, r.port)
        if r.node in member_to_region and key not in fed_ports:
            rn, i = out_index[key]
            return TensorRef(rn, i)
        return r

    def map_ctrls(ctrls: Iterable[str]) -> List[str]:
        mapped: List[str] = []
        for c in ctrls:
            mc = member_to_region.get(c, c)
            if mc not in mapped:
                mapped.append(mc)
        return mapped

    fg = Graph()
    emitted: Set[str] = set()
    for n in g2.nodes:  # insertion order preserved for topo tie-breaks
        if n not in names:
            continue
        if n in member_to_region:
            rn = member_to_region[n]
            if rn in emitted:
                continue
            emitted.add(rn)
            s = spec_by_name[rn]
            fg.nodes[rn] = Node(
                name=rn, op="FusedRegion",
                inputs=[map_ref(r) for r in s.input_refs],
                control_inputs=map_ctrls(s.control_externals),
                attrs={"spec": s}, device=s.device)
        else:
            node = g2.nodes[n]
            fg.nodes[n] = Node(
                name=n, op=node.op,
                inputs=[map_ref(r) for r in node.inputs],
                control_inputs=map_ctrls(node.control_inputs),
                attrs=dict(node.attrs), device=node.device)
    fg.loop_specs = dict(g2.loop_specs)
    fg.cond_specs = dict(g2.cond_specs)
    fg_names = set(fg.nodes)

    try:  # safety net: region contraction must never create a cycle
        fg.topo_sort(fg_names)
    except GraphError as e:
        raise FusionError(f"region contraction created a cycle: {e}") from e

    fetch_map = {fr: map_ref(fr) for fr in fetch_refs
                 if map_ref(fr) != fr}

    new_placement: Optional[Dict[str, str]] = None
    if placement is not None:
        new_placement = {n: placement[n] for n in fg_names if n in placement}
        for s in specs:
            new_placement[s.name] = s.device or ""

    STATS["regions_built"] += len(specs)
    STATS["nodes_fused"] += len(member_to_region)
    return FusionResult(graph=fg, names=fg_names, regions=specs,
                        fetch_map=fetch_map, placement=new_placement,
                        changed=bool(n_changes or specs))


def try_fuse(*args, **kwargs) -> Optional[FusionResult]:
    """``fuse`` with a fail-open contract: any planning/rewrite error
    falls back to the unfused executable (counted in STATS)."""
    try:
        return fuse(*args, **kwargs)
    except (FusionError, GraphError, KeyError) as _e:  # noqa: F841
        STATS["fallbacks"] += 1
        return None
