"""§5.2 ASAP/ALAP critical-path scheduling of Recv nodes.

Without precautions, Recv nodes may all start as soon as execution begins,
holding remote tensors in memory long before they are needed.  We compute
per-node ASAP times (longest path from sources) and ALAP times (latest
start that does not delay the sinks), and for each Recv with positive
slack we insert a control edge from a suitably-late local predecessor of
its consumer so the Recv is delayed until just before its result is
needed — reducing the peak-memory window exactly as described.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

from .graph import Graph, Node, TensorRef
from .placement import CostModel
from . import control_flow as cf_mod
from ..obs.metrics import StatsDict
from ..runtime.devices import DeviceSet


def _times(g: Graph, names: Set[str], cm: CostModel, devices, placement):
    def dur(n: str) -> float:
        node = g.nodes[n]
        dev = devices[placement[n]] if placement and n in placement else None
        if dev is None:
            return 1.0
        return cm.compute_seconds(node, dev)

    def fwd_deps(n: str) -> List[str]:
        # only deps inside the executed ``names`` (fed/pruned producers may
        # linger in g.nodes without ASAP/ALAP times), and never through a
        # NextIteration -> Merge back edge (§4.4) — back edges are
        # non-ordering, so consulting them would read times of nodes that
        # sort *after* their consumer
        return [d for d in g.deps(g.nodes[n])
                if d in names and g.nodes[d].op != "NextIteration"]

    order = g.topo_sort(names)  # back edges are non-ordering (graph.py)
    asap: Dict[str, float] = {}
    for n in order:
        start = 0.0
        for d in fwd_deps(n):
            start = max(start, asap[d] + dur(d))
        asap[n] = start
    makespan = max((asap[n] + dur(n) for n in order), default=0.0)
    alap: Dict[str, float] = {}
    consumers: Dict[str, List[str]] = {n: [] for n in names}
    for n in order:
        for d in fwd_deps(n):
            consumers[d].append(n)
    for n in reversed(order):
        latest_end = makespan
        for c in consumers[n]:
            latest_end = min(latest_end, alap[c])
        alap[n] = latest_end - dur(n)
    return asap, alap


# pass-invocation counter (see placement.STATS; DESIGN.md §5),
# registry-backed since §16.4
STATS = StatsDict("scheduler", keys=("schedule_calls",))


def schedule_recvs(
    g: Graph,
    node_names: Optional[Set[str]] = None,
    cost_model: Optional[CostModel] = None,
    devices: Optional[DeviceSet] = None,
    placement: Optional[Dict[str, str]] = None,
) -> int:
    """Insert delaying control edges on Recv nodes; returns #edges added."""
    STATS["schedule_calls"] += 1
    names = set(node_names) if node_names is not None else set(g.nodes)
    cm = cost_model or CostModel()
    asap, alap = _times(g, names, cm, devices, placement)
    # §4.4: in-frame Recvs fire once per loop iteration, driven by their
    # frame's iteration token — start-time slack is meaningless for them,
    # and a delaying edge into or out of a frame would couple one
    # iteration's schedule to unrelated root work (or deadlock the frame)
    frames = cf_mod.static_frames(g, names)

    def closure(target: str) -> Set[str]:
        # like Graph.transitive_closure, but tolerant of dangling refs —
        # fed edges leave inputs pointing at producers that were pruned
        # out of the executed graph (§4.2)
        seen: Set[str] = set()
        stack = [target]
        while stack:
            t = stack.pop()
            if t in seen or t not in g.nodes:
                continue
            seen.add(t)
            stack.extend(g.deps(g.nodes[t]))
        return seen

    added = 0
    for n in list(names):
        node = g.nodes[n]
        if node.op != "Recv":
            continue
        if frames.get(n):
            continue  # per-iteration Recv: paced by its frame token
        slack = alap[n] - asap[n]
        if slack <= 0:
            continue
        # find the latest node (same device if known) finishing before ALAP(recv)
        best, best_t = None, -1.0
        for m in names:
            if m == n or g.nodes[m].op in ("Recv", "Send"):
                continue
            if frames.get(m):
                continue  # never pace a root Recv behind loop-frame work
            if placement is not None and placement.get(m) != placement.get(n):
                continue
            if alap[m] <= alap[n] and asap[m] > best_t and m not in closure(n):
                # avoid cycles: m must not depend on the recv
                if n in closure(m):
                    continue
                best, best_t = m, asap[m]
        if best is not None and best not in node.control_inputs:
            node.control_inputs.append(best)
            added += 1
    return added
