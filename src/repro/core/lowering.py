"""§10 JIT lowering: a pruned dataflow subgraph -> one pure JAX function.

The paper's "future work" compiler ("take a subgraph of a TensorFlow
execution ... and generate an optimized routine for this subgraph") is the
production path of this reproduction: ``compile_subgraph`` prunes the
graph to the fetches (§4.2 semantics), optionally runs CSE (§5.1), then
evaluates the subgraph symbolically under JAX tracing.  Variables become
explicit function inputs and (for written variables) outputs, so the
lowered function is pure and pjit-able under any mesh:

    fn(feeds: dict[str, Array], var_values: dict[str, Any])
        -> (fetch_values: list, new_var_values: dict)

Control-flow subgraphs recorded by the §4.4 builders are emitted as
``lax.while_loop`` / ``lax.cond``; stateful runtime ops that have no
compiled analogue (queues, Send/Recv, Save/Restore) are rejected — they
belong to the eager runtime and to the data pipeline *around* the step.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax

from .graph import Graph, Node, TensorRef, as_ref
from . import control_flow
from . import ops as ops_mod
from . import cse as cse_mod

_UNSUPPORTED = {"Send", "Recv", "Save", "Restore", "QueueEnqueue", "QueueDequeue"}


class LoweringError(Exception):
    pass


@dataclasses.dataclass
class Lowered:
    fn: Callable  # (feeds: dict, vars: dict) -> (list fetches, dict new_vars)
    feed_refs: List[TensorRef]
    fetch_refs: List[TensorRef]
    var_reads: List[str]
    var_writes: List[str]
    n_nodes: int


class _LoweringState:
    """Tracks current variable values during symbolic evaluation."""

    def __init__(self, var_values: Dict[str, Any]):
        self.var_current = dict(var_values)
        self.var_reads: Set[str] = set()
        self.var_writes: Set[str] = set()

    # ExecutionContext protocol subset used by pure/stateful op kernels:
    def read_variable(self, node: Node):
        name = node.name
        self.var_reads.add(name)
        if name not in self.var_current:
            init = node.attrs.get("init")
            if init is None:
                raise LoweringError(f"variable {name!r} has no value and no init")
            self.var_current[name] = init() if callable(init) else init
        return self.var_current[name]

    def write_variable(self, var_name: str, value):
        self.var_writes.add(var_name)
        self.var_current[var_name] = value


class _Evaluator:
    def __init__(self, g: Graph, node_set: Set[str], state: _LoweringState,
                 bindings: Dict[Tuple[str, int], Any],
                 overrides: Optional[Dict[str, Callable]] = None):
        self.g = g
        self.node_set = node_set
        self.state = state
        self.bindings = dict(bindings)  # (node, port) -> value
        # anchor -> kernel-registry override (DESIGN.md §12); only region
        # lowering populates this, sub-evaluators stay generic
        self.overrides = overrides or {}
        self.memo: Dict[Tuple[str, int], Any] = {}
        self.executed: Set[str] = set()
        # node -> owning loop/cond spec name
        self.loop_of: Dict[str, str] = {}
        self.cond_of: Dict[str, str] = {}
        for lname, spec in g.loop_specs.items():
            for m in control_flow.loop_spec_members(lname, spec):
                self.loop_of[m] = lname
        for cname, spec in g.cond_specs.items():
            for m in control_flow.cond_spec_members(spec):
                self.cond_of[m] = cname

    # ------------------------------------------------------------------
    def value(self, ref: TensorRef):
        key = (ref.node, ref.port)
        if key in self.bindings:
            return self.bindings[key]
        if key in self.memo:
            return self.memo[key]
        name = ref.node
        if name in self.loop_of:
            self._run_loop(self.loop_of[name])
            if key not in self.memo:
                raise LoweringError(f"loop {self.loop_of[name]} did not produce {ref}")
            return self.memo[key]
        if name in self.cond_of:
            self._run_cond(self.cond_of[name])
            if key not in self.memo:
                raise LoweringError(f"cond {self.cond_of[name]} did not produce {ref}")
            return self.memo[key]
        self.execute(name)
        if key not in self.memo:
            raise LoweringError(f"node {name} produced no output port {ref.port}")
        return self.memo[key]

    def execute(self, name: str) -> None:
        if name in self.executed:
            return
        node = self.g.nodes.get(name)
        if node is None:
            raise LoweringError(f"unknown node {name!r}")
        if node.op in _UNSUPPORTED:
            raise LoweringError(
                f"op {node.op} ({name}) is eager-runtime-only and cannot be lowered")
        # control dependencies first (effect ordering)
        for c in node.control_inputs:
            if c in self.node_set:
                self.execute(c)
        if node.op == "Placeholder":
            raise LoweringError(f"placeholder {name!r} must be fed at compile time")
        if node.op == "Variable":
            # not memoized: reads observe the current (possibly updated) value
            self.executed.add(name)
            self.memo[(name, 0)] = self.state.read_variable(node)
            return
        ov = self.overrides.get(name)
        if ov is not None:
            # registered backend kernel: consumes its pattern's leaf refs
            # directly (interior members still trace generically; unused
            # interior values are dead code to XLA)
            self.executed.add(name)
            outs = ov(self, node)
        else:
            ins = [self.value(r) for r in node.inputs]
            self.executed.add(name)
            od = ops_mod.opdef(node.op)
            outs = od.compute(self.state, node, *ins)
        for p, v in enumerate(outs):
            self.memo[(name, p)] = v
        # Variable re-read support: invalidate variable memo after writes
        if node.op in ("Assign", "AssignAdd"):
            var_name = node.inputs[0].node
            self.memo[(var_name, 0)] = self.state.var_current[var_name]

    # ------------------------------------------------------------------
    def _sub_eval(self, extra_bindings: Dict[Tuple[str, int], Any],
                  release: Set[str] = frozenset()) -> "_Evaluator":
        ev = _Evaluator(self.g, self.node_set, self.state, {})
        ev.bindings = dict(self.bindings)
        ev.bindings.update({k: v for k, v in self.memo.items()})
        ev.bindings.update(extra_bindings)
        # nodes of the spec being expanded must evaluate as plain ops inside
        # the branch/body function, not re-trigger the macro
        for n in release:
            ev.loop_of.pop(n, None)
            ev.cond_of.pop(n, None)
        return ev

    def _external_refs(self, node_names: Sequence[str], internal: Set[str]) -> List[TensorRef]:
        refs = []
        for n in node_names:
            node = self.g.nodes[n]
            for r in node.inputs:
                if r.node not in internal:
                    refs.append(r)
        return refs

    def _run_loop(self, lname: str) -> None:
        spec = self.g.loop_specs[lname]
        internal = set(spec.cond_nodes + spec.body_nodes + spec.merge_names
                       + spec.switch_names + spec.exit_names
                       + [f"{lname}/enter{i}" for i in range(len(spec.init_refs))]
                       + [f"{lname}/next{i}" for i in range(len(spec.init_refs))]
                       + [f"{lname}/cond"])
        init_vals = tuple(self.value(r) for r in spec.init_refs)
        # pin external closure values (evaluated once, outside the loop)
        for r in self._external_refs(spec.cond_nodes + spec.body_nodes, internal):
            if (r.node, r.port) not in self.memo and (r.node, r.port) not in self.bindings:
                self.value(r)

        def cond_f(carry):
            binds = {(m, 0): c for m, c in zip(spec.merge_names, carry)}
            ev = self._sub_eval(binds, release=internal)
            return ev.value(spec.pred_ref)

        def body_f(carry):
            binds = {(m, 0): c for m, c in zip(spec.merge_names, carry)}
            binds.update({(s, 1): c for s, c in zip(spec.switch_names, carry)})
            ev = self._sub_eval(binds, release=internal)
            return tuple(ev.value(r) for r in spec.body_out_refs)

        results = jax.lax.while_loop(cond_f, body_f, init_vals)
        for ename, v in zip(spec.exit_names, results):
            self.memo[(ename, 0)] = v
            self.executed.add(ename)

    def _run_cond(self, cname: str) -> None:
        spec = self.g.cond_specs[cname]
        pred = self.value(spec.pred_ref)
        in_vals = tuple(self.value(r) for r in spec.input_refs)
        internal = set(spec.switch_names + spec.true_nodes + spec.false_nodes
                       + spec.merge_names)
        for r in self._external_refs(spec.true_nodes + spec.false_nodes, internal):
            if (r.node, r.port) not in self.memo and (r.node, r.port) not in self.bindings:
                self.value(r)

        def branch(port: int, out_refs):
            def f(vals):
                binds = {(s, port): v for s, v in zip(spec.switch_names, vals)}
                ev = self._sub_eval(binds, release=internal)
                return tuple(ev.value(r) for r in out_refs)
            return f

        results = jax.lax.cond(pred, branch(1, spec.true_out_refs),
                               branch(0, spec.false_out_refs), in_vals)
        for mname, v in zip(spec.merge_names, results):
            self.memo[(mname, 0)] = v
            self.executed.add(mname)


# ---------------------------------------------------------------------------


def _specs_intersect(g: Graph, node_set: Set[str]) -> bool:
    """True iff any loop/cond spec has members inside ``node_set``."""
    for lname, spec in g.loop_specs.items():
        if node_set.intersection(control_flow.loop_spec_members(lname, spec)):
            return True
    for spec in g.cond_specs.values():
        if node_set.intersection(control_flow.cond_spec_members(spec)):
            return True
    return False


def lower_region(
    g: Graph,
    members: Sequence[str],
    input_refs: Sequence[TensorRef],
    output_refs: Sequence[TensorRef],
    member_order: Optional[Sequence[str]] = None,
    *,
    backend: str = "generic",
    device_kind: str = "cpu",
) -> Callable:
    """Lower one fused *region* of a (partitioned) graph to a pure function.

    Unlike :func:`compile_subgraph`, which owns the whole (feeds->fetches)
    signature, a region is an arbitrary pure node set cut out of a larger
    graph: every external data edge (including fed tensors and tensors
    produced by Send/Recv/other regions) is an explicit positional input
    binding, and the exported tensors are explicit positional outputs.

    Returns ``fn(input_values, var_values) -> (outputs, new_var_values)``:

    * ``input_values`` — values for ``input_refs``, in order;
    * ``var_values``   — {var_name: value} for every Variable member read;
    * ``outputs``      — tuple of values for ``output_refs``, in order;
    * ``new_var_values`` — {var_name: value} for every variable written.

    Every member is force-executed (in ``member_order``) so effect-only
    nodes (assignments, NoOps) run exactly as the eager executor would
    have run them — the fused/unfused parity contract.
    """
    member_set = set(members)
    in_refs = [as_ref(r) for r in input_refs]
    out_refs = [as_ref(r) for r in output_refs]
    order = list(member_order) if member_order is not None else list(members)

    overrides: Dict[str, Callable] = {}
    if backend and backend != "generic":
        from . import kernel_registry

        overrides = kernel_registry.plan_region_overrides(
            g, member_set, backend, device_kind)

    def fn(input_values: Sequence[Any], var_values: Dict[str, Any]):
        state = _LoweringState(dict(var_values))
        bindings = {(r.node, r.port): v for r, v in zip(in_refs, input_values)}
        ev = _Evaluator(g, member_set, state, bindings, overrides=overrides)
        outs = tuple(ev.value(r) for r in out_refs)
        for m in order:
            ev.execute(m)
        new_vars = {n: state.var_current[n] for n in state.var_writes}
        return outs, new_vars

    return fn


def compile_subgraph(
    session,
    fetches,
    feeds: Sequence,
    *,
    run_cse: bool = True,
    extra_updates: Sequence[str] = (),
) -> Lowered:
    """Lower the (feeds -> fetches) subgraph of ``session.graph``.

    ``extra_updates``: names of stateful nodes (e.g. the optimizer's update
    group) that must execute even if no fetch depends on them by data edge.
    """
    fetch_list = fetches if isinstance(fetches, (list, tuple)) else [fetches]
    fetch_refs = [as_ref(f) for f in fetch_list]
    feed_refs = [as_ref(f) for f in feeds]

    roots = [r.node for r in fetch_refs] + list(extra_updates)
    node_set = session.pruned_nodes(
        [TensorRef(r, 0) for r in roots], {fr: None for fr in feed_refs})

    g = copy.deepcopy(session.graph.subgraph(node_set))
    g.loop_specs = session.graph.loop_specs
    g.cond_specs = session.graph.cond_specs
    if run_cse and not _specs_intersect(g, set(g.nodes)):
        # CSE must not run across control-flow boundaries — but only the
        # loops/conds whose members are actually IN this pruned subgraph
        # matter; unrelated specs elsewhere in the Session graph must not
        # disable CSE for a straight-line step (§5.1).
        cse_mod.eliminate_common_subexpressions(g)
    node_set = set(g.nodes)

    var_read_candidates = [n for n in g.nodes if g.nodes[n].op == "Variable"]
    write_ops = [n for n in g.nodes if g.nodes[n].op in ("Assign", "AssignAdd")]
    var_write_names = sorted({g.nodes[n].inputs[0].node for n in write_ops})

    def fn(feed_values: Dict[str, Any], var_values: Dict[str, Any]):
        state = _LoweringState(var_values)
        bindings = {}
        for r in feed_refs:
            key = str(r)
            if key not in feed_values and r.node in feed_values and r.port == 0:
                key = r.node
            bindings[(r.node, r.port)] = feed_values[key]
        ev = _Evaluator(g, node_set, state, bindings)

        def fetch(r):
            node = g.nodes.get(r.node)
            if node is not None and ops_mod.opdef(node.op).num_outputs(node) == 0:
                ev.execute(r.node)  # operation fetch: run for effect
                return None
            return ev.value(r)

        outs = [fetch(r) for r in fetch_refs]
        for extra in extra_updates:
            ev.execute(extra)
        new_vars = {n: state.var_current[n] for n in state.var_writes}
        return outs, new_vars

    return Lowered(
        fn=fn,
        feed_refs=feed_refs,
        fetch_refs=fetch_refs,
        var_reads=sorted(var_read_candidates),
        var_writes=var_write_names,
        n_nodes=len(node_set),
    )
