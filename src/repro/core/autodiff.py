"""§4.1 automatic gradient computation by *extending the graph*.

``gradients(g, ys, xs)`` finds the forward path from each ``x`` to ``y``,
then backtracks from ``y`` to ``x`` adding one gradient node per forward
operation, composing partial gradients along the backward path with the
chain rule.  Each gradient node invokes the *gradient function registered
for the forward operation* and — exactly as the paper describes — receives
not only the partial gradients already computed along the backward path
but also (optionally) the inputs and outputs of the forward operation.
Unused output ports get a zero gradient ("the first input to O's gradient
function is set to 0 since dC/dy1 = 0").
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp

from .graph import Graph, Node, TensorRef, as_ref
from . import ops as ops_mod


def _ones_like(v):
    return jnp.ones_like(v)


def _zeros_like(x):
    return jnp.zeros_like(x)


class _GradFn:
    """The backward kernel of one forward node, as a *picklable* callable.

    Closures cannot cross a process boundary; this class-with-state form
    pickles by reference to the class plus the forward :class:`Node`
    (plain data), so §4.1 gradient graphs ship to §11 worker pools like
    any other primitive-op graph — the opdef is re-resolved from the
    registry at call time on whichever process executes the node.

    For a forward Call node this only works when its attrs are themselves
    picklable: factory-form Calls (``GraphBuilder.call_factory``, DESIGN.md
    §15) carry a ``module:qualname`` spec instead of a closure, so both the
    forward node embedded here and the backward kernel rebuild on the
    worker via ``ops.resolve_call_fn``.
    """

    def __init__(self, node: Node, n_in: int, n_out: int) -> None:
        self.node, self.n_in, self.n_out = node, n_in, n_out

    def __call__(self, *vals):
        od = ops_mod.opdef(self.node.op)
        ins = vals[:self.n_in]
        outs = vals[self.n_in:self.n_in + self.n_out]
        gouts = vals[self.n_in + self.n_out:]
        gins = od.grad(self.node, list(ins), list(outs), list(gouts))
        return tuple(
            jnp.zeros_like(ins[i]) if gi is None else gi
            for i, gi in enumerate(gins)
        )


def _zeros_like_node(g: Graph, ref: TensorRef) -> TensorRef:
    node = g.add_node("Call", [ref], name=f"grad/zeros_{ref.node}_{ref.port}",
                      attrs={"fn": _zeros_like, "n_out": 1})
    return node.ref


def _add_n(g: Graph, refs: List[TensorRef], base: str) -> TensorRef:
    if len(refs) == 1:
        return refs[0]
    acc = refs[0]
    for i, r in enumerate(refs[1:]):
        acc = g.add_node("Add", [acc, r], name=f"{base}/acc{i}").ref
    return acc


def gradients(
    g: Graph,
    ys: Sequence["Node | TensorRef | str"],
    xs: Sequence["Node | TensorRef | str"],
    grad_ys: Optional[Sequence[TensorRef]] = None,
) -> List[Optional[TensorRef]]:
    """Extend ``g`` with gradient nodes; return dC/dx refs (None if unreachable)."""
    y_refs = [as_ref(y) for y in ys]
    x_refs = [as_ref(x) for x in xs]

    # --- forward reachability: nodes on a path from any x to any y.
    consumers = g.consumers()
    from_x: Set[str] = set()
    stack = [r.node for r in x_refs]
    while stack:
        n = stack.pop()
        if n in from_x:
            continue
        from_x.add(n)
        stack.extend(consumers[n])
    to_y: Set[str] = g.transitive_closure([r.node for r in y_refs])
    active = from_x & to_y

    # --- seed gradients.
    grads: Dict[Tuple[str, int], List[TensorRef]] = {}
    for i, yr in enumerate(y_refs):
        if grad_ys is not None:
            seed = as_ref(grad_ys[i])
        else:
            seed = g.add_node(
                "Call", [yr], name=f"grad/ones_{yr.node}",
                attrs={"fn": _ones_like, "n_out": 1},
            ).ref
        grads.setdefault((yr.node, yr.port), []).append(seed)

    # --- backward pass in reverse topological order over the active set.
    order = [n for n in g.topo_sort(g.transitive_closure([r.node for r in y_refs]))
             if n in active]
    for name in reversed(order):
        node = g.nodes[name]
        od = ops_mod.opdef(node.op)
        n_out = od.num_outputs(node)
        out_grad_refs = [grads.get((name, p)) for p in range(n_out)]
        if all(r is None for r in out_grad_refs):
            continue  # no gradient flows through this node
        if od.grad is None:
            continue  # non-differentiable: gradient stops (leaf or opaque op)

        # Materialize zero grads for unused ports (§4.1).
        gout_refs: List[TensorRef] = []
        for p, refs in enumerate(out_grad_refs):
            if refs is None:
                gout_refs.append(_zeros_like_node(g, TensorRef(name, p)))
            else:
                gout_refs.append(_add_n(g, refs, f"grad/{name}/out{p}"))

        n_in = len(node.inputs)
        fwd_out_refs = [TensorRef(name, p) for p in range(n_out)]

        gnode = g.add_node(
            "Call",
            list(node.inputs) + fwd_out_refs + gout_refs,
            name=f"grad/{name}",
            attrs={"fn": _GradFn(node, n_in, n_out), "n_out": n_in,
                   "is_grad_of": name},
        )
        for i, in_ref in enumerate(node.inputs):
            if in_ref.node in active or in_ref.node in {r.node for r in x_refs}:
                grads.setdefault((in_ref.node, in_ref.port), []).append(
                    TensorRef(gnode.name, i))

    # --- collect dC/dx.
    results: List[Optional[TensorRef]] = []
    for xr in x_refs:
        refs = grads.get((xr.node, xr.port))
        if refs is None:
            results.append(None)
        else:
            results.append(_add_n(g, refs, f"grad/wrt_{xr.node}_{xr.port}"))
    return results
