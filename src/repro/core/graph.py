"""Dataflow graph IR — the paper's §2 programming model.

A computation is a directed graph of :class:`Node`\\ s.  Each node
instantiates an *operation* (registered in :mod:`repro.core.ops`), has zero
or more data inputs (edges carrying tensors, identified by
``"node_name:port"``), zero or more *control* inputs (happens-before edges
carrying no data), a dict of attributes fixed at graph-construction time,
and an optional device constraint string (§4.3).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

_NAME_RE = re.compile(r"^[A-Za-z0-9_.\-/]+$")


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """A reference to output ``port`` of node ``node`` (§4.2 "name:port")."""

    node: str
    port: int = 0

    @staticmethod
    def parse(spec: "TensorRef | str | Tuple[str, int]") -> "TensorRef":
        if isinstance(spec, TensorRef):
            return spec
        if isinstance(spec, tuple):
            return TensorRef(spec[0], int(spec[1]))
        if ":" in spec:
            name, port = spec.rsplit(":", 1)
            return TensorRef(name, int(port))
        return TensorRef(spec, 0)

    def __str__(self) -> str:
        return f"{self.node}:{self.port}"


@dataclasses.dataclass
class Node:
    """One operation instance in the graph."""

    name: str
    op: str
    inputs: List[TensorRef] = dataclasses.field(default_factory=list)
    control_inputs: List[str] = dataclasses.field(default_factory=list)
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    device: Optional[str] = None  # §4.3 partial device constraint

    def output(self, port: int = 0) -> TensorRef:
        return TensorRef(self.name, port)

    # Convenience: node used directly where a TensorRef is expected.
    @property
    def ref(self) -> TensorRef:
        return TensorRef(self.name, 0)


def as_ref(x: "Node | TensorRef | str") -> TensorRef:
    if isinstance(x, Node):
        return x.ref
    return TensorRef.parse(x)


class GraphError(Exception):
    pass


class Graph:
    """A mutable dataflow graph (the Session's ``Extend`` target)."""

    def __init__(self) -> None:
        self.nodes: Dict[str, Node] = {}  # insertion-ordered
        self._name_counts: Dict[str, int] = {}
        # Monotonic structure version: bumped on every add_node/extend so
        # Session-level Executable caches can detect staleness cheaply
        # without hashing the graph (DESIGN.md §5).
        self.version: int = 0
        # §4.4 structured-loop metadata recorded by control_flow builders so
        # the JIT lowering can emit lax.while_loop for loops that the eager
        # executor runs via the Switch/Merge/Enter/... primitives.
        self.loop_specs: Dict[str, Any] = {}
        self.cond_specs: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    def unique_name(self, base: str) -> str:
        if base not in self.nodes and base not in self._name_counts:
            self._name_counts[base] = 0
            return base
        while True:
            self._name_counts[base] = self._name_counts.get(base, 0) + 1
            cand = f"{base}_{self._name_counts[base]}"
            if cand not in self.nodes:
                return cand

    def add_node(
        self,
        op: str,
        inputs: Sequence["Node | TensorRef | str"] = (),
        *,
        name: Optional[str] = None,
        attrs: Optional[Dict[str, Any]] = None,
        control_inputs: Sequence["Node | str"] = (),
        device: Optional[str] = None,
    ) -> Node:
        name = self.unique_name(name or op)
        if not _NAME_RE.match(name):
            raise GraphError(f"invalid node name {name!r}")
        node = Node(
            name=name,
            op=op,
            inputs=[as_ref(i) for i in inputs],
            control_inputs=[c.name if isinstance(c, Node) else str(c) for c in control_inputs],
            attrs=dict(attrs or {}),
            device=device,
        )
        for ref in node.inputs:
            if ref.node not in self.nodes:
                raise GraphError(f"node {name!r} references unknown input {ref}")
        for cname in node.control_inputs:
            if cname not in self.nodes:
                raise GraphError(f"node {name!r} references unknown control input {cname!r}")
        self.nodes[name] = node
        self.version += 1
        return node

    def extend(self, other: "Graph") -> None:
        """Session.Extend — merge ``other`` into this graph (§2)."""
        for node in other.nodes.values():
            if node.name in self.nodes:
                raise GraphError(f"duplicate node {node.name!r} in Extend")
            self.nodes[node.name] = node
        self.loop_specs.update(other.loop_specs)
        self.cond_specs.update(other.cond_specs)
        self.version += 1

    def __contains__(self, name: str) -> bool:
        return name in self.nodes

    def __getitem__(self, name: str) -> Node:
        return self.nodes[name]

    # ------------------------------------------------------------------
    def deps(self, node: Node) -> List[str]:
        """All predecessor node names (data + control)."""
        return [r.node for r in node.inputs] + list(node.control_inputs)

    def consumers(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for node in self.nodes.values():
            for d in self.deps(node):
                out[d].append(node.name)
        return out

    def transitive_closure(self, targets: Iterable[str]) -> Set[str]:
        """§2 Run: the set of nodes that must execute to produce ``targets``."""
        needed: Set[str] = set()
        stack = [t for t in targets]
        while stack:
            n = stack.pop()
            if n in needed:
                continue
            if n not in self.nodes:
                raise GraphError(f"unknown node {n!r}")
            needed.add(n)
            stack.extend(self.deps(self.nodes[n]))
        return needed

    def subgraph(self, names: Iterable[str]) -> "Graph":
        """Copy of the induced subgraph.  Nodes are shallow-copied (fresh
        input/control lists) so passes like §3.2.2 partitioning can rewire
        edges without corrupting the Session's graph."""
        keep = set(names)
        g = Graph()
        for name, node in self.nodes.items():
            if name in keep:
                g.nodes[name] = Node(
                    name=node.name, op=node.op, inputs=list(node.inputs),
                    control_inputs=list(node.control_inputs),
                    attrs=dict(node.attrs), device=node.device)
        g.loop_specs = dict(self.loop_specs)
        g.cond_specs = dict(self.cond_specs)
        return g

    def topo_sort(self, names: Optional[Iterable[str]] = None) -> List[str]:
        """Dependency-respecting order (construction order used as tiebreak,
        the paper's §4.1 memory heuristic).

        Edges whose producer is a ``NextIteration`` node — the only legal
        cycle source (the §4.4 while-loop back edge into Merge) — are
        treated as non-ordering, so structural passes (placement, Recv
        scheduling, region fusion) can order graphs that contain loops
        instead of raising.  Any other cycle raises :class:`GraphError`.
        """
        keep = set(names) if names is not None else set(self.nodes)
        indeg: Dict[str, int] = {}
        consumers: Dict[str, List[str]] = {n: [] for n in keep}

        def _deps(node: Node) -> List[str]:
            return [d for d in self.deps(node)
                    if d not in self.nodes or self.nodes[d].op != "NextIteration"]

        for n in self.nodes:  # insertion order => deterministic tie-break
            if n not in keep:
                continue
            node = self.nodes[n]
            ds = [d for d in _deps(node) if d in keep]
            indeg[n] = len(ds)
            for d in ds:
                consumers[d].append(n)
        # stable: iterate in insertion order repeatedly
        order: List[str] = []
        ready = [n for n in self.nodes if n in keep and indeg[n] == 0]
        seen_ready = set(ready)
        while ready:
            n = ready.pop(0)
            order.append(n)
            for c in consumers[n]:
                indeg[c] -= 1
                if indeg[c] == 0 and c not in seen_ready:
                    ready.append(c)
                    seen_ready.add(c)
        if len(order) != len(keep):
            raise GraphError("graph contains a cycle (use control_flow builders for loops)")
        return order
