"""Compile-once / run-many Executables (§3.2, §4.2; DESIGN.md §5).

The paper's master "caches these graphs so that subsequent uses incur no
recomputation overhead": pruning, placement, partitioning and Recv
scheduling happen once per *run signature* — the (fetches, fed-tensor
keys, device set, graph version) tuple — not once per ``Session.run``.

An :class:`Executable` is the cached product of that pipeline:

* the pruned node set (§4.2 feed/fetch rewrite),
* for multi-device graphs: the placement (§3.2.1), the partitioned
  graph with canonicalised Send/Recv pairs (§3.2.2) and the §5.2 Recv
  schedule,
* one *reusable* :class:`~repro.core.executor.Executor` per device —
  executors hold only immutable static analysis, so the same Executable
  can run repeatedly and concurrently; each ``run`` allocates nothing
  but per-run :class:`~repro.core.executor.ExecutorState` (plus a fresh
  rendezvous for multi-device runs).

:class:`ExecutableCache` is the small thread-safe LRU the Session keys
by :class:`RunSignature`.  The serving layer applies the same
compile-once/run-many discipline with a lighter mechanism — the batcher
caches its jitted slot step directly on the model instance
(serving/batcher.py).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, FrozenSet, Hashable, List, Optional, Sequence, Set, Tuple

from .graph import TensorRef
from .executor import ExecutionContext, Executor, ExecutorError
from . import fusion as fusion_mod
from . import placement as placement_mod
from . import partition as partition_mod
from . import scheduler as scheduler_mod
from ..analysis import verifier as verifier_mod
from ..runtime.rendezvous import Rendezvous

# Ops whose side effects cannot be replayed for a reference re-execution:
# running the unfused-strict reference AND the fused-fast candidate on the
# same feeds would double-consume queue items / double-write checkpoints.
# Executables containing these skip the per-session parity guard (the CI
# gate covers their op classes instead; DESIGN.md §9).
GUARD_UNSAFE = frozenset(
    {"QueueEnqueue", "QueueDequeue", "Save", "Restore", "Send", "Recv"})


@dataclasses.dataclass(frozen=True)
class RunSignature:
    """Cache key for one prepared run pipeline (DESIGN.md §5).

    Two ``Session.run`` calls share an Executable iff they fetch the same
    tensors, feed the same tensor *keys* (values differ per run), see the
    same device set, and the graph has not been extended in between.
    """

    fetches: Tuple[TensorRef, ...]
    feed_keys: FrozenSet[TensorRef]
    device_fingerprint: Tuple[str, ...]
    graph_version: int
    # region fusion and its numerics mode are part of the signature:
    # flipping ``Session.fuse_regions`` or ``Session.numerics`` mid-
    # process must rebuild, never reuse a stale plan — strict and fast
    # executables cache separately (a cached strict executable silently
    # serving a fast-mode session, or vice versa, would make results
    # signature-dependent; DESIGN.md §9)
    fuse_regions: bool = True
    fuse_numerics: str = "strict"
    # the kernel-backend registry key (DESIGN.md §12): flipping
    # Session(backend=...) must rebuild, never reuse — a cached
    # generic-lowered Executable serving a pallas session (or vice
    # versa) would make which kernels run signature-dependent
    kernel_backend: str = "generic"
    # §14 verify mode: a cached warn-mode Executable must not silently
    # serve a Session that asked for verify="error" (the error-mode
    # build is the one that raises), so the mode is part of the key
    verify: str = "warn"

    @staticmethod
    def for_session(session, fetch_refs: Sequence[TensorRef],
                    feed_keys) -> "RunSignature":
        devs = session.devices
        fp = devs.fingerprint() if devs is not None else ()
        cluster = getattr(session, "cluster", None)
        if cluster is not None:
            # §3.3/DESIGN.md §13: the cluster's SHAPE (task count, devices
            # per task, kind) is part of the device fingerprint — a
            # different topology must rebuild Executables.  Endpoints are
            # deliberately absent: partial re-placement and whole-pool
            # rebinds keep cached Executables (placement depends only on
            # virtual device names) and re-register through the master's
            # generation counter / per-task re-registration instead
            fp = tuple(fp) + cluster.fingerprint()
        # every options-dependent key component derives from the session's
        # resolved SessionOptions in this one place (repro.core.options) —
        # the getattr fallbacks only serve bare session-like test doubles
        opts = getattr(session, "options", None)
        if opts is not None:
            fuse_regions, fuse_numerics = opts.fuse_regions, opts.numerics
            kernel_backend, verify = opts.backend, opts.verify
        else:
            fuse_regions = getattr(session, "fuse_regions", True)
            fuse_numerics = getattr(
                session, "numerics",
                os.environ.get("REPRO_FUSE_NUMERICS", "strict"))
            kernel_backend = getattr(session, "kernel_backend", "generic")
            verify = getattr(session, "verify", "warn")
        return RunSignature(
            fetches=tuple(fetch_refs),
            feed_keys=frozenset(feed_keys),
            device_fingerprint=fp,
            graph_version=session.graph.version,
            fuse_regions=fuse_regions,
            fuse_numerics=fuse_numerics,
            kernel_backend=kernel_backend,
            verify=verify,
        )


class ExecutableCache:
    """Thread-safe LRU of prepared execution state.

    ``maxsize == 0`` disables caching entirely (every lookup misses and
    nothing is stored) — used to benchmark the uncached path.
    """

    def __init__(self, maxsize: int = 16) -> None:
        self.maxsize = maxsize
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "invalidations": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.stats["hits"] += 1
                return self._entries[key]
            self.stats["misses"] += 1
            return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.maxsize <= 0:
            return
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.stats["evictions"] += 1

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        cached = self.get(key)
        if cached is not None:
            return cached
        value = builder()
        self.put(key, value)
        return value

    def invalidate(self, predicate: Optional[Callable[[Hashable], bool]] = None) -> int:
        """Drop entries whose key matches ``predicate`` (all if None)."""
        with self._lock:
            if predicate is None:
                n = len(self._entries)
                self._entries.clear()
            else:
                stale = [k for k in self._entries if predicate(k)]
                for k in stale:
                    del self._entries[k]
                n = len(stale)
            self.stats["invalidations"] += n
            return n

    def keys(self) -> List[Hashable]:
        with self._lock:
            return list(self._entries)


class Executable:
    """One fully-prepared run pipeline bound to a Session.

    Construction performs prune -> place -> partition -> schedule-recvs ->
    executor static analysis exactly once; ``run`` only allocates per-run
    state (and, multi-device, a fresh rendezvous + worker threads), so it
    is safe to call repeatedly and concurrently.
    """

    def __init__(self, session, fetch_refs: Sequence[TensorRef],
                 feed_keys, *,
                 node_set: Optional[Set[str]] = None,
                 compress: bool = False,
                 cost_model: Optional[placement_mod.CostModel] = None,
                 force_partitioned: bool = False,
                 fuse_regions: Optional[bool] = None,
                 numerics: Optional[str] = None) -> None:
        self.session = session
        self.fetches: Tuple[TensorRef, ...] = tuple(fetch_refs)
        self.feed_keys: FrozenSet[TensorRef] = frozenset(feed_keys)
        self.graph_version = session.graph.version
        self.compress = compress
        self.fuse_regions = (getattr(session, "fuse_regions", True)
                             if fuse_regions is None else fuse_regions)
        # numerics policy for fused regions (DESIGN.md §9): "strict"
        # (bit-parity) or "fast" (full XLA opt, tolerance-bounded drift)
        self.numerics: str = (
            numerics if numerics is not None
            else getattr(session, "numerics",
                         os.environ.get("REPRO_FUSE_NUMERICS", "strict")))
        # kernel-backend registry key (DESIGN.md §12); cluster executions
        # ship it in the WirePlan payloads so workers re-fuse their slices
        # under the same backend (distrib/worker.py, §15)
        self.kernel_backend: str = getattr(session, "kernel_backend",
                                           "generic")
        # DESIGN.md §7: region fusion runs once per signature, here; the
        # result (incl. each region's lazily-jitted kernel) is cached with
        # the Executable.  Fetches into fused members are remapped to the
        # exporting region's output port.
        self.fusion: Optional[fusion_mod.FusionResult] = None
        self._fetch_remap: Dict[TensorRef, TensorRef] = {}
        # tracer= runs observe the faithful unfused interpretation (per-
        # kernel EEG events, §9.2); built lazily on the first traced run
        self._unfused: Optional[Tuple[Any, Any]] = None
        self._unfused_lock = threading.Lock()

        if node_set is None:
            node_set = session.pruned_nodes(
                self.fetches, {k: None for k in self.feed_keys})
        self.node_set: Set[str] = set(node_set)

        devices = session.devices
        # Session.run uses the plain in-thread executor for 0/1-device
        # sessions; run_partitioned forces the worker-thread path even for
        # one device (it carries the device-kind kernel dispatch and the
        # join timeout), and a cluster session always partitions — even a
        # one-worker pool executes in its worker process, not here.
        self.multi_device = devices is not None and (
            len(devices) > 1 or force_partitioned
            or getattr(session, "cluster", None) is not None)
        # §3.3/DESIGN.md §11: a cluster session ships per-device subgraphs
        # to worker processes instead of running local executor threads
        self.cluster = getattr(session, "cluster", None)
        self.wire_plan = None
        if self.multi_device:
            cm = self._cost_model = cost_model or placement_mod.CostModel()
            self.placement = placement_mod.place(
                session.graph, devices, cm, self.node_set)
            # §4.4/DESIGN.md §8: partition is frame-aware — a while-loop
            # whose body straddles devices gets its control skeleton
            # replicated per device here, once, and the resulting
            # loop-bearing partition is cached by RunSignature exactly
            # like any straight-line graph.
            self.partitioned = partition_mod.partition(
                session.graph, self.placement, self.node_set, compress=compress)
            # §14 verifier (DESIGN.md): analyze the partitioned plan —
            # the canonical Send/Recv pairs and per-device schedule are
            # what actually runs — once per build; the report rides the
            # Executable so a cache hit re-runs no analysis.
            self.verify_report = verifier_mod.verify_executable(self)
            exec_graph = self.partitioned.graph
            exec_placement = self.partitioned.placement
            device_nodes = self.partitioned.device_nodes
            if self.cluster is not None:
                # ship the *unfused* partitioned subgraphs (fusion specs
                # hold jitted closures that cannot cross a process
                # boundary); each worker re-fuses its local slice under
                # the same numerics policy (distrib/worker.py, §7/§9)
                scheduler_mod.schedule_recvs(
                    exec_graph, set(exec_graph.nodes), cm, devices,
                    exec_placement)
                self.device_executors = {}
                self.fetch_by_dev = self._route_fetches(
                    exec_placement, device_nodes, remap=False)
                self.n_nodes = len(exec_graph.nodes)
                from ..distrib.master import WirePlan

                self.wire_plan = WirePlan(self, device_nodes)
                # kept for the §13 distributed parity guard: the strict
                # reference plan is built lazily from the same partition
                self._wire_device_nodes = device_nodes
                self._wire_strict: Optional[WirePlan] = None
                self._init_parity_guard(session)
                return
            if self.fuse_regions:
                fus = fusion_mod.try_fuse(
                    exec_graph, set(exec_graph.nodes),
                    placement=exec_placement,
                    feeds=self.feed_keys, fetch_refs=self.fetches,
                    written_vars=fusion_mod.written_variables(
                        exec_graph, exec_graph.nodes),
                    numerics=self.numerics,
                    backend=self.kernel_backend)
                if fus is not None and (fus.regions or fus.changed):
                    self.fusion = fus
                    exec_graph = fus.graph
                    exec_placement = fus.placement
                    self._fetch_remap = fus.fetch_map
                    device_nodes = {}
                    for n in fus.names:
                        device_nodes.setdefault(
                            exec_placement[n], set()).add(n)
            scheduler_mod.schedule_recvs(
                exec_graph, set(exec_graph.nodes), cm, devices, exec_placement)
            # one immutable Executor per device, reused across runs
            self.device_executors = self._build_executors(
                exec_graph, device_nodes)
            self.fetch_by_dev = self._route_fetches(
                exec_placement, device_nodes, remap=True)
            self.n_nodes = len(exec_graph.nodes)
        else:
            # §14 verifier, single-device path: the pruned subgraph.
            self.verify_report = verifier_mod.verify_executable(self)
            exec_graph, exec_names = session.graph, self.node_set
            if self.fuse_regions:
                fus = fusion_mod.try_fuse(
                    session.graph, self.node_set, placement=None,
                    feeds=self.feed_keys, fetch_refs=self.fetches,
                    written_vars=fusion_mod.written_variables(
                        session.graph, self.node_set),
                    numerics=self.numerics,
                    backend=self.kernel_backend)
                if fus is not None and (fus.regions or fus.changed):
                    self.fusion = fus
                    exec_graph, exec_names = fus.graph, fus.names
                    self._fetch_remap = fus.fetch_map
            self.executor = Executor(exec_graph, node_filter=exec_names)
            self.n_nodes = len(exec_names)

        self._init_parity_guard(session)

    def _init_parity_guard(self, session) -> None:
        # ---- fast-mode parity guard (DESIGN.md §9) -------------------
        # The first run of a fast-numerics Executable is verified against
        # the unfused-strict reference within the §9 per-op-class
        # tolerances; with ``REPRO_NUMERICS_GUARD=sample:N`` every Nth
        # subsequent run re-verifies too (long-lived serving processes:
        # input distribution shift can expose drift the first batch
        # didn't).  A breach warns and permanently falls back to strict
        # (unfused) execution.  Skipped when the executed set contains
        # ops whose side effects cannot be replayed (queues, checkpoint
        # IO) — the CI parity gate still covers those op classes.
        # Cluster Executables get the DISTRIBUTED guard (§13): Variable
        # state lives worker-side, so the snapshot/restore rides
        # get_variables/set_variables and the reference is a strict wire
        # run of the same partition (strict == unfused bit-for-bit, §7);
        # a breach demotes to the strict WirePlan, never to local
        # execution (which would desync from worker-side state).
        self._strict_fallback = False
        self._parity_pending = False
        self._guard_lock = threading.Lock()
        self._guard_vars: List[str] = []
        self._guard_tol = None
        self._guard_every: Optional[int] = None
        self._guard_runs = 0
        fused = self.fusion is not None and self.fusion.regions
        if (self.numerics == "fast"
                and (fused or self.wire_plan is not None)
                and getattr(session, "parity_guard", False)):
            ops = {session.graph.nodes[n].op for n in self.node_set}
            if not ops & GUARD_UNSAFE:
                from . import numerics as numerics_mod  # lazy: import cycle

                self._parity_pending = True
                # only *written* variables can drift (read-only ones are
                # restored-snapshot-identical by construction); limiting
                # the snapshot avoids holding 3 extra copies of e.g. a
                # serve graph's full params through the first token
                self._guard_vars = sorted(
                    fusion_mod.written_variables(session.graph,
                                                 self.node_set)
                    & {n for n in self.node_set
                       if session.graph.nodes[n].op == "Variable"})
                kinds = ("cpu",)
                if self.multi_device and getattr(self, "placement", None):
                    kinds = tuple(sorted(
                        {fusion_mod._device_kind(d, "cpu")
                         for d in self.placement.values()})) or ("cpu",)
                self._guard_tol = numerics_mod.tolerance_for_ops(
                    ops, device_kinds=kinds, backend=self.kernel_backend)
                self._guard_every = getattr(session, "parity_guard_every", None)

    # ------------------------------------------------------------------
    def run(self, feeds: Optional[Dict[TensorRef, Any]] = None, *,
            trace: Optional[List[str]] = None, tracer: Any = None,
            spans: Any = None, timeout: float = 60.0) -> List[Any]:
        feeds = feeds or {}
        if frozenset(feeds) != self.feed_keys:
            raise ExecutorError(
                f"feed keys {sorted(map(str, feeds))} do not match the keys this "
                f"Executable was compiled for {sorted(map(str, self.feed_keys))}")
        # Session(trace_dir=) turns on the §16 span stream for every run of
        # this session, including make_callable paths that pass no kwargs.
        # Unlike trace=/tracer= it is NOT part of the run signature: spans
        # observe the compiled artifact without changing it.
        if spans is None:
            spans = getattr(self.session, "_spans", None)
        if self.wire_plan is not None:
            # DESIGN.md §11: multi-process execution over the wire
            # rendezvous; the legacy per-kernel tracer needs the in-process
            # engine, but the §16 span stream traces cluster runs natively
            if tracer is not None or trace is not None:
                raise ExecutorError(
                    "trace=/tracer= are not supported for cluster execution "
                    "(use Session(trace_dir=) / REPRO_TRACE for the "
                    "distributed EEG, or run without cluster= for legacy "
                    "per-kernel tracing)")
            if self._strict_fallback:
                # §13 breach demotion: route through the strict wire plan
                # (same partition, strict numerics worker-side) — NOT the
                # local unfused pipeline, which would run against stale
                # master-side Variable state
                return self._wire_strict_plan().run(feeds, timeout=timeout,
                                                    spans=spans)
            if self._parity_pending:
                return self._guarded_wire_run(feeds, timeout, spans=spans)
            if self._sample_due():
                return self._guarded_wire_run(feeds, timeout, sampled=True,
                                              spans=spans)
            return self.wire_plan.run(feeds, timeout=timeout, spans=spans)
        if tracer is not None and self.fusion is not None:
            # per-kernel tracing: run the faithful unfused interpretation
            # (fused kernels are opaque blobs to an EEG-style tracer)
            return self._run_unfused(feeds, trace=trace, tracer=tracer,
                                     timeout=timeout)
        if self._strict_fallback:
            # a parity breach demoted this Executable (DESIGN.md §9): the
            # unfused pipeline IS strict execution, bit-identical to the
            # pre-fusion engine
            return self._run_unfused(feeds, trace=trace, tracer=tracer,
                                     spans=spans, timeout=timeout)
        if self._parity_pending:
            return self._guarded_run(feeds, trace, tracer, timeout,
                                     spans=spans)
        if self._sample_due():
            return self._guarded_run(feeds, trace, tracer, timeout,
                                     sampled=True, spans=spans)
        return self._dispatch(feeds, trace=trace, tracer=tracer, spans=spans,
                              timeout=timeout)

    def _sample_due(self) -> bool:
        """REPRO_NUMERICS_GUARD=sample:N — is this run a re-verification?
        The counter starts after the (always-verified) first run."""
        if self._guard_every is None or self._strict_fallback:
            return False
        with self._guard_lock:
            self._guard_runs += 1
            return self._guard_runs % self._guard_every == 0

    def _dispatch(self, feeds: Dict[TensorRef, Any], *,
                  trace: Optional[List[str]], tracer: Any,
                  timeout: float, spans: Any = None) -> List[Any]:
        """The prepared (possibly fused) pipeline, no guard logic."""
        if self.multi_device:
            return self._run_multi(feeds, trace=trace, tracer=tracer,
                                   spans=spans, timeout=timeout)
        fetches = [self._fetch_remap.get(r, r) for r in self.fetches]
        return self.executor.run(fetches, feeds, ctx=self.session._ctx(),
                                 trace=trace, tracer=tracer, spans=spans)

    def _run_unfused(self, feeds: Dict[TensorRef, Any], *,
                     trace: Optional[List[str]], tracer: Any,
                     timeout: float, spans: Any = None) -> List[Any]:
        """The lazily-built unfused pipeline: per-kernel tracing, the
        parity-guard reference, and the post-breach strict fallback."""
        if self.multi_device:
            execs, fetch_by_dev = self._unfused_pipeline()
            return self._run_multi(
                feeds, trace=trace, tracer=tracer, spans=spans,
                timeout=timeout, executors=execs, fetch_by_dev=fetch_by_dev,
                remap=False)
        executor, _ = self._unfused_pipeline()
        return executor.run(self.fetches, feeds, ctx=self.session._ctx(),
                            trace=trace, tracer=tracer, spans=spans)

    def _guarded_run(self, feeds: Dict[TensorRef, Any],
                     trace: Optional[List[str]], tracer: Any,
                     timeout: float, *, sampled: bool = False,
                     spans: Any = None) -> List[Any]:
        """Verified run of a fast-numerics Executable (the first run, and
        with guard sampling every Nth thereafter): execute the unfused-
        strict reference AND the fused-fast pipeline on the same feeds
        (variable state snapshotted in between so both start identically)
        and require the drift to stay within the §9 tolerances.  On a
        breach: warn, restore the reference results/state, and demote the
        Executable to strict execution permanently.
        """
        with self._guard_lock:
            if not sampled and not self._parity_pending:
                # raced with another first run
                if self._strict_fallback:
                    return self._run_unfused(feeds, trace=trace,
                                             tracer=tracer, spans=spans,
                                             timeout=timeout)
                return self._dispatch(feeds, trace=trace, tracer=tracer,
                                      spans=spans, timeout=timeout)
            from . import numerics as numerics_mod

            store = self.session.variables
            g = self.session.graph
            # force-init so both executions observe identical initial state
            snap = {n: store.read(n, g.nodes[n].attrs)
                    for n in self._guard_vars}
            ref = self._run_unfused(feeds, trace=None, tracer=None,
                                    timeout=timeout)
            ref_vars = {n: store.read(n, g.nodes[n].attrs)
                        for n in self._guard_vars}
            for n, v in snap.items():
                store.write(n, v)
            got = self._dispatch(feeds, trace=trace, tracer=tracer,
                                 spans=spans, timeout=timeout)
            got_vars = {n: store.read(n, g.nodes[n].attrs)
                        for n in self._guard_vars}
            # elementwise either-criterion (compare), NOT an aggregate
            # max-drift check: max ULP and max rel may come from
            # different tensors that each pass on their own bound —
            # merging them first would demote spuriously
            ok, drift = numerics_mod.compare(
                list(ref) + [ref_vars[n] for n in self._guard_vars],
                list(got) + [got_vars[n] for n in self._guard_vars],
                self._guard_tol)
            if not ok:
                import warnings

                warnings.warn(
                    f"fast-numerics parity breach: fused-fast drifted "
                    f"{drift} from the unfused-strict reference, beyond "
                    f"the {self._guard_tol} tolerance for this graph's op "
                    f"classes; falling back to strict execution for "
                    f"fetches {[str(r) for r in self.fetches]} "
                    f"(DESIGN.md §9)", RuntimeWarning, stacklevel=3)
                self._strict_fallback = True
                for n, v in ref_vars.items():
                    store.write(n, v)
                # cleared only with the verdict, inside the lock: an
                # early clear would let a concurrent run() slip past the
                # guard unverified and race the comparison; and if either
                # execution raised above, the Executable stays pending so
                # the next run re-verifies
                self._parity_pending = False
                return ref
            self._parity_pending = False
            return got

    # ------------------------------------------------------------------
    def _wire_strict_plan(self):
        """Companion strict-numerics WirePlan over the same partition —
        the §13 distributed guard's reference pipeline and the
        post-breach fallback.  Registered lazily, on first need."""
        from ..distrib.master import WirePlan

        with self._unfused_lock:
            if self._wire_strict is None:
                self._wire_strict = WirePlan(
                    self, self._wire_device_nodes, numerics="strict",
                    backend="generic")
            return self._wire_strict

    def _guarded_wire_run(self, feeds: Dict[TensorRef, Any],
                          timeout: float, *, sampled: bool = False,
                          spans: Any = None) -> List[Any]:
        """The §9 parity guard, distributed (§13): Variable state lives in
        the worker processes, so the snapshot/rewind rides
        ``get_variables``/``set_variables`` and the strict reference is a
        wire run of the same partition under strict numerics (workers
        re-fuse strict, which is bit-identical to unfused; §7).  Both
        executions therefore observe identical worker-side starting
        state.  A breach warns, force-restores the reference's Variable
        values, and demotes this Executable to the strict plan."""
        with self._guard_lock:
            if not sampled and not self._parity_pending:
                # raced with another first run
                if self._strict_fallback:
                    return self._wire_strict_plan().run(feeds, timeout=timeout,
                                                        spans=spans)
                return self.wire_plan.run(feeds, timeout=timeout, spans=spans)
            from . import numerics as numerics_mod

            plan = self.wire_plan
            strict = self._wire_strict_plan()
            # register (and SEED Variables) before snapshotting: on the
            # very first run nothing exists worker-side yet, and the
            # reference run below mutates the real worker state
            plan.ensure_registered()
            strict.ensure_registered()
            snap = plan.snapshot_variables(self._guard_vars)
            ref = strict.run(feeds, timeout=timeout)
            ref_vars = plan.snapshot_variables(self._guard_vars)
            plan.restore_variables(snap)
            got = plan.run(feeds, timeout=timeout, spans=spans)
            got_vars = plan.snapshot_variables(self._guard_vars)
            names = sorted(set(ref_vars) & set(got_vars))
            ok, drift = numerics_mod.compare(
                list(ref) + [ref_vars[n] for n in names],
                list(got) + [got_vars[n] for n in names],
                self._guard_tol)
            if not ok:
                import warnings

                warnings.warn(
                    f"fast-numerics parity breach (distributed): fused-fast "
                    f"drifted {drift} from the strict wire reference, beyond "
                    f"the {self._guard_tol} tolerance for this graph's op "
                    f"classes; falling back to strict wire execution for "
                    f"fetches {[str(r) for r in self.fetches]} "
                    f"(DESIGN.md §9/§13)", RuntimeWarning, stacklevel=3)
                self._strict_fallback = True
                plan.restore_variables(ref_vars)
                self._parity_pending = False
                return ref
            self._parity_pending = False
            return got

    # ------------------------------------------------------------------
    @staticmethod
    def _build_executors(graph, device_nodes) -> Dict[str, Executor]:
        return {
            dev: Executor(graph, node_filter=names, device_label=dev)
            for dev, names in device_nodes.items()
        }

    def _route_fetches(self, placement: Dict[str, str], device_nodes,
                       *, remap: bool) -> Dict[str, List[int]]:
        """device -> indices of ``self.fetches`` that device produces.

        ``remap`` routes fetches into fused members through the exporting
        region's node (the fused pipeline); the unfused pipeline routes
        the original refs.
        """
        fetch_by_dev: Dict[str, List[int]] = {}
        for i, ref in enumerate(self.fetches):
            mref = self._fetch_remap.get(ref, ref) if remap else ref
            dev = placement.get(mref.node)
            if dev is None and ref in self.feed_keys:
                # fully-fed fetch: any worker returns the fed value
                dev = next(iter(device_nodes))
            fetch_by_dev.setdefault(dev, []).append(i)
        return fetch_by_dev

    def _unfused_pipeline(self):
        """Lazily-built unfused executors for tracer= runs (DESIGN.md §7)."""
        with self._unfused_lock:
            if self._unfused is None:
                if self.multi_device:
                    pg = self.partitioned.graph
                    scheduler_mod.schedule_recvs(
                        pg, set(pg.nodes), self._cost_model,
                        self.session.devices, self.partitioned.placement)
                    self._unfused = (
                        self._build_executors(
                            pg, self.partitioned.device_nodes),
                        self._route_fetches(
                            self.partitioned.placement,
                            self.partitioned.device_nodes, remap=False))
                else:
                    self._unfused = (
                        Executor(self.session.graph, node_filter=self.node_set),
                        None)
            return self._unfused

    # ------------------------------------------------------------------
    def _run_multi(self, feeds: Dict[TensorRef, Any], *,
                   trace: Optional[List[str]], tracer: Any,
                   timeout: float,
                   executors: Optional[Dict[str, Executor]] = None,
                   fetch_by_dev: Optional[Dict[str, List[int]]] = None,
                   remap: bool = True, spans: Any = None) -> List[Any]:
        from ..obs import metrics as metrics_mod

        session = self.session
        executors = executors if executors is not None else self.device_executors
        fetch_by_dev = (fetch_by_dev if fetch_by_dev is not None
                        else self.fetch_by_dev)
        # per-run rendezvous: concurrent runs never mix; its recv timeout
        # tracks the run deadline so a caller-raised timeout is honoured
        run_rdv = Rendezvous(timeout=timeout)
        results: Dict[int, Any] = {}
        errors: List[BaseException] = []
        lock = threading.Lock()

        def mark_progress(dev_name: str) -> None:
            # §16.4 last-progress gauge: a hung run's report reads this to
            # say how long each stuck device has been silent
            metrics_mod.gauge(
                f"exec.device.{dev_name}.last_progress_ts").set(time.time())

        def worker(dev_name: str, executor: Executor) -> None:
            mark_progress(dev_name)
            ctx = ExecutionContext(
                variables=session.variables,
                rendezvous=run_rdv,
                queues=session.queues,
                checkpoint_io=session.checkpoint_io,
                device_kind=dev_name.split("device:")[-1].split(":")[0],
            )
            local_trace: Optional[List[str]] = [] if trace is not None else None
            idxs = fetch_by_dev.get(dev_name, [])
            if remap:
                local_fetches = [
                    self._fetch_remap.get(self.fetches[i], self.fetches[i])
                    for i in idxs]
            else:
                local_fetches = [self.fetches[i] for i in idxs]
            try:
                vals = executor.run(local_fetches, feeds, ctx=ctx,
                                    trace=local_trace, tracer=tracer,
                                    spans=spans)
                with lock:
                    for i, v in zip(idxs, vals):
                        results[i] = v
                    if trace is not None:
                        trace.extend(local_trace or [])
            except BaseException as e:  # noqa: BLE001 — §3.3: surface any worker failure
                with lock:
                    errors.append(e)
            finally:
                mark_progress(dev_name)

        threads = {
            dev: threading.Thread(target=worker, args=(dev, ex), daemon=True)
            for dev, ex in executors.items()
        }
        for t in threads.values():
            t.start()
        deadline = time.monotonic() + timeout
        for t in threads.values():
            t.join(timeout=max(0.0, deadline - time.monotonic()))

        if errors:
            # §3.3 fault tolerance: abort the whole graph execution on any failure
            raise errors[0]
        stuck = sorted(dev for dev, t in threads.items() if t.is_alive())
        if stuck:
            # §3.3: name the owning worker *process*, not just the virtual
            # device — multi-process hangs are diagnosed by which OS
            # process holds the stuck executor (distrib workers report
            # their task/pid the same way; DESIGN.md §11).  Each stuck
            # device also reports its last-progress timestamp from the
            # metrics registry (§16.4) so the report distinguishes
            # never-started from wedged-mid-run.
            now = time.time()

            def _age(dev: str) -> str:
                ts = metrics_mod.gauge(
                    f"exec.device.{dev}.last_progress_ts").value
                return f"{now - ts:.1f}s ago" if ts else "never"

            ident = ", ".join(
                f"{dev} (in-process worker thread {threads[dev].name!r}, "
                f"pid {os.getpid()}, last progress {_age(dev)})"
                for dev in stuck)
            raise ExecutorError(
                f"graph execution timed out after {timeout:.1f}s: worker(s) for "
                f"{ident} never finished (stuck Send/Recv or a hung "
                f"kernel; §3.3 failure reporting)")
        missing = [str(self.fetches[i]) for i in range(len(self.fetches))
                   if i not in results]
        if missing:
            raise ExecutorError(
                f"workers finished but fetches {missing} were never produced "
                f"(partition/fetch routing bug; §3.3 failure reporting)")
        return [results[i] for i in range(len(self.fetches))]
