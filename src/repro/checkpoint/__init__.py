from .manager import FileCheckpointIO, CheckpointManager, attach_save_restore

__all__ = ["FileCheckpointIO", "CheckpointManager", "attach_save_restore"]
