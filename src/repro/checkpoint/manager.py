"""§3.3 fault tolerance: Save/Restore nodes + periodic checkpointing.

Each Variable connects to a Save node executed every N steps/seconds, and
to a Restore node enabled in the first iteration after a restart.  On any
worker failure the whole graph execution aborts and restarts from the
last checkpoint (tested in tests/test_checkpoint.py by killing a training
loop mid-run and restoring).

Storage is ``.npz`` per checkpoint path with a pytree manifest, so the
same IO serves both the graph-engine Variables and the compiled path's
parameter/optimizer pytrees.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.graph import Node
from ..core.ops import GraphBuilder


class FileCheckpointIO:
    """Persistent checkpoint storage (the paper's "distributed file system")."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, path: str) -> str:
        return os.path.join(self.root, path.replace("/", "__") + ".npz")

    def save(self, path: str, values: Dict[str, Any]) -> None:
        flat: Dict[str, np.ndarray] = {}
        manifest: Dict[str, Any] = {}
        for name, val in values.items():
            leaves, treedef = jax.tree.flatten(val)
            manifest[name] = {"treedef": str(treedef), "n": len(leaves)}
            for i, leaf in enumerate(leaves):
                flat[f"{name}::{i}"] = np.asarray(leaf)
        tmp = self._path(path) + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(
                {k: {"n": v["n"]} for k, v in manifest.items()}), **flat)
        os.replace(tmp, self._path(path))  # atomic publish
        # stash treedefs in-process for exact pytree reconstruction
        self._treedefs = getattr(self, "_treedefs", {})
        self._treedefs[path] = {name: jax.tree.structure(values[name]) for name in values}

    def load(self, path: str) -> Dict[str, Any]:
        with np.load(self._path(path), allow_pickle=False) as z:
            manifest = json.loads(str(z["__manifest__"]))
            out: Dict[str, Any] = {}
            treedefs = getattr(self, "_treedefs", {}).get(path, {})
            for name, meta in manifest.items():
                leaves = [jax.numpy.asarray(z[f"{name}::{i}"]) for i in range(meta["n"])]
                if name in treedefs:
                    out[name] = jax.tree.unflatten(treedefs[name], leaves)
                elif meta["n"] == 1:
                    out[name] = leaves[0]
                else:
                    out[name] = leaves
            return out

    def exists(self, path: str) -> bool:
        return os.path.exists(self._path(path))

    def list(self) -> List[str]:
        return sorted(f[:-4].replace("__", "/") for f in os.listdir(self.root)
                      if f.endswith(".npz"))


class CheckpointManager:
    """Periodic save-every-N-steps/-seconds policy with retention."""

    def __init__(self, io: FileCheckpointIO, prefix: str = "ckpt",
                 every_steps: Optional[int] = 100,
                 every_seconds: Optional[float] = None,
                 keep: int = 3) -> None:
        self.io = io
        self.prefix = prefix
        self.every_steps = every_steps
        self.every_seconds = every_seconds
        self.keep = keep
        self._last_time = time.monotonic()
        self._saved_steps: List[int] = []
        for p in io.list():
            if p.startswith(prefix + "/step_"):
                try:
                    self._saved_steps.append(int(p.rsplit("_", 1)[1]))
                except ValueError:
                    pass
        self._saved_steps.sort()

    def should_save(self, step: int) -> bool:
        if self.every_steps and step > 0 and step % self.every_steps == 0:
            return True
        if self.every_seconds and (time.monotonic() - self._last_time) >= self.every_seconds:
            return True
        return False

    def save(self, step: int, values: Dict[str, Any]) -> str:
        path = f"{self.prefix}/step_{step}"
        self.io.save(path, values)
        self._last_time = time.monotonic()
        self._saved_steps.append(step)
        self._saved_steps.sort()
        while len(self._saved_steps) > self.keep:
            old = self._saved_steps.pop(0)
            try:
                os.remove(self.io._path(f"{self.prefix}/step_{old}"))
            except FileNotFoundError:
                pass
        return path

    def latest_step(self) -> Optional[int]:
        return self._saved_steps[-1] if self._saved_steps else None

    def restore_latest(self) -> Optional[Dict[str, Any]]:
        step = self.latest_step()
        if step is None:
            return None
        return self.io.load(f"{self.prefix}/step_{step}")


def attach_save_restore(b: GraphBuilder, variables: Sequence[Node],
                        path: str = "ckpt/manual") -> Dict[str, Node]:
    """§3.3 graph plumbing: connect each Variable to Save and Restore nodes."""
    save = b.save(list(variables), path, name=f"save_{path.replace('/', '_')}")
    restore = b.restore(list(variables), path, name=f"restore_{path.replace('/', '_')}")
    return {"save": save, "restore": restore}
