from .pipeline import (SyntheticLMDataset, FileRecordReader, Prefetcher,
                       input_pipeline, batch_iterator)

__all__ = ["SyntheticLMDataset", "FileRecordReader", "Prefetcher",
           "input_pipeline", "batch_iterator"]
