"""§4.5/§4.6 input pipeline: input ops + prefetch queues.

The paper's pattern: special input operation nodes configured with
filenames yield example tensors straight into the worker process, and
queues decouple the IO cadence from the compute cadence (prefetching the
next batch while the current one trains).  We implement:

  * ``SyntheticLMDataset`` — deterministic synthetic LM token stream (the
    substrate for training runs in this repo; vocab-bounded, seeded).
  * ``FileRecordReader``  — a real file-backed record reader (length-
    prefixed binary records), the §4.5 "read directly from storage" path.
  * ``Prefetcher``        — a background thread feeding a FIFO/shuffling
    queue; the training loop dequeues (§4.6).
"""
from __future__ import annotations

import os
import struct
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.queues import FIFOQueue, QueueClosed, ShufflingQueue


class SyntheticLMDataset:
    """Deterministic pseudo-text: Zipfian tokens with local correlations.

    A tiny fixed bigram structure makes the next-token task learnable, so
    "loss decreases" integration tests are meaningful rather than noise.
    """

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0) -> None:
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.RandomState(seed)
        # each token deterministically prefers a successor: easy structure
        self._succ = rng.randint(0, vocab_size, size=(vocab_size,), dtype=np.int64)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, batch_size: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2 ** 31))
        toks = np.empty((batch_size, self.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=batch_size, p=self._p)
        coin = rng.random_sample((batch_size, self.seq_len))
        rand = rng.choice(self.vocab_size, size=(batch_size, self.seq_len), p=self._p)
        for t in range(self.seq_len):
            follow = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(coin[:, t] < 0.75, follow, rand[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(1, step)
            step += 1


class FileRecordReader:
    """Length-prefixed binary record files (§4.5 input operations).

    Format: repeated [uint32 length][payload bytes].  ``write_records``
    is provided for tests and example-data generation.
    """

    def __init__(self, filenames: Sequence[str],
                 parse: Optional[Callable[[bytes], Any]] = None) -> None:
        self.filenames = list(filenames)
        self.parse = parse or (lambda b: b)

    @staticmethod
    def write_records(path: str, records: Sequence[bytes]) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "wb") as f:
            for r in records:
                f.write(struct.pack("<I", len(r)))
                f.write(r)

    def __iter__(self) -> Iterator[Any]:
        for fname in self.filenames:
            with open(fname, "rb") as f:
                while True:
                    hdr = f.read(4)
                    if len(hdr) < 4:
                        break
                    (n,) = struct.unpack("<I", hdr)
                    payload = f.read(n)
                    if len(payload) < n:
                        raise IOError(f"truncated record in {fname}")
                    yield self.parse(payload)


class Prefetcher:
    """Background thread: source iterator -> (shuffling) queue (§4.6)."""

    def __init__(self, source: Iterator[Any], capacity: int = 8,
                 shuffle: bool = False, min_after_dequeue: Optional[int] = None,
                 seed: Optional[int] = None) -> None:
        if shuffle:
            # Pre-fill contract: without a floor, a consumer that drains
            # as fast as the producer fills holds the shuffle window at
            # ~1 item and the "shuffled" stream can come out in order
            # (the old test_prefetcher_shuffling flake).  Defaulting the
            # floor to half the capacity keeps a real window resident
            # until the source closes; pass min_after_dequeue=0 to opt
            # out (e.g. latency-critical consumers).
            if min_after_dequeue is None:
                # clamped to capacity-1: a capacity-1 queue can never
                # hold the min_after_dequeue+1 items dequeue waits for
                min_after_dequeue = min(capacity - 1, max(1, capacity // 2))
            self.queue: FIFOQueue = ShufflingQueue(
                capacity=capacity, min_after_dequeue=min_after_dequeue, seed=seed)
        else:
            self.queue = FIFOQueue(capacity=capacity)
        self._source = source
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._started = False

    def _fill(self) -> None:
        try:
            for item in self._source:
                self.queue.enqueue(item)
                # Yield the GIL right after publishing: a consumer blocked
                # in dequeue() was just notified, but without an explicit
                # yield the producer keeps the GIL for up to the switch
                # interval (5ms default) while it generates the *next*
                # item, serialising the very overlap the queue exists to
                # provide (the b5 convoy effect).
                time.sleep(0)
        except QueueClosed:
            return
        finally:
            self.queue.close()

    def start(self) -> "Prefetcher":
        if not self._started:
            self._thread.start()
            self._started = True
        return self

    def get(self) -> Any:
        return self.queue.dequeue()

    def __iter__(self) -> Iterator[Any]:
        self.start()
        while True:
            try:
                yield self.queue.dequeue()
            except QueueClosed:
                return

    def stop(self) -> None:
        self.queue.close()


def batch_iterator(dataset: SyntheticLMDataset, batch_size: int,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield dataset.batch(batch_size, step)
        step += 1


def input_pipeline(vocab_size: int, seq_len: int, batch_size: int,
                   *, prefetch: int = 4, seed: int = 0,
                   start_step: int = 0) -> Prefetcher:
    """The standard train-input pipeline: synthetic LM -> prefetch queue."""
    ds = SyntheticLMDataset(vocab_size, seq_len, seed=seed)
    return Prefetcher(batch_iterator(ds, batch_size, start_step),
                      capacity=prefetch).start()
