"""Shared CLI surface for the launch tools (DESIGN.md §15).

``train`` and ``serve`` expose the same engine/numerics/cluster flags;
this module defines them once so the two parsers cannot drift, and turns
parsed args into a :class:`~repro.core.options.SessionOptions` in one
place — the options object then applies the documented resolution order
(explicit > ``REPRO_*`` env > default) itself.
"""
from __future__ import annotations

import argparse
from typing import Optional

from ..core.options import SessionOptions


def add_engine_options(ap: argparse.ArgumentParser,
                       *, numerics_default: str = "fast"
                       ) -> argparse.ArgumentParser:
    """--engine / --numerics / --backend: how a step executes locally."""
    ap.add_argument("--engine", choices=("jit", "graph"), default="jit",
                    help="jit: lowered+jitted step; graph: eager Session.run "
                         "through the cached Executable (DESIGN.md §5)")
    ap.add_argument("--numerics", choices=("fast", "strict"),
                    default=numerics_default,
                    help="graph-engine fused-region numerics (DESIGN.md §9): "
                         "fast compiles regions at full XLA optimization "
                         "under the CI-enforced tolerance contract; strict "
                         "restores fused==unfused bit-parity")
    ap.add_argument("--backend", default=None, metavar="NAME",
                    help="kernel backend for fused regions (e.g. pallas; "
                         "DESIGN.md §12) — default resolves "
                         "REPRO_KERNEL_BACKEND, then 'generic'")
    return ap


def add_cluster_options(ap: argparse.ArgumentParser,
                        *, replication: bool = False,
                        standby: bool = False) -> argparse.ArgumentParser:
    """--cluster (and friends): where a step executes (DESIGN.md §11)."""
    ap.add_argument("--cluster", default=None, metavar="HOST:PORT,...",
                    help="run over this worker pool (one `python -m "
                         "repro.distrib.worker` process per endpoint; "
                         "DESIGN.md §11)")
    if standby:
        ap.add_argument("--standby", default=None, metavar="HOST:PORT,...",
                        help="spare workers for §13 partial re-placement: a "
                             "dead task's subgraph re-places onto the first "
                             "free standby (survivors keep live state) before "
                             "the whole-pool checkpoint restart is considered")
    if replication:
        ap.add_argument("--replicas", type=int, default=1, metavar="N",
                        help="data-parallel replicas of the train step over "
                             "the --cluster pool (DESIGN.md §15)")
        ap.add_argument("--mode", choices=("sync", "async"), default="sync",
                        help="gradient aggregation across replicas: sync = "
                             "barrier step with tree-reduced mean gradients; "
                             "async = parameter-server applies with no "
                             "barrier (DESIGN.md §15)")
    return ap


def add_obs_options(ap: argparse.ArgumentParser,
                    *, summary: bool = False) -> argparse.ArgumentParser:
    """--trace-dir / --metrics-every (and --summary-dir for train): the
    §16 observability surface — distributed EEG traces, periodic metrics
    registry dumps, §9.1 scalar summaries."""
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write a merged Chrome-trace/Perfetto JSON of the "
                         "run there (§16 distributed EEG; also REPRO_TRACE). "
                         "Unset = tracing fully off, zero per-op cost")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="every N steps (or requests), print a snapshot of "
                         "the §16.4 metrics registry (0 = never)")
    if summary:
        ap.add_argument("--summary-dir", default=None, metavar="DIR",
                        help="append per-step scalar summaries (loss, "
                             "tokens/sec) as JSONL events there (§9.1; "
                             "read back with repro.tools.summary.read_events)")
    return ap


def session_options_from_args(args: argparse.Namespace,
                              **overrides) -> SessionOptions:
    """A SessionOptions carrying every session-relevant flag the parser
    saw.  Only explicitly-present args are forwarded, so flags a tool did
    not register (or that stayed None) fall through to the env/default
    tiers of the options resolution order."""
    kw = {}
    for field in ("numerics", "backend", "standby"):
        v = getattr(args, field, None)
        if v is not None:
            kw[field] = v
    if getattr(args, "trace_dir", None):
        kw["trace_dir"] = args.trace_dir
    if getattr(args, "cluster", None):
        kw["cluster"] = args.cluster
    kw.update(overrides)
    return SessionOptions(**kw)
