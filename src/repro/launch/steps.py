"""Step-function builders — the paper's system end to end.

``build_train_step`` constructs the training step AS A repro.core GRAPH
(loss Call node, §4.1 ``gradients()`` backward extension, AdamW update +
Assign nodes on Variables) and lowers it through the §10 JIT path to a
pure JAX function.  ``build_serve_step`` does the same for one decode
step with the KV/SSD cache as a Variable.  The launch layer then wraps
the lowered function in ``jax.jit`` with the mesh shardings from
parallel.sharding — placement-as-sharding-rules (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import (GraphBuilder, Session, SessionOptions, compile_subgraph,
                    gradients)
from ..models.api import Model, Shape, SHAPES
from ..models.config import ModelConfig
from ..models.params import abstract_params, param_axes, init_params
from ..optim import adamw_init, adamw_update
from ..parallel import sharding as shd
from . import mesh as mesh_mod


@dataclasses.dataclass
class StepBundle:
    """Everything needed to run/lower one workload step."""

    fn: Callable                   # (feeds dict, vars dict) -> (outs, new_vars)
    feed_specs: Dict[str, jax.ShapeDtypeStruct]
    var_specs: Dict[str, Any]      # abstract values for Variables
    feed_shardings: Dict[str, Any]
    var_shardings: Dict[str, Any]
    out_shardings: Any
    model: Model
    kind: str
    graph_nodes: int = 0


@dataclasses.dataclass
class EagerStepBundle:
    """A step driven through ``Session.run`` (the §2 eager path).

    ``step`` is bound to the Session's cached Executable for its run
    signature (DESIGN.md §5): the first call pays prune/place/partition/
    schedule + executor static analysis, every subsequent call only
    allocates per-run executor state.  Variables (params/opt/cache) live
    in the Session's variable store — set them with
    ``bundle.session.set_variable`` before the first step.
    """

    session: Session
    step: Callable[[Dict[str, Any]], Any]  # feeds by name -> primary output
    model: Model
    feed_names: Tuple[str, ...]
    kind: str
    graph_nodes: int = 0

    def variables(self) -> Dict[str, Any]:
        """Snapshot the step's Variables (e.g. for checkpointing)."""
        return {name: self.session.variable_value(name)
                for name, node in self.session.graph.nodes.items()
                if node.op == "Variable"}


def _named(mesh: Optional[Mesh], spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _feed_key(name: str) -> str:
    return f"{name}:0"


def step_hparams(cfg: ModelConfig, shape: Shape, n_groups: int) -> Dict[str, Any]:
    """Workload-dependent chunking knobs (memory-safety defaults)."""
    hp: Dict[str, Any] = {
        "compute_dtype": jnp.bfloat16,
        "n_token_groups": n_groups,
        "q_chunk": 0,
        "loss_chunk": 0,
        "scan_unroll": 1,
        "microbatch": 1,   # gradient-accumulation steps (memory lever)
    }
    if shape.kind in ("train", "prefill"):
        if shape.seq_len >= 4096:
            hp["q_chunk"] = 256
        hp["loss_chunk"] = 512 if shape.seq_len >= 4096 else 0
    if shape.global_batch < n_groups or shape.global_batch % n_groups:
        hp["n_token_groups"] = 1
    return hp


# ---------------------------------------------------------------------------
# Wire-shippable Call factories (DESIGN.md §15): the LM step kernels as
# importable ``module:qualname`` constructors over picklable statics, so
# the graphs built below register on a §11 worker pool unchanged.  A
# worker resolves them at registration time via ``ops.resolve_call_fn``
# (one model build per process, shared across replicas).

LM_LOSS_FACTORY = "repro.launch.steps:lm_loss_factory"
LM_LOSS_GRAD_FACTORY = "repro.launch.steps:lm_loss_and_grad_factory"
LM_UPDATE_FACTORY = "repro.launch.steps:lm_update_factory"
LM_SERVE_FACTORY = "repro.launch.steps:lm_serve_factory"


def lm_loss_factory(cfg: ModelConfig, shard: int, feed_names, loss_kw):
    """Rebuild the LM loss kernel: ``(params, *feeds) -> scalar loss``."""
    model = Model.for_config(cfg, shard)
    feed_names, loss_kw = tuple(feed_names), dict(loss_kw)

    def graph_loss(params, *feeds):
        return model.loss_fn(params, dict(zip(feed_names, feeds)), **loss_kw)

    return graph_loss


def lm_loss_and_grad_factory(cfg: ModelConfig, shard: int, feed_names,
                             loss_kw, n_micro: int):
    """Rebuild the fused loss+grad kernel with gradient accumulation over
    ``n_micro`` microbatches (memory lever: stored activations scale with
    B/n_micro, grads accumulate fp32)."""
    feed_names = tuple(feed_names)
    loss_feeds = lm_loss_factory(cfg, shard, feed_names, loss_kw)

    def loss_of(params, batch):
        return loss_feeds(params, *[batch[n] for n in feed_names])

    def graph_loss_grad(params, *feeds):
        batch = dict(zip(feed_names, feeds))
        if n_micro <= 1:
            return jax.value_and_grad(loss_of)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = {k: v.reshape((n_micro, B // n_micro) + v.shape[1:])
              for k, v in batch.items()}

        def body(carry, mbatch):
            tot_loss, acc = carry
            l, g = jax.value_and_grad(loss_of)(params, mbatch)
            acc = jax.tree.map(
                lambda a, gi: a + gi.astype(jnp.float32) / n_micro, acc, g)
            return (tot_loss + l / n_micro, acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_val, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss_val, grads

    return graph_loss_grad


def lm_update_factory(lr: float):
    """Rebuild the AdamW apply: ``(params, grads, opt) -> (params, opt)``."""

    def update(params, grads, opt):
        return adamw_update(params, grads, opt, lr=lr)

    return update


def lm_serve_factory(cfg: ModelConfig, shard: int, serve_kw):
    """Rebuild one-token decode: ``(params, cache, tokens, pos) ->
    (logits, cache)``."""
    model = Model.for_config(cfg, shard)
    serve_kw = dict(serve_kw)

    def serve(params, cache, tokens, pos):
        return model.serve_step(params, cache, tokens, pos, **serve_kw)

    return serve


def _train_graph(feed_names, cfg: ModelConfig, shard: int, loss_kw,
                 lr: float, n_micro: int):
    """The training step AS A repro.core GRAPH: loss Call node, §4.1
    ``gradients()`` backward extension, AdamW update + Assign nodes —
    shared by the lowered (JIT) and eager (Session.run) paths.  Every
    Call is declared through a wire-shippable factory (§15), so the same
    graph also registers on a worker pool."""
    b = GraphBuilder()
    v_params = b.variable("params")
    v_opt = b.variable("opt")
    feed_names = tuple(feed_names)
    feed_nodes = {n: b.placeholder(n) for n in feed_names}
    ins = [v_params] + [feed_nodes[n] for n in feed_names]

    if n_micro <= 1:
        # faithful path: §4.1 gradients() extends the graph
        loss_node = b.call_factory(LM_LOSS_FACTORY, ins,
                                   args=(cfg, shard, feed_names, loss_kw),
                                   name="loss")
        (gref,) = gradients(b.graph, [loss_node], [v_params])
    else:
        # accumulated grads are one fused node (still "just nodes")
        lg = b.call_factory(LM_LOSS_GRAD_FACTORY, ins,
                            args=(cfg, shard, feed_names, loss_kw, n_micro),
                            name="loss_and_grad", n_out=2)
        loss_node, gref = lg, lg.output(1)
    upd = b.call_factory(LM_UPDATE_FACTORY, [v_params, gref, v_opt],
                         args=(lr,), name="adamw", n_out=2)
    a1 = b.assign(v_params, upd.output(0))
    a2 = b.assign(v_opt, upd.output(1))
    return b, loss_node, a1, a2, feed_nodes


def build_train_step(
    cfg: ModelConfig,
    shape: Shape,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, Any]] = None,
    *,
    lr: float = 3e-4,
    hparam_overrides: Optional[Dict[str, Any]] = None,
    via_graph: bool = True,
) -> StepBundle:
    shard = mesh.shape["model"] if mesh is not None else 1
    n_groups = mesh_mod.batch_shard_size(mesh) if mesh is not None else 1
    model = Model.for_config(cfg, shard)
    hp = step_hparams(cfg, shape, n_groups)
    hp.update(hparam_overrides or {})
    loss_kw = dict(q_chunk=hp["q_chunk"], loss_chunk=hp["loss_chunk"],
                   compute_dtype=hp["compute_dtype"],
                   scan_unroll=hp["scan_unroll"])
    if not model.is_encdec:
        loss_kw["n_token_groups"] = hp["n_token_groups"]

    def loss_of(params, batch):
        return model.loss_fn(params, batch, **loss_kw)

    def update_of(params, grads, opt):
        return adamw_update(params, grads, opt, lr=lr)

    batch_desc = model.batch_desc(shape)
    feed_names = list(batch_desc)
    n_micro = int(hp.get("microbatch", 1))

    def loss_and_grad_of(params, batch):
        """Gradient accumulation over n_micro microbatches (memory lever:
        stored activations scale with B/n_micro, grads accumulate fp32)."""
        if n_micro <= 1:
            return jax.value_and_grad(loss_of)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = {k: v.reshape((n_micro, B // n_micro) + v.shape[1:])
              for k, v in batch.items()}

        def body(carry, mbatch):
            tot_loss, acc = carry
            l, g = jax.value_and_grad(loss_of)(params, mbatch)
            acc = jax.tree.map(
                lambda a, gi: a + gi.astype(jnp.float32) / n_micro, acc, g)
            return (tot_loss + l / n_micro, acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_val, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss_val, grads

    if via_graph:
        b, loss_node, a1, a2, feed_nodes = _train_graph(
            feed_names, cfg, shard, loss_kw, lr, n_micro)
        sess = Session(b.graph)
        lowered = compile_subgraph(
            sess, [loss_node.ref], [feed_nodes[n].ref for n in feed_names],
            extra_updates=[a1.name, a2.name])
        n_nodes = lowered.n_nodes

        def fn(feeds: Dict[str, Any], variables: Dict[str, Any]):
            feed_vals = {_feed_key(n): feeds[n] for n in feed_names}
            (loss_val,), new_vars = lowered.fn(feed_vals, variables)
            return loss_val, new_vars
    else:
        n_nodes = 0

        def fn(feeds: Dict[str, Any], variables: Dict[str, Any]):
            params, opt = variables["params"], variables["opt"]
            loss_val, grads = loss_and_grad_of(params, feeds)
            new_params, new_opt = update_of(params, grads, opt)
            return loss_val, {"params": new_params, "opt": new_opt}

    # --- specs + shardings
    pdesc = model.describe_params()
    params_abs = abstract_params(pdesc)
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    rules = rules if rules is not None else (
        mesh_mod.mesh_rules(mesh) if mesh is not None else None)
    if rules is not None:
        paxes = param_axes(pdesc)
        pspec = shd.param_pspecs(paxes, rules)
        opt_spec = jax.eval_shape(adamw_init, params_abs)  # structure template
        opt_pspec = dataclasses_replace_optstate(pspec, opt_spec)
        var_shardings = _named(mesh, {"params": pspec, "opt": opt_pspec})
        feed_shardings = {
            n: NamedSharding(mesh, shd.pspec_of(batch_desc[n].axes, rules))
            for n in feed_names}
        out_shardings = (NamedSharding(mesh, P()),
                         var_shardings)
    else:
        var_shardings = feed_shardings = out_shardings = None

    feed_specs = {n: jax.ShapeDtypeStruct(batch_desc[n].shape, batch_desc[n].dtype)
                  for n in feed_names}
    return StepBundle(fn=fn, feed_specs=feed_specs,
                      var_specs={"params": params_abs, "opt": opt_abs},
                      feed_shardings=feed_shardings,
                      var_shardings=var_shardings,
                      out_shardings=out_shardings,
                      model=model, kind="train", graph_nodes=n_nodes)


def dataclasses_replace_optstate(pspec_tree, opt_template):
    """OptState(step, m, v): m/v shard like params, step replicated."""
    from ..optim import OptState
    return OptState(step=P(), m=pspec_tree, v=pspec_tree)


# ---------------------------------------------------------------------------


def build_prefill_step(
    cfg: ModelConfig,
    shape: Shape,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, Any]] = None,
    *,
    hparam_overrides: Optional[Dict[str, Any]] = None,
) -> StepBundle:
    """Forward over the full prompt; returns last-position logits."""
    shard = mesh.shape["model"] if mesh is not None else 1
    n_groups = mesh_mod.batch_shard_size(mesh) if mesh is not None else 1
    model = Model.for_config(cfg, shard)
    hp = step_hparams(cfg, shape, n_groups)
    hp.update(hparam_overrides or {})

    fwd_kw = dict(q_chunk=hp["q_chunk"], compute_dtype=hp["compute_dtype"],
                  scan_unroll=hp["scan_unroll"])
    if not model.is_encdec:
        fwd_kw["n_token_groups"] = hp["n_token_groups"]

    from ..models import lm as lm_mod
    from ..models import encdec as encdec_mod

    def fn(feeds: Dict[str, Any], variables: Dict[str, Any]):
        params = variables["params"]
        if model.is_encdec:
            x, _ = encdec_mod.forward(cfg, model.plan, params, feeds["tokens"],
                                      feeds["frames"], q_chunk=hp["q_chunk"],
                                      compute_dtype=hp["compute_dtype"],
                                      scan_unroll=hp["scan_unroll"])
        else:
            x, _ = lm_mod.forward(cfg, model.plan, params, feeds["tokens"],
                                  **fwd_kw)
        last = x[:, -1:, :]
        logits = lm_mod.logits_from_hidden(cfg, model.plan, params, last)
        return logits, {}

    batch_desc = model.batch_desc(shape)
    batch_desc.pop("labels", None)
    feed_names = list(batch_desc)
    pdesc = model.describe_params()
    params_abs = abstract_params(pdesc)
    rules = rules if rules is not None else (
        mesh_mod.mesh_rules(mesh) if mesh is not None else None)
    if rules is not None:
        pspec = shd.param_pspecs(param_axes(pdesc), rules)
        var_shardings = _named(mesh, {"params": pspec})
        feed_shardings = {
            n: NamedSharding(mesh, shd.pspec_of(batch_desc[n].axes, rules))
            for n in feed_names}
        out_shardings = (NamedSharding(
            mesh, shd.pspec_of(("batch", None, "vocab"), rules)), {})
    else:
        var_shardings = feed_shardings = out_shardings = None
    feed_specs = {n: jax.ShapeDtypeStruct(batch_desc[n].shape, batch_desc[n].dtype)
                  for n in feed_names}
    return StepBundle(fn=fn, feed_specs=feed_specs,
                      var_specs={"params": params_abs},
                      feed_shardings=feed_shardings,
                      var_shardings=var_shardings, out_shardings=out_shardings,
                      model=model, kind="prefill")


# ---------------------------------------------------------------------------


def build_serve_step(
    cfg: ModelConfig,
    shape: Shape,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, Any]] = None,
    *,
    hparam_overrides: Optional[Dict[str, Any]] = None,
    via_graph: bool = True,
) -> StepBundle:
    """One-token decode against a seq_len cache (Variable in the graph)."""
    shard = mesh.shape["model"] if mesh is not None else 1
    n_groups = mesh_mod.batch_shard_size(mesh) if mesh is not None else 1
    model = Model.for_config(cfg, shard)
    longctx = shape.name == "long_500k"
    hp = step_hparams(cfg, shape, n_groups)
    hp.update(hparam_overrides or {})

    serve_kw: Dict[str, Any] = dict(compute_dtype=hp["compute_dtype"],
                                    serve_longctx=longctx,
                                    scan_unroll=hp["scan_unroll"])
    if not model.is_encdec:
        serve_kw["n_token_groups"] = hp["n_token_groups"]

    def serve_of(params, cache, tokens, pos):
        return model.serve_step(params, cache, tokens, pos, **serve_kw)

    if via_graph:
        b = GraphBuilder()
        v_params = b.variable("params")
        v_cache = b.variable("cache")
        t_ph = b.placeholder("tokens")
        p_ph = b.placeholder("pos")
        out = b.call_factory(LM_SERVE_FACTORY, [v_params, v_cache, t_ph, p_ph],
                             args=(cfg, shard, serve_kw), name="serve",
                             n_out=2)
        a_cache = b.assign(v_cache, out.output(1))
        sess = Session(b.graph)
        lowered = compile_subgraph(sess, [out.output(0)],
                                   [t_ph.ref, p_ph.ref],
                                   extra_updates=[a_cache.name])
        n_nodes = lowered.n_nodes

        def fn(feeds: Dict[str, Any], variables: Dict[str, Any]):
            feed_vals = {"tokens:0": feeds["tokens"], "pos:0": feeds["pos"]}
            (logits,), new_vars = lowered.fn(feed_vals, variables)
            return logits, new_vars
    else:
        n_nodes = 0

        def fn(feeds, variables):
            logits, new_cache = serve_of(variables["params"], variables["cache"],
                                         feeds["tokens"], feeds["pos"])
            return logits, {"cache": new_cache}

    pdesc = model.describe_params(serve_longctx=longctx)
    if hp.get("param_dtype") is not None:
        # serving-mode weights (e.g. bf16): checkpoint-cast at load time
        import dataclasses as _dc

        pdesc = jax.tree.map(
            lambda sp: _dc.replace(sp, dtype=hp["param_dtype"]), pdesc,
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
    cdesc = model.init_cache_desc(batch=shape.global_batch,
                                  max_seq=shape.seq_len, serve_longctx=longctx,
                                  dtype=hp["compute_dtype"])
    params_abs = abstract_params(pdesc)
    cache_abs = abstract_params(cdesc)
    batch_desc = model.batch_desc(shape)
    feed_names = list(batch_desc)
    rules = rules if rules is not None else (
        mesh_mod.mesh_rules(mesh) if mesh is not None else None)
    if rules is not None:
        pspec = shd.param_pspecs(param_axes(pdesc), rules)
        caxes = param_axes(cdesc)
        if shape.global_batch == 1:  # long_500k: nothing to shard on batch
            caxes = jax.tree.map(
                lambda axes: tuple(None if a == "batch" else a for a in axes),
                caxes, is_leaf=lambda x: isinstance(x, tuple) and all(
                    a is None or isinstance(a, str) for a in x))
        cspec = shd.param_pspecs(caxes, rules)
        var_shardings = _named(mesh, {"params": pspec, "cache": cspec})
        feed_shardings = {}
        for n in feed_names:
            axes = batch_desc[n].axes
            if shape.global_batch == 1:
                axes = tuple(None for _ in axes)
            feed_shardings[n] = NamedSharding(mesh, shd.pspec_of(axes, rules))
        out_vocab = shd.pspec_of(
            ("batch" if shape.global_batch > 1 else None, None, "vocab"), rules)
        out_shardings = (NamedSharding(mesh, out_vocab),
                         _named(mesh, {"cache": cspec}))
    else:
        var_shardings = feed_shardings = out_shardings = None
    feed_specs = {n: jax.ShapeDtypeStruct(batch_desc[n].shape, batch_desc[n].dtype)
                  for n in feed_names}
    return StepBundle(fn=fn, feed_specs=feed_specs,
                      var_specs={"params": params_abs, "cache": cache_abs},
                      feed_shardings=feed_shardings,
                      var_shardings=var_shardings, out_shardings=out_shardings,
                      model=model, kind="decode", graph_nodes=n_nodes)


def build_eager_train_step(
    cfg: ModelConfig,
    shape: Shape,
    *,
    lr: float = 3e-4,
    hparam_overrides: Optional[Dict[str, Any]] = None,
    numerics: Optional[str] = None,
    options: Optional[SessionOptions] = None,
) -> EagerStepBundle:
    """Train step for the eager multi-run path: the same graph as
    ``build_train_step(via_graph=True)`` but *run*, not lowered — each call
    re-enters ``Session.run`` and hits the cached Executable for the
    (loss, train_op) signature (compile once, run many; DESIGN.md §5).
    ``numerics`` selects the fused-region policy (DESIGN.md §9): the
    train tool defaults the graph engine to "fast".  The graph is built
    from §15 Call factories, so with ``options.cluster`` set the same
    step registers and runs on a worker pool."""
    model = Model.for_config(cfg)
    hp = step_hparams(cfg, shape, 1)
    hp.update(hparam_overrides or {})
    loss_kw = dict(q_chunk=hp["q_chunk"], loss_chunk=hp["loss_chunk"],
                   compute_dtype=hp["compute_dtype"],
                   scan_unroll=hp["scan_unroll"])
    if not model.is_encdec:
        loss_kw["n_token_groups"] = hp["n_token_groups"]

    feed_names = list(model.batch_desc(shape))
    b, loss_node, a1, a2, feed_nodes = _train_graph(
        feed_names, cfg, 1, loss_kw, lr, 1)
    train_op = b.group([a1, a2], name="train_op")
    opts = options or SessionOptions()
    if numerics is not None:
        opts = dataclasses.replace(opts, numerics=numerics)
    sess = Session(b.graph, options=opts)
    run = sess.make_callable([loss_node.ref, train_op.ref],
                             [feed_nodes[n].ref for n in feed_names])

    def step(feeds: Dict[str, Any]):
        loss_val, _ = run(*[feeds[n] for n in feed_names])
        return loss_val

    return EagerStepBundle(session=sess, step=step, model=model,
                           feed_names=tuple(feed_names), kind="train",
                           graph_nodes=len(b.graph.nodes))


def build_eager_serve_step(cfg: ModelConfig,
                           numerics: Optional[str] = None,
                           options: Optional[SessionOptions] = None
                           ) -> EagerStepBundle:
    """One-token decode as a Session graph: the KV cache is a Variable
    updated by an Assign node, so the decode loop is exactly the paper's
    steady-state serving shape — one cached Executable re-run per token.
    Under ``numerics="fast"`` (the serve tool's graph-engine default) the
    ``Call`` + cache Assign fuse into one jitted region (DESIGN.md §9).
    The serve Call is factory-form (§15), so the graph is wire-shippable."""
    model = Model.for_config(cfg)

    b = GraphBuilder()
    v_params = b.variable("params")
    v_cache = b.variable("cache")
    t_ph = b.placeholder("tokens")
    p_ph = b.placeholder("pos")
    out = b.call_factory(LM_SERVE_FACTORY, [v_params, v_cache, t_ph, p_ph],
                         args=(cfg, 1, {}), name="serve", n_out=2)
    a_cache = b.assign(v_cache, out.output(1))
    opts = options or SessionOptions()
    if numerics is not None:
        opts = dataclasses.replace(opts, numerics=numerics)
    sess = Session(b.graph, options=opts)
    run = sess.make_callable([out.output(0), a_cache.ref],
                             [t_ph.ref, p_ph.ref])

    def step(feeds: Dict[str, Any]):
        logits, _ = run(feeds["tokens"], feeds["pos"])
        return logits

    return EagerStepBundle(session=sess, step=step, model=model,
                           feed_names=("tokens", "pos"), kind="decode",
                           graph_nodes=len(b.graph.nodes))


@dataclasses.dataclass
class WireStepBundle:
    """A train/score step whose graph can ship to a §11 worker pool.

    Every node is a registered primitive op (MatMul/ReLU/SoftmaxXent/
    Assign/...), so the graph pickles onto the wire with no Call
    machinery at all — the minimal exemplar.  The Call-based LM steps
    ship too, now that they are declared through §15 factories
    (``GraphBuilder.call_factory``); see ``build_lm_replica_spec``.
    """

    builder: Any                     # GraphBuilder owning the graph
    loss: Any                        # TensorRef: scalar mean xent
    logits: Any                      # TensorRef: pre-softmax scores
    train_op: Any                    # TensorRef: grouped Assign updates
    feed_x: Any                      # TensorRef: [batch, n_features] float32
    feed_y: Any                      # TensorRef: [batch] int labels
    var_names: Tuple[str, ...]


def build_wire_train_step(tasks: Sequence[str], *, n_features: int = 16,
                          n_hidden: int = 32, n_classes: int = 8,
                          lr: float = 0.1, seed: int = 0) -> WireStepBundle:
    """Primitive-op MLP softmax classifier, device-tagged across ``tasks``.

    The forward pass alternates devices (x@W1+ReLU on the first task, the
    logits matmul on the last), so every step exercises cross-task
    Send/Recv in both directions; §4.1 ``gradients()`` extends the graph
    with the backward pass and SGD updates land in Assign nodes that the
    §3.2.1 placer colocates with their Variables — which is what keeps
    each worker's variable store authoritative for the state it owns.
    """
    import numpy as np

    from ..core import GraphBuilder, gradients

    rs = np.random.RandomState(seed)
    b = GraphBuilder()
    d0, d1 = tasks[0], tasks[-1]
    x = b.placeholder("x")
    y = b.placeholder("y")
    w1 = b.variable("w1", jnp.asarray(
        rs.randn(n_features, n_hidden).astype("f") * 0.2), device=d0)
    w2 = b.variable("w2", jnp.asarray(
        rs.randn(n_hidden, n_classes).astype("f") * 0.2), device=d1)
    h = b.relu(b.matmul(x, w1, name="mm1", device=d0), name="h", device=d0)
    logits = b.matmul(h, w2, name="logits", device=d1)
    loss = b.softmax_xent(logits, y, name="loss")
    g1, g2 = gradients(b.graph, [loss], [w1, w2])
    lrc = b.constant(jnp.float32(lr), name="lr")
    a1 = b.assign(w1, b.sub(w1, b.mul(lrc, g1, name="upd1/scaled"),
                            name="upd1/new"))
    a2 = b.assign(w2, b.sub(w2, b.mul(lrc, g2, name="upd2/scaled"),
                            name="upd2/new"))
    train_op = b.group([a1, a2], name="train_op")
    return WireStepBundle(builder=b, loss=loss.ref, logits=logits.ref,
                          train_op=train_op.ref, feed_x=x.ref, feed_y=y.ref,
                          var_names=("w1", "w2"))


# ---------------------------------------------------------------------------
# §15 replica specs: train-step shapes for distrib.replication.ReplicaPlan


def _sgd_apply(lr, values, grads):
    """Master-side parameter-server SGD (async mode)."""
    return {k: values[k] - lr * g for k, g in grads.items()}


def _lm_apply(lr, values, grads):
    """Master-side parameter-server AdamW (async mode)."""
    new_params, new_opt = adamw_update(values["params"], grads["params"],
                                       values["opt"], lr=lr)
    return {"params": new_params, "opt": new_opt}


def build_mlp_replica_spec(*, n_features: int = 16, n_hidden: int = 32,
                           n_classes: int = 8, lr: float = 0.1,
                           seed: int = 0):
    """The primitive-op MLP of ``build_wire_train_step`` reshaped as a
    ReplicaSpec: N data-parallel copies sharing (w1, w2)."""
    import numpy as np

    from ..distrib.replication import ReplicaSpec, ReplicaStep

    rs = np.random.RandomState(seed)
    init = {
        "w1": jnp.asarray(rs.randn(n_features, n_hidden).astype("f") * 0.2),
        "w2": jnp.asarray(rs.randn(n_hidden, n_classes).astype("f") * 0.2),
    }

    def build_replica(b, r, dev, var_inputs):
        x = b.placeholder(f"rep{r}/x")
        y = b.placeholder(f"rep{r}/y")
        w1, w2 = var_inputs["w1"], var_inputs["w2"]
        h = b.relu(b.matmul(x, w1, name=f"rep{r}/mm1", device=dev),
                   name=f"rep{r}/h", device=dev)
        logits = b.matmul(h, w2, name=f"rep{r}/logits", device=dev)
        loss = b.softmax_xent(logits, y, name=f"rep{r}/loss")
        g1, g2 = gradients(b.graph, [loss], [w1, w2])
        return ReplicaStep(loss=loss.ref, grads={"w1": g1, "w2": g2},
                           feeds={"x": x.ref, "y": y.ref})

    def build_apply(b, var_nodes, mean_grads, dev):
        lrc = b.constant(jnp.float32(lr), name="lr", device=dev)
        a1 = b.assign(var_nodes["w1"], b.sub(
            var_nodes["w1"], b.mul(lrc, mean_grads["w1"], name="upd1/scaled"),
            name="upd1/new"))
        a2 = b.assign(var_nodes["w2"], b.sub(
            var_nodes["w2"], b.mul(lrc, mean_grads["w2"], name="upd2/scaled"),
            name="upd2/new"))
        return b.group([a1, a2], name="train_op")

    return ReplicaSpec(var_names=("w1", "w2"), read_vars=("w1", "w2"),
                       grad_vars=("w1", "w2"), feed_names=("x", "y"),
                       init_values=init, build_replica=build_replica,
                       build_apply=build_apply,
                       apply_fn=functools.partial(_sgd_apply, lr))


def build_lm_replica_spec(cfg: ModelConfig, shape: Shape, *, lr: float = 1e-2,
                          hparam_overrides: Optional[Dict[str, Any]] = None,
                          seed: int = 0):
    """The factory-Call LM train step as a ReplicaSpec: each replica is
    one ``lm_loss_factory`` Call plus its §4.1 backward extension, with
    parameters shared (sync) or parameter-served (async)."""
    from ..distrib.replication import ReplicaSpec, ReplicaStep

    model = Model.for_config(cfg)
    hp = step_hparams(cfg, shape, 1)
    hp.update(hparam_overrides or {})
    loss_kw = dict(q_chunk=hp["q_chunk"], loss_chunk=hp["loss_chunk"],
                   compute_dtype=hp["compute_dtype"],
                   scan_unroll=hp["scan_unroll"])
    if not model.is_encdec:
        loss_kw["n_token_groups"] = hp["n_token_groups"]
    feed_names = tuple(model.batch_desc(shape))
    params = init_params(model.describe_params(), jax.random.PRNGKey(seed))
    init = {"params": params, "opt": adamw_init(params)}

    def build_replica(b, r, dev, var_inputs):
        feeds = {n: b.placeholder(f"rep{r}/{n}") for n in feed_names}
        loss = b.call_factory(
            LM_LOSS_FACTORY,
            [var_inputs["params"]] + [feeds[n] for n in feed_names],
            args=(cfg, 1, feed_names, loss_kw), name=f"rep{r}/loss",
            device=dev)
        (g,) = gradients(b.graph, [loss], [var_inputs["params"]])
        return ReplicaStep(loss=loss.ref, grads={"params": g},
                           feeds={n: feeds[n].ref for n in feed_names})

    def build_apply(b, var_nodes, mean_grads, dev):
        upd = b.call_factory(
            LM_UPDATE_FACTORY,
            [var_nodes["params"], mean_grads["params"], var_nodes["opt"]],
            args=(lr,), name="adamw", n_out=2, device=dev)
        a1 = b.assign(var_nodes["params"], upd.output(0))
        a2 = b.assign(var_nodes["opt"], upd.output(1))
        return b.group([a1, a2], name="train_op")

    return ReplicaSpec(var_names=("params", "opt"), read_vars=("params",),
                       grad_vars=("params",), feed_names=feed_names,
                       init_values=init, build_replica=build_replica,
                       build_apply=build_apply,
                       apply_fn=functools.partial(_lm_apply, lr))


def build_step(cfg: ModelConfig, shape_name: str, mesh=None, rules=None, **kw
               ) -> StepBundle:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, rules, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, rules, **kw)
    return build_serve_step(cfg, shape, mesh, rules, **kw)
