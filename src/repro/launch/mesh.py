"""Production mesh definitions (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is an outer
pure-data-parallel axis whose gradient all-reduce crosses DCI.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run pins XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax


# Older jax (< 0.5) has no jax.sharding.AxisType and jax.make_mesh takes
# no axis_types kwarg; every axis behaves as Auto there, so building the
# mesh untyped is semantics-preserving.  Gate on the attribute instead of
# a version string (the attribute is the actual dependency).
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def host_mesh_supported() -> bool:
    """True iff this jax can build the degenerate 1x1 host mesh (used by
    CPU tests of the sharded path to skip cleanly on exotic versions)."""
    try:
        make_host_mesh()
        return True
    except (AttributeError, TypeError):
        # only the known version incompatibilities (missing AxisType /
        # make_mesh signature drift) downgrade to a skip — anything else
        # propagates so a broken sharded path fails loudly, not silently
        return False


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests of the sharded code path."""
    return _make_mesh((1, 1), ("data", "model"))


def mesh_rules(mesh, *, overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Logical-axis rules appropriate for a mesh (see parallel.sharding)."""
    from ..parallel.sharding import DEFAULT_RULES

    rules = dict(DEFAULT_RULES)
    if "pod" in mesh.axis_names:
        rules["batch"] = ("pod", "data")
    for k, v in (overrides or {}).items():
        rules[k] = v
    return rules


def batch_shard_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
